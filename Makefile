# Developer entry points.  The linter (`make lint`) is pure stdlib; the
# test lanes need jax + numpy + requirements-dev.txt (pytest, hypothesis).
PYTHONPATH := src

.PHONY: lint lint-json fast test bench-table

lint:          ## invariant linter over the whole tree (CI `analysis` job)
	PYTHONPATH=$(PYTHONPATH) python -m repro.analysis src tests benchmarks examples
	PYTHONPATH=$(PYTHONPATH) python benchmarks/report.py --check

lint-json:     ## machine-readable findings (CI annotation / tooling)
	PYTHONPATH=$(PYTHONPATH) python -m repro.analysis --format json src tests benchmarks examples

fast:          ## fast test lane: slow-marked tests excluded
	HYPOTHESIS_PROFILE=fast PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m "not slow"

test:          ## tier-1: the full suite (release gate)
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-table:   ## regenerate the README perf-trajectory table
	PYTHONPATH=$(PYTHONPATH) python benchmarks/report.py --readme
