"""Mesh-agnostic sharding-constraint hooks.

Model code calls ``constrain(x, "activation")`` at a few strategic points
(embeddings out, logits, MoE expert buffer).  Outside a mesh context this is
the identity; inside (set up by the step builders in ``repro.launch``), it
applies ``with_sharding_constraint`` with the logical→mesh axis mapping of
the active mesh, so the same model code runs on CPU tests and on the
(pod, data, model) production mesh.

Logical axes:
  dp  — batch/data parallel        → ("pod", "data") or ("data",)
  tp  — tensor/model parallel      → ("model",)
  sp  — sequence parallel (opt-in) → ("data",)  [used by §Perf hillclimbs]
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

# logical name -> PartitionSpec template (in logical axes)
SPEC_TABLE = {
    # [B, S, d]
    "activation": ("dp", None, None),
    # [B, S, d] with Megatron-style sequence parallelism: the residual
    # stream (and the per-layer saved carries under remat) shard S over the
    # tensor axis; GSPMD inserts the all-gather/reduce-scatter pair at the
    # layer boundaries.
    "activation_sp": ("dp", "tp", None),
    # [B, S, V]
    "logits": ("dp", None, "tp"),
    # [E, C, d]
    "moe_buffer": ("tp", None, None),
    # [B, S, H, D]
    "heads": ("dp", None, "tp", None),
    # KV cache [B, S, Hkv, D]
    "kv_cache": ("dp", None, None, None),
    # KV cache, sequence-parallel variant (long-context decode hillclimb)
    "kv_cache_sp": ("dp", "sp", None, None),
}


def _mapping() -> Optional[dict]:
    return getattr(_state, "mapping", None)


@contextlib.contextmanager
def axis_mapping(mapping: dict[str, tuple[str, ...]], mesh=None):
    """mapping: logical axis -> tuple of mesh axis names (or ())."""
    prev = _mapping()
    prev_mesh = getattr(_state, "mesh", None)
    _state.mapping = mapping
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mapping = prev
        _state.mesh = prev_mesh


def current_mesh():
    """The concrete mesh of the active step builder (None on CPU tests)."""
    return getattr(_state, "mesh", None)


def dp_axes() -> tuple[str, ...]:
    m = _mapping()
    return tuple(m.get("dp", ())) if m else ()


def tp_axes() -> tuple[str, ...]:
    m = _mapping()
    return tuple(m.get("tp", ())) if m else ()


def resolve(name: str) -> Optional[P]:
    m = _mapping()
    if m is None:
        return None
    template = SPEC_TABLE[name]
    axes = []
    for a in template:
        if a is None:
            axes.append(None)
        else:
            mesh_axes = m.get(a, ())
            axes.append(mesh_axes if mesh_axes else None)
    return P(*axes)


def constrain(x: jax.Array, name: str) -> jax.Array:
    spec = resolve(name)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except ValueError:
        # rank/axis mismatch (e.g. reduced smoke shapes) — skip constraint
        return x
