"""Parameter partitioning rules.

Name-and-divisibility-driven PartitionSpec assignment (DESIGN.md §5):

  * tensor parallelism ("model" axis): head-aligned projection dims, FFN
    hidden dims, expert axis, vocab — sharded iff the semantic unit count
    (heads / experts / vocab / d_ff) divides the axis size;
  * FSDP ("data"(+"pod") axes, training only): the largest not-yet-sharded
    dim of every ≥2D weight, iff divisible;
  * everything that fails divisibility falls back to replication and is
    recorded in the returned ``report`` (these show up in EXPERIMENTS.md —
    e.g. gemma's 8 q-heads on a 16-wide model axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any

# weights whose LAST dim is a tp-shardable "output feature" dim
_COL_PARALLEL = ("wq", "wk", "wv", "w_q", "w_k", "w_v", "w_gate", "w_up",
                 "w_uk", "w_uv", "w_z", "w_x", "conv_x_w", "w_ig", "w_fg")
# weights whose FIRST dim is the matching "input feature" dim (row-parallel)
_ROW_PARALLEL = ("wo", "w_down", "w_out")
_REPLICATE = ("router", "w_dkv", "w_kr", "w_b", "w_c", "w_dt", "conv_b_w",
              "conv_c_w")


@dataclasses.dataclass
class ShardingReport:
    """What got sharded how — and what fell back to replication."""
    tp_sharded: list[str] = dataclasses.field(default_factory=list)
    fsdp_sharded: list[str] = dataclasses.field(default_factory=list)
    replicated: list[str] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        return (f"tp={len(self.tp_sharded)} fsdp={len(self.fsdp_sharded)} "
                f"replicated={len(self.replicated)}")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _leaf_name(path) -> str:
    return str(getattr(path[-1], "key", path[-1]))


def _num_stack_dims(path) -> int:
    """Layer-stacked leaves live under 'layers'/'mamba'/'mlstm'/'slstm'/
    'enc_layers'/'dec_layers'; their leading dims are stack axes."""
    parts = [str(getattr(p, "key", "")) for p in path]
    if "mamba" in parts or "mlstm" in parts:
        return 2                      # [G, K, ...]
    if any(s in parts for s in ("layers", "enc_layers", "dec_layers",
                                "slstm")):
        return 1
    return 0


def partition_spec_for(path, shape: tuple[int, ...], cfg, *,
                       tp: int, fsdp: int, mode: str,
                       report: Optional[ShardingReport] = None):  # noqa: D401
    """PartitionSpec for one param leaf. mode: 'train' | 'serve'."""
    name = _leaf_name(path)
    nstack = _num_stack_dims(path)
    body = list(shape[nstack:])       # dims after layer-stack axes
    spec: list = [None] * len(shape)
    pstr = _path_str(path)

    def try_tp(dim_idx: int, unit: int) -> bool:
        """Shard body dim `dim_idx` on 'model' iff `unit` divides tp."""
        if tp > 1 and unit % tp == 0 and body[dim_idx] % tp == 0:
            spec[nstack + dim_idx] = "model"
            if report:
                report.tp_sharded.append(pstr)
            return True
        return False

    tp_ok = False
    if name == "embed":
        tp_ok = try_tp(0, shape[-2])                 # vocab rows
    elif name == "lm_head":
        tp_ok = try_tp(1, body[1])                   # vocab cols
    elif name in ("w_gate", "w_up", "w_down") and len(body) == 3:
        # MoE expert weights [E, d, ff]: expert parallelism
        if tp > 1 and cfg.num_experts and cfg.num_experts % tp == 0:
            spec[nstack] = "model"
            tp_ok = True
            if report:
                report.tp_sharded.append(pstr)
    elif name in ("wq", "w_q"):
        tp_ok = try_tp(1, cfg.num_heads)
    elif name in ("wk", "wv", "w_k", "w_v"):
        tp_ok = try_tp(1, cfg.num_kv_heads)
    elif name in ("w_uk", "w_uv"):                   # MLA up-proj [r, H*dn]
        tp_ok = try_tp(1, cfg.num_heads)
    elif name in ("w_z", "w_x", "conv_x_w", "w_ig", "w_fg"):
        # mamba/xlstm inner width: head-aligned
        unit = cfg.n_ssm_heads if cfg.family in ("hybrid",) else cfg.num_heads
        tp_ok = try_tp(len(body) - 1, unit)
    elif name in ("w_gate", "w_up"):                 # dense FFN [d, ff]
        tp_ok = try_tp(1, body[1])
    elif name in _ROW_PARALLEL and len(body) >= 2:
        if name == "w_down" and len(body) == 2:
            tp_ok = try_tp(0, body[0])
        elif name == "wo":
            tp_ok = try_tp(0, cfg.num_heads)
        elif name == "w_out":
            unit = cfg.n_ssm_heads if cfg.family in ("hybrid",) \
                else cfg.num_heads
            tp_ok = try_tp(0, unit)

    # §Perf D: row-parallel fallback for attention projections whose head
    # count does not divide the model axis (e.g. gemma's 8 q-heads on 16):
    # shard the CONTRACTION dim instead (partial sums -> psum), trading a
    # per-layer all-reduce for 16x less replicated matmul compute.
    import os
    if (os.environ.get("REPRO_ROWPAR_ATTN") and not tp_ok and tp > 1
            and name in ("wq", "wk", "wv", "wo") and len(body) == 2
            and body[0] % tp == 0):
        spec[nstack] = "model"
        tp_ok = True
        if report:
            report.tp_sharded.append(pstr + "(rowpar)")

    # FSDP (training only): largest remaining body dim of ≥2D weights
    if os.environ.get("REPRO_NO_FSDP"):
        fsdp = 1                      # §Perf experiment lever
    if mode == "train" and fsdp > 1 and len(body) >= 2:
        order = sorted(range(len(body)), key=lambda i: -body[i])
        for i in order:
            if spec[nstack + i] is None and body[i] % fsdp == 0:
                spec[nstack + i] = ("pod", "data") if fsdp > 16 else "data"
                if report:
                    report.fsdp_sharded.append(pstr)
                break

    if report and not tp_ok and all(s is None for s in spec):
        report.replicated.append(pstr)
    return P(*spec)


def param_specs(cfg, shapes: PyTree, mesh: Mesh, mode: str = "train",
                no_fsdp: bool = False):
    """PartitionSpec pytree for a param-shapes tree. Returns (specs, report)."""
    axis = dict(mesh.shape)
    tp = axis.get("model", 1)
    fsdp = 1 if no_fsdp else axis.get("data", 1) * axis.get("pod", 1)
    report = ShardingReport()
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    specs = [partition_spec_for(path, shape, cfg, tp=tp, fsdp=fsdp,
                                mode=mode, report=report)
             for path, shape in flat]
    return jax.tree_util.tree_unflatten(treedef, specs), report


def batch_specs(cfg, batch_shapes: PyTree, mesh: Mesh):
    """Shard the batch dim over ('pod','data') where divisible."""
    axis = dict(mesh.shape)
    dp = axis.get("data", 1) * axis.get("pod", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axis and axis[a] > 1)

    def spec(sd):
        shape = sd if isinstance(sd, tuple) else sd.shape
        if shape and shape[0] % dp == 0 and dp > 1:
            return P(dp_axes, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map(
        spec, batch_shapes, is_leaf=lambda x: isinstance(x, tuple))


_KV_LEAVES = ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
              "attn_k", "attn_v")
_LATENT_LEAVES = ("ckv", "kr")


def cache_specs(cfg, cache_shapes: PyTree, mesh: Mesh, *,
                seq_shard: bool = False):
    """Decode-cache sharding.

    Baseline policy (DESIGN.md §5):
      * batch dim over dp where divisible;
      * KV heads over 'model' where divisible, else the *sequence* dim over
        'model' (distributed-softmax decode; GSPMD inserts the lse
        reductions);
      * MLA latent caches shard sequence over 'model' (no head dim);
      * when the batch cannot shard (long_500k B=1), the sequence
        additionally shards over 'data' — flash-decode style.
    ``seq_shard=True`` forces sequence-over-'data' even when the batch is
    shardable (a §Perf experiment lever).
    """
    axis = dict(mesh.shape)
    dp = axis.get("data", 1) * axis.get("pod", 1)
    tp = axis.get("model", 1)
    data = axis.get("data", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axis and axis[a] > 1)

    def spec(path, sd):
        shape, _ = sd if isinstance(sd, tuple) else (sd.shape, None)
        name = _leaf_name(path)
        if name == "pos" or not shape:
            return P()
        s: list = [None] * len(shape)
        # leading layer-stack dims before the batch dim
        if name in _KV_LEAVES or name in _LATENT_LEAVES:
            nstack = 1
        elif name in ("ssm", "conv") or name.startswith("m_"):
            nstack = 2
        elif name.startswith("s_"):
            nstack = 1
        else:
            nstack = 0
        batch_ok = (nstack < len(shape) and dp > 1
                    and shape[nstack] % dp == 0)
        if batch_ok:
            s[nstack] = dp_axes
        if name in _KV_LEAVES and len(shape) >= 4:
            seq_dim, head_dim_idx = nstack + 1, len(shape) - 2
            if tp > 1 and shape[head_dim_idx] % tp == 0:
                s[head_dim_idx] = "model"
            elif tp > 1 and shape[seq_dim] % tp == 0:
                s[seq_dim] = "model"
            if (seq_shard or not batch_ok) and data > 1 \
                    and shape[seq_dim] % (data * tp) == 0:
                s[seq_dim] = (("model", "data") if s[seq_dim] == "model"
                              else "data" if s[seq_dim] is None
                              else s[seq_dim])
        elif name in _LATENT_LEAVES and len(shape) >= 3:
            seq_dim = nstack + 1
            if tp > 1 and shape[seq_dim] % tp == 0:
                s[seq_dim] = "model"
            if (seq_shard or not batch_ok) and data > 1 \
                    and shape[seq_dim] % (tp * data) == 0:
                # split the sequence over model×data jointly
                s[seq_dim] = ("model", "data") if tp > 1 else "data"
        elif name == "ssm" and tp > 1 and len(shape) > 3 \
                and shape[3] % tp == 0:
            s[3] = "model"
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        cache_shapes, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, sd) for p, sd in flat])


def to_named(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
