"""Architecture registry: the 10 assigned configs + input shapes.

``get_config(name)`` accepts the assigned arch ids (``--arch gemma-2b``);
``reduced_config(name)`` returns the CPU-smoke-test variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, ModelConfig, InputShape,
                                reduced_shape)

_MODULES = {
    "gemma-2b": "gemma_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "xlstm-350m": "xlstm_350m",
    "starcoder2-7b": "starcoder2_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "minitron-4b": "minitron_4b",
    "qwen3-1.7b": "qwen3_1_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-tiny": "whisper_tiny",
}

ARCH_NAMES = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def reduced_config(name: str) -> ModelConfig:
    return _module(name).reduced()


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


__all__ = [
    "ARCH_NAMES",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "all_configs",
    "get_config",
    "reduced_config",
    "reduced_shape",
]
