"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242].

38 Mamba2 layers (ssm_state=64); one *shared* transformer block (32H attn +
d_ff=8192 MLP) applied before every 6th Mamba2 layer (7 applications).
38 % 6 != 0 → the trailing group is padded with identity layers
(pad fraction reported by ``repro.models.hybrid.pad_fraction``).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,                 # shared block MLP
    vocab_size=32000,
    mlp_act="gelu",
    tie_embeddings=True,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    shared_attn_every=6,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-1.2b-reduced", num_layers=5, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512, ssm_state=16,
        shared_attn_every=2)
