"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed)
[hf:microsoft/Phi-3-vision-128k-instruct].

The vision encoder + projector is a STUB per the assignment: the backbone
consumes precomputed patch+token embeddings from ``input_specs``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_act="silu",
    tie_embeddings=False,
    takes_embeddings=True,
    num_image_tokens=576,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi-3-vision-4.2b-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512,
        num_image_tokens=16)
