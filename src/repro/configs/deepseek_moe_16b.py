"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6, GQA
[arXiv:2401.06066].

Deviation from the released checkpoint (noted in DESIGN.md): the real model's
first layer is dense; we keep all layers MoE for scan homogeneity.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=102400,
    mlp_act="silu",
    tie_embeddings=False,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-moe-16b-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, vocab_size=512,
        num_experts=4, num_shared_experts=1, top_k=2, moe_d_ff=128)
