"""minitron-4b [dense] — pruned Nemotron, squared-ReLU MLP [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    source="arXiv:2407.14679",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_act="relu2",           # Nemotron squared-ReLU (non-gated)
    tie_embeddings=False,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="minitron-4b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
