"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434].

Assignment-line note: the bracket text says "160 routed" (the V2-236B
figure); the structured spec says "MoE 64e top-6" which matches the actual
V2-Lite — we follow the structured spec (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,              # v_head_dim; qk = nope 128 + rope 64
    d_ff=0,
    vocab_size=102400,
    mlp_act="silu",
    tie_embeddings=False,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-lite-16b-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=32, vocab_size=512,
        kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
        num_experts=4, num_shared_experts=1, top_k=2, moe_d_ff=128)
