"""qwen3-1.7b [dense] — qk-norm, GQA kv=8 [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    mlp_act="silu",
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-1.7b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
