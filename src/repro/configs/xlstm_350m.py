"""xlstm-350m [ssm] — sLSTM + mLSTM blocks, no FFN [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,                    # per assignment: no FFN
    vocab_size=50304,
    tie_embeddings=True,
    use_rope=False,
    slstm_every=4,             # [m, m, m, s] × 6
    xlstm_proj_factor=2.0,
    # §Perf B3 (adopted): pinning inner activations model-replicated kills
    # a 6 GiB/layer all-gather GSPMD otherwise inserts (EXPERIMENTS.md §Perf)
    xlstm_pin_inner=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-350m-reduced", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, vocab_size=512)
