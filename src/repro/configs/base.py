"""Model configuration schema shared by all assigned architectures.

Every architecture in ``repro/configs/<id>.py`` instantiates :class:`ModelConfig`
with the exact assigned dimensions and provides a ``reduced()`` variant
(≤2 layers, d_model ≤ 512, ≤4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | ssm | hybrid | audio
    source: str                     # citation (arXiv id / model card)

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- MLP / norm flavour ---
    mlp_act: str = "silu"           # silu->SwiGLU, gelu->GeGLU, gelu_plain->MLP
    use_qk_norm: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # --- attention flavour ---
    attn_kind: str = "gqa"          # gqa | mla | none
    use_rope: bool = True
    rope_theta: float = 10000.0
    window: int = 0                 # 0 = full causal; >0 = sliding window

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mla_absorbed: bool = False      # decode-path weight absorption (§Perf)

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    moe_bf16_combine: bool = False  # bf16 expert-combine psum (§Perf)

    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_heads: int = 0              # number of SSD heads (derived if 0)

    # --- xLSTM ---
    slstm_every: int = 0            # every k-th layer is an sLSTM block
    xlstm_proj_factor: float = 2.0
    xlstm_pin_inner: bool = False   # pin inner acts model-replicated (§Perf)

    # --- hybrid (Zamba2) ---
    shared_attn_every: int = 0      # shared attn block before every k-th layer

    # --- encoder-decoder (Whisper) ---
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500             # conv-frontend output frames (stubbed)

    # --- VLM ---
    takes_embeddings: bool = False  # inputs are embeddings, not token ids
    num_image_tokens: int = 0

    # --- numerics ---
    dtype: str = "bfloat16"

    # --- distribution knobs (set by the launchers, not per-arch) ---
    seq_parallel: bool = False      # Megatron-SP residual stream (train)
    loss_chunk: int = 0             # sequence-chunked xent (0 = off)

    # ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(self.num_heads // max(self.num_kv_heads, 1), 1)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        # headdim 64 convention
        return max(self.d_inner // 64, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_window(self, window: int) -> "ModelConfig":
        """Sliding-window variant (used by dense archs for long_500k)."""
        return self.replace(window=window, name=f"{self.name}-swa{window}")


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch, mode) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def reduced_shape(shape: InputShape, seq_len: int = 64,
                  global_batch: int = 2) -> InputShape:
    return InputShape(f"{shape.name}-reduced", seq_len, global_batch, shape.mode)
