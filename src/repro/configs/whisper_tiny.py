"""whisper-tiny [audio] — enc-dec, conv/mel frontend stubbed [arXiv:2212.04356].

The encoder consumes precomputed frame embeddings [B, 1500, 384] from
``input_specs`` (the mel+conv frontend is the assignment's allowed stub).
Decoder positions are sinusoidal (deviation; see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,              # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_act="gelu_plain",
    tie_embeddings=True,
    use_rope=False,
    is_encoder_decoder=True,
    enc_layers=4,
    enc_seq=1500,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-tiny-reduced", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512, enc_layers=2,
        enc_seq=64)
