"""starcoder2-7b [dense] — GQA kv=4, RoPE, plain-GELU MLP [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    mlp_act="gelu_plain",
    tie_embeddings=False,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="starcoder2-7b-reduced", num_layers=2, d_model=288,
        num_heads=4, num_kv_heads=2, head_dim=72, d_ff=576, vocab_size=512)
