"""Loop-aware HLO cost extraction.

``compiled.cost_analysis()`` visits every instruction ONCE — while-loop
bodies (our scan-over-layers, flash-attention KV scans, SSD chunk scans)
are counted a single time, undercounting FLOPs by ~num_layers.  XLA:CPU
annotates loops with ``known_trip_count``, so we re-derive costs from the
optimized HLO text, multiplying each computation's cost by the product of
trip counts on its call path:

  * FLOPs: 2·prod(result)·prod(contracting dims) per ``dot`` (anywhere,
    including inside fusion bodies);
  * bytes: operand+result sizes of *top-level* instructions in non-fused
    computations (fusion internals stay in registers/VMEM — counting at
    fusion granularity approximates HBM buffer traffic).

Validated against 6·N·D analytics in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:{[\\"]*n[\\"]*:[\\"]*(\d+)')
_CALL_ATTR_RE = re.compile(
    r"(?:body|calls|to_apply|branch_computations)=\{?([%\w.,\- ]+)\}?")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")

_SKIP_BYTES_OPS = ("parameter(", "tuple(", "get-tuple-element(",
                   "constant(", "bitcast(", "after-all(", "iota(")


def _dims(dims_str: str) -> list[int]:
    return [int(d) for d in dims_str.split(",") if d]


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return _dims(m.group(2))


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes_: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    # (child_name, multiplier)
    children: list = dataclasses.field(default_factory=list)


def parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    shapes: dict[str, str] = {}
    fused_bodies: set[str] = set()
    entry: str | None = None

    for raw in hlo.splitlines():
        line = raw.strip()
        hdr = _COMP_HDR_RE.match(raw)
        if hdr and (raw.startswith("%") or raw.startswith("ENTRY")):
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            shapes = {}
            continue
        if raw.startswith("}"):
            cur = None                   # computation closed — no bleed
            continue
        if cur is None or not line or line.startswith("//"):
            continue
        if line == "}":
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # type is everything up to the op name: "<type> <op>(..."
        op_idx = rest.find("(")
        type_and_op = rest[:op_idx] if op_idx > 0 else rest
        parts = type_and_op.rsplit(" ", 1)
        type_str = parts[0] if len(parts) == 2 else type_and_op
        op_name = parts[1] if len(parts) == 2 else ""
        shapes[name] = type_str

        # ---- FLOPs: dot ops -------------------------------------------
        if op_name == "dot":
            res_dims = _first_shape_dims(type_str) or []
            res_elems = 1
            for d in res_dims:
                res_elems *= d
            ops_m = _OPERANDS_RE.search(rest[op_idx:])
            lhs_name = (ops_m.group(1).split(",")[0].strip()
                        if ops_m else "")
            lhs_dims = _first_shape_dims(shapes.get(lhs_name, "")) or []
            cd = _DOT_DIMS_RE.search(rest)
            k = 1
            if cd and lhs_dims:
                for i in _dims(cd.group(1)):
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
            cur.flops += 2.0 * res_elems * k

        # ---- collectives (result bytes, by kind; -start counted, -done
        # skipped so async pairs are not double-counted) --------------------
        if not op_name.endswith("-done"):
            for kind in _COLLECTIVES:
                if kind in op_name:
                    cur.coll[kind] += _shape_bytes(type_str)
                    break

        # ---- bytes: top-level buffer traffic ---------------------------
        if not any(s in rest for s in _SKIP_BYTES_OPS):
            b = _shape_bytes(type_str)
            ops_m = _OPERANDS_RE.search(rest[op_idx:]) if op_idx > 0 else None
            if ops_m:
                for operand in ops_m.group(1).split(","):
                    operand = operand.strip()
                    if operand.startswith("%") and operand in shapes:
                        b += _shape_bytes(shapes[operand])
            cur.bytes_ += b

        # ---- call graph --------------------------------------------------
        if " while(" in rest:
            trip = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = int(tm.group(1))
            bm = re.search(r"body=(%[\w.\-]+)", rest)
            cm = _COND_RE.search(rest)
            if bm:
                cur.children.append((bm.group(1), trip))
            if cm:
                cur.children.append((cm.group(1), trip))
        elif " fusion(" in rest:
            fm = re.search(r"calls=(%[\w.\-]+)", rest)
            if fm:
                fused_bodies.add(fm.group(1))
                cur.children.append((fm.group(1), 1))
        else:
            cm = _CALL_ATTR_RE.search(rest)
            if cm and ("call(" in rest or "conditional(" in rest
                       or "map(" in rest or "reduce(" in rest
                       or "scatter(" in rest or "sort(" in rest):
                for child in cm.group(1).split(","):
                    child = child.strip()
                    if child.startswith("%"):
                        cur.children.append((child, 1))

    # zero out bytes inside fusion bodies (they live in registers/VMEM)
    for fb in fused_bodies:
        if fb in comps:
            comps[fb].bytes_ = 0.0
    comps["__entry__"] = comps.get(entry, _Comp("__none__"))
    return comps


def corrected_costs(hlo: str) -> dict:
    """Loop-aware (flops, bytes, collectives) totals from optimized HLO."""
    comps = parse_computations(hlo)
    entry = comps["__entry__"]
    memo: dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        zero = (0.0, 0.0, {k: 0.0 for k in _COLLECTIVES})
        if name not in comps or depth > 50:
            return zero
        memo[name] = zero                # cycle guard
        c = comps[name]
        f, b = c.flops, c.bytes_
        coll = dict(c.coll)
        for child, mult in c.children:
            cf, cb, cc = total(child, depth + 1)
            f += mult * cf
            b += mult * cb
            for k in _COLLECTIVES:
                coll[k] += mult * cc[k]
        memo[name] = (f, b, coll)
        return memo[name]

    f, b, coll = total(entry.name)
    return {"flops": f, "bytes": b, "collectives": coll,
            "collective_bytes": float(sum(coll.values()))}
