"""repro.obs — zero-perturbation observability: tracing, metrics,
Perfetto/Prometheus export.

The profiling premise of the paper — capture per-run data, make
offloading decisions predictable — applied to our own stack: every
runtime subsystem (both sim engines, the queueing layer, the online
oracle, both serving engines) accepts an ``obs=`` tracer and emits

  * per-task lifecycle **spans** (``sojourn ⊃ queue_wait · service ·
    transfer``), one track per node/pool, stamped in virtual time
    inside ``repro.sim`` and wall time in ``repro.serve``;
  * **instant events** for the control plane: replans, split re-picks,
    pool saturation, Page–Hinkley drift triggers, oracle refits,
    registry publishes;
  * **metrics** via :class:`MetricsRegistry` — counters, gauges, and
    fixed-boundary histograms with a Prometheus text-exposition dump.

The hard contract is *zero perturbation*: the default
:data:`NULL_TRACER` no-ops every hook, and a live :class:`Tracer` only
observes values the engines already compute — no RNG draws, no float-
path changes — so traced runs are bit-for-bit identical to untraced
ones and every engine-equivalence pin holds with tracing on
(``tests/test_obs.py``).

Export: :func:`export_chrome` writes Chrome trace-event JSON loadable
in Perfetto; :func:`validate_chrome` is the span-pairing checker;
``Tracer.last(n)`` is the bounded flight recorder for post-mortems
(:func:`postmortem_dump` writes it out when an engine crashes).

The consumption layer lives one package down in :mod:`repro.obs.
analyze`: phase attribution and deadline-miss classification
(:func:`~repro.obs.analyze.attribute`), differential profiling
(:func:`~repro.obs.analyze.diff`), mergeable streaming quantiles
(:class:`QuantileSketch`, also a registry kind via
``MetricsRegistry.quantile``), and the ``regress`` CI gate
(``python -m repro.obs.analyze``).  See ``docs/observability.md``.
"""
from repro.obs.chrome import export_chrome, validate_chrome
from repro.obs.metrics import (LATENCY_BOUNDARIES, Counter, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.trace import (NULL_TRACER, InstantEvent, NullTracer,
                             SpanEvent, Tracer, postmortem_dump)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "SpanEvent", "InstantEvent",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "LATENCY_BOUNDARIES", "export_chrome", "validate_chrome",
    "postmortem_dump", "QuantileSketch",
]


def __getattr__(name):
    # QuantileSketch lives in the analyze layer above metrics; a lazy
    # attribute keeps `from repro.obs import QuantileSketch` working
    # without repro.obs importing its own consumption layer eagerly
    if name == "QuantileSketch":
        from repro.obs.analyze.sketch import QuantileSketch
        return QuantileSketch
    raise AttributeError(f"module {__name__!r} has no attribute "
                         f"{name!r}")
