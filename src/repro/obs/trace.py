"""Zero-perturbation tracing: per-task lifecycle spans, instant events,
and a bounded flight recorder.

A :class:`Tracer` collects two kinds of structured events:

``SpanEvent``
    a named interval on a *track* — in the simulators a track is one
    node (or pool) and spans are the task lifecycle
    (``sojourn ⊃ queue_wait · service · transfer``); in the serving
    engines a track is the engine and spans are prefill/decode phases.
``InstantEvent``
    a point event — replan, split re-pick, pool saturation,
    Page–Hinkley drift trigger, oracle refit, registry publish.

Timestamps are *whatever clock the caller lives on*: virtual seconds
inside :mod:`repro.sim` (the engines pass event-loop / slab times —
the tracer itself never reads a wall clock for them, keeping
``repro.sim`` DET002-clean), wall seconds in :mod:`repro.serve` and the
benchmarks (callers pass their already-measured ``perf_counter``
values).  The tracer only *observes* values the engines already
compute: it draws no RNG, touches no float path, and with the
:data:`NULL_TRACER` default every hook is a no-op — which is what makes
the traced and untraced runs bit-for-bit identical (pinned in
``tests/test_obs.py``).

Ingestion paths mirror :class:`repro.sim.telemetry.Telemetry`:

  * :meth:`Tracer.span` / :meth:`Tracer.instant` /
    :meth:`Tracer.task_spans` — the event loop's per-event path;
  * :meth:`Tracer.span_arrays` — the fleet engine's slab path: one call
    ingests parallel columns for a whole run's completions, deferred
    and only materialised into span objects on first read.

The last ``ring`` events (spans and instants interleaved in ingestion
order) are kept in a bounded flight-recorder deque — after a
deadline miss or a drift trigger, :meth:`Tracer.last` replays the
recent history for a post-mortem without holding the full trace.

Export: :meth:`Tracer.export_chrome` writes Chrome trace-event JSON
(loadable in Perfetto / ``chrome://tracing``) via
:mod:`repro.obs.chrome`.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

__all__ = ["SpanEvent", "InstantEvent", "NullTracer", "Tracer",
           "NULL_TRACER", "postmortem_dump"]


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One named interval ``[t0, t1]`` on ``(track, tid)``.

    ``track`` maps to a Chrome trace *process* (one per node / pool /
    engine), ``tid`` to a thread within it (one per task, so each
    task's lifecycle renders as its own row and B/E nesting is exact).
    """
    track: str
    tid: int
    name: str
    t0: float
    t1: float
    args: Optional[dict] = None

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass(frozen=True)
class InstantEvent:
    """One point event at ``ts`` on ``(track, tid)``."""
    track: str
    tid: int
    name: str
    ts: float
    args: Optional[dict] = None


class NullTracer:
    """The no-op tracer — the default for every ``obs=`` seam.

    Every hook returns immediately; hot paths additionally guard on
    :attr:`enabled` so that with tracing off not even the event's
    argument tuple is built.  Keeping the interface on a real class
    (rather than ``None`` checks at every call site) means
    instrumentation reads as straight-line code.
    """

    __slots__ = ()
    enabled = False

    def span(self, track: str, name: str, t0: float, t1: float, *,
             tid: int = 0, args: Optional[dict] = None) -> None:
        pass

    def instant(self, track: str, name: str, ts: float, *,
                tid: int = 0, args: Optional[dict] = None) -> None:
        pass

    def task_spans(self, track: str, tid: int, name: str,
                   arrived_s: float, started_s: float, finished_s: float,
                   *, transfer_s: float = 0.0,
                   args: Optional[dict] = None) -> None:
        pass

    def span_arrays(self, tracks, tids, names, arrived_s, started_s,
                    finished_s, *, transfer_s=None,
                    args_cols=None) -> None:
        pass

    def instant_arrays(self, track, name, ts, *, tid: int = 0,
                       args_cols=None) -> None:
        pass

    def last(self, n: int = 64) -> list:
        return []

    def export_chrome(self, path: str) -> None:
        raise ValueError(
            "cannot export a trace from the no-op tracer — pass "
            "obs=Tracer() to the run you want traced")


#: module-level singleton every ``obs=None`` seam resolves to
NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Collecting tracer (see module docstring for the event model).

    ``ring`` bounds the flight-recorder deque (most recent events, spans
    and instants interleaved in ingestion order).  The tracer is
    append-only and clock-agnostic: callers stamp every event
    themselves, so one class serves virtual-time simulation and
    wall-time serving alike.
    """

    __slots__ = ("spans", "instants", "_pending", "_ring")
    enabled = True

    def __init__(self, ring: int = 4096):
        self.spans: list[SpanEvent] = []
        self.instants: list[InstantEvent] = []
        self._pending: list[tuple] = []      # deferred column batches
        self._ring: deque = deque(maxlen=int(ring))

    # -- ingestion: per-event path ----------------------------------------
    def span(self, track: str, name: str, t0: float, t1: float, *,
             tid: int = 0, args: Optional[dict] = None) -> None:
        """Record one complete interval (callers know both endpoints —
        the sim emits lifecycle spans at the completion event, serving
        emits phase spans from already-measured wall times)."""
        if t1 < t0:
            raise ValueError(f"span {name!r} ends before it starts "
                             f"({t1} < {t0})")
        if self._pending:
            self._materialise()
        ev = SpanEvent(str(track), int(tid), str(name), float(t0),
                       float(t1), args)
        self.spans.append(ev)
        self._ring.append(ev)

    def instant(self, track: str, name: str, ts: float, *,
                tid: int = 0, args: Optional[dict] = None) -> None:
        if self._pending:
            self._materialise()
        ev = InstantEvent(str(track), int(tid), str(name), float(ts),
                          args)
        self.instants.append(ev)
        self._ring.append(ev)

    def task_spans(self, track: str, tid: int, name: str,
                   arrived_s: float, started_s: float, finished_s: float,
                   *, transfer_s: float = 0.0,
                   args: Optional[dict] = None) -> None:
        """One task's lifecycle as properly-nested spans on its own
        ``(track, tid)`` row::

            sojourn   [arrived, finished]
              queue_wait [arrived, started]          (omitted if 0)
              service    [started, finished - transfer]
              transfer   [finished - transfer, finished]  (omitted if 0)

        The ``sojourn`` span carries ``args`` (split, deadline, ...).
        """
        arrived_s = float(arrived_s)
        started_s = float(started_s)
        finished_s = float(finished_s)
        transfer_s = float(transfer_s)
        self.span(track, "sojourn", arrived_s, finished_s, tid=tid,
                  args={"task": name, **(args or {})})
        if started_s > arrived_s:
            self.span(track, "queue_wait", arrived_s, started_s, tid=tid)
        service_end = finished_s - transfer_s
        self.span(track, "service", started_s, service_end, tid=tid)
        if transfer_s > 0.0:
            self.span(track, "transfer", service_end, finished_s,
                      tid=tid)

    # -- ingestion: the fleet engine's slab path --------------------------
    def span_arrays(self, tracks: Sequence[str], tids, names,
                    arrived_s, started_s, finished_s, *,
                    transfer_s=None, args_cols=None) -> None:
        """Batched :meth:`task_spans`: parallel columns (all length n)
        for one slab of completed tasks, deferred — equivalent to n
        ``task_spans`` calls in column order, but the hot loop only pays
        one tuple append (mirrors ``Telemetry.complete_arrays``).

        ``args_cols`` maps arg key -> a parallel column stamped onto
        each row's ``sojourn`` span (``deadline_s``, ``split``, ...);
        ``None`` entries in a column mean "no such arg for this row"."""
        n = len(names)
        for label, col in (("tracks", tracks), ("tids", tids),
                           ("arrived_s", arrived_s),
                           ("started_s", started_s),
                           ("finished_s", finished_s)):
            if len(col) != n:
                raise ValueError(f"column {label} has {len(col)} rows, "
                                 f"expected {n}")
        if transfer_s is not None and len(transfer_s) != n:
            raise ValueError(f"column transfer_s has {len(transfer_s)} "
                             f"rows, expected {n}")
        for key, col in (args_cols or {}).items():
            if len(col) != n:
                raise ValueError(f"args column {key!r} has {len(col)} "
                                 f"rows, expected {n}")
        self._pending.append(("spans", list(tracks), tids, list(names),
                              arrived_s, started_s, finished_s,
                              transfer_s, args_cols))

    def instant_arrays(self, track: str, name: str, ts, *, tid: int = 0,
                       args_cols: Optional[dict] = None) -> None:
        """Batched :meth:`instant`: one deferred column append for a run
        of same-named instants (``ts`` is the timestamp column;
        ``args_cols`` maps arg key -> a parallel column).  Equivalent to
        ``len(ts)`` instant calls in column order."""
        n = len(ts)
        for key, col in (args_cols or {}).items():
            if len(col) != n:
                raise ValueError(f"args column {key!r} has {len(col)} "
                                 f"rows, expected {n}")
        self._pending.append(("instants", str(track), str(name), ts,
                              int(tid), args_cols))

    def _materialise(self) -> None:
        batches, self._pending = self._pending, []
        for batch in batches:
            if batch[0] == "spans":
                (_, tracks, tids, names, arrived, started, finished,
                 transfer, args_cols) = batch
                for k in range(len(names)):
                    args = None
                    if args_cols is not None:
                        args = {key: col[k].item()
                                if hasattr(col[k], "item") else col[k]
                                for key, col in args_cols.items()
                                if col[k] is not None}
                        args = args or None
                    self.task_spans(
                        tracks[k], int(tids[k]), names[k],
                        float(arrived[k]), float(started[k]),
                        float(finished[k]),
                        transfer_s=0.0 if transfer is None
                        else float(transfer[k]),
                        args=args)
            else:
                _, track, name, ts, tid, args_cols = batch
                for k in range(len(ts)):
                    self.instant(
                        track, name, float(ts[k]), tid=tid,
                        args=None if args_cols is None else
                        {key: col[k].item()
                         if hasattr(col[k], "item") else col[k]
                         for key, col in args_cols.items()})

    # -- reads ------------------------------------------------------------
    def __len__(self) -> int:
        n = len(self.spans) + len(self.instants)
        return n + sum(len(b[3]) for b in self._pending)

    def all_spans(self) -> list[SpanEvent]:
        if self._pending:
            self._materialise()
        return self.spans

    def all_instants(self) -> list[InstantEvent]:
        if self._pending:
            self._materialise()
        return self.instants

    def last(self, n: int = 64) -> list:
        """The flight recorder: the most recent ``min(n, ring)`` events
        in ingestion order — the post-mortem view after a deadline miss
        or drift trigger."""
        if self._pending:
            self._materialise()
        if n <= 0:
            return []
        buf = list(self._ring)
        return buf[-int(n):]

    # -- export -----------------------------------------------------------
    def export_chrome(self, path: str) -> dict:
        """Write the trace as Chrome trace-event JSON (Perfetto /
        ``chrome://tracing``); returns the trace object.  One Chrome
        *process* per track, one *thread* per tid; lifecycle spans emit
        matched B/E pairs with children nested inside parents."""
        from repro.obs.chrome import export_chrome
        return export_chrome(self, path)


def postmortem_dump(tracer, *, clock_s: float, error: str = "",
                    path: str = "results/postmortem.json",
                    n: int = 64) -> Optional[dict]:
    """Flight-recorder post-mortem: the last ``n`` traced events plus
    the crashing clock reading, written to ``path`` and summarised on
    stderr.  The engines call this from their crash handlers *before*
    re-raising — with the :data:`NULL_TRACER` (tracing off) it is a
    no-op, and any failure inside the dump itself is swallowed so a
    broken disk never masks the original exception.  Returns the dump
    dict (or None when disabled / failed)."""
    if not getattr(tracer, "enabled", False):
        return None
    try:
        import json
        import os
        import sys
        events = [{"kind": type(ev).__name__, **dataclasses.asdict(ev)}
                  for ev in tracer.last(n)]
        dump = {"clock_s": float(clock_s), "error": str(error),
                "n_events": len(events), "events": events}
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(dump, f, indent=1, default=float)
        print(f"[repro.obs] post-mortem: {len(events)} flight-recorder "
              f"events at t={clock_s:.6g}s -> {path}"
              + (f" ({error})" if error else ""), file=sys.stderr)
        return dump
    except Exception:
        return None
