"""Regression gating: compare fresh benchmark / telemetry rows against
a committed baseline with per-metric tolerance bands.

Both sides use the flat ``results/`` record schema the benchmarks and
``MetricsRegistry.to_rows()`` write: ``[{"name": ..., metric: value,
...}, ...]``.  Rows match by ``"name"``; within a matched row every
metric is checked by *direction*:

  * **lower-better** (``*_s``, ``*_ms``, ``us_per_*``, ``*_err``,
    ``nrmse``, ``miss*``, latency-ish names): flag when the fresh value
    exceeds ``base × (1 + tol)``;
  * **higher-better** (``*_per_s`` / ``*_per_sec``, ``speedup*``,
    ``throughput*``, ``*tokens*``): flag when the fresh value falls
    below ``base × (1 − tol)``;
  * **either** (unrecognised numerics — config scalars, counts): flag
    when the relative deviation exceeds ``tol`` in *any* direction;
  * strings / bools: must be equal (a changed backend tag or
    ``interpret`` flag is a config change, not noise).

Good-direction moves are reported as improvements, never failures.
A baseline value of exactly ``0`` makes relative bands meaningless, so
any bad-direction deviation there flags.

``python -m repro.obs.analyze regress BASE [FRESH]`` is the CI gate:
exit 0 clean, 1 on regression, 2 on usage/IO error.  ``--selftest``
proves the gate has teeth without fresh data: the baseline compared to
itself must pass, and a synthetically perturbed copy (each eligible
metric pushed past its band in the bad direction) must be flagged —
so CI can gate on committed wall-time baselines whose absolute numbers
are machine-dependent.
"""
from __future__ import annotations

import copy
import dataclasses
import json
from typing import Optional, Sequence

__all__ = ["MetricCheck", "RegressionReport", "compare_rows",
           "compare_files", "direction_of", "load_rows", "selftest"]

#: default relative tolerance band (wall-time benchmarks are noisy;
#: deterministic virtual-time metrics should override much tighter)
DEFAULT_TOL = 0.2

#: substrings marking higher-is-better metrics (checked FIRST:
#: ``decisions_per_s`` must not fall through to the ``_s`` rule)
_HIGHER = ("per_sec", "per_s", "speedup", "throughput", "tokens")

#: suffix / substring rules for lower-is-better metrics
_LOWER_SUFFIX = ("_s", "_ms", "_us", "_err", "_bytes")
_LOWER_SUB = ("us_per", "ms_per", "nrmse", "miss", "latency", "sojourn",
              "wait", "rel_err", "overhead")

#: metadata keys never compared
_SKIP = ("name",)


def direction_of(metric: str) -> str:
    """``"higher"`` / ``"lower"`` / ``"either"`` for a metric name."""
    m = metric.lower()
    if any(s in m for s in _HIGHER):
        return "higher"
    if m.endswith(_LOWER_SUFFIX) or any(s in m for s in _LOWER_SUB):
        return "lower"
    return "either"


@dataclasses.dataclass
class MetricCheck:
    """Outcome of one (row, metric) comparison."""
    row: str
    metric: str
    direction: str
    base: object
    fresh: object
    rel_delta: float          # (fresh − base) / |base|; 0 for strings
    tol: float
    status: str               # "ok" | "improved" | "regressed"

    def describe(self) -> str:
        if isinstance(self.base, str) or isinstance(self.base, bool):
            return (f"{self.row}.{self.metric}: {self.base!r} -> "
                    f"{self.fresh!r} ({self.status})")
        return (f"{self.row}.{self.metric} [{self.direction}]: "
                f"{self.base:.6g} -> {self.fresh:.6g} "
                f"({self.rel_delta:+.1%}, tol ±{self.tol:.0%}, "
                f"{self.status})")


@dataclasses.dataclass
class RegressionReport:
    """Everything the gate decided, machine- and human-readable."""
    checked: int
    regressions: list[MetricCheck]
    improvements: list[MetricCheck]
    missing_rows: list[str]     # in baseline, absent from fresh
    extra_rows: list[str]       # in fresh, absent from baseline

    @property
    def ok(self) -> bool:
        return not self.regressions and not self.missing_rows

    def to_dict(self) -> dict:
        return {
            "ok": self.ok, "checked": self.checked,
            "regressions": [dataclasses.asdict(c)
                            for c in self.regressions],
            "improvements": [dataclasses.asdict(c)
                             for c in self.improvements],
            "missing_rows": self.missing_rows,
            "extra_rows": self.extra_rows,
        }

    def table_str(self) -> str:
        lines = [f"== regression gate: "
                 f"{'PASS' if self.ok else 'FAIL'} "
                 f"({self.checked} metrics checked, "
                 f"{len(self.regressions)} regressed, "
                 f"{len(self.improvements)} improved) =="]
        for c in self.regressions:
            lines.append(f"  REGRESSED  {c.describe()}")
        for r in self.missing_rows:
            lines.append(f"  MISSING    row {r!r} absent from fresh run")
        for c in self.improvements:
            lines.append(f"  improved   {c.describe()}")
        for r in self.extra_rows:
            lines.append(f"  (new row {r!r} not in baseline)")
        return "\n".join(lines)


def _is_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check(row: str, metric: str, base, fresh, tol: float
           ) -> Optional[MetricCheck]:
    """Compare one metric; None when incomparable (missing / non-scalar
    on either side — new metrics in fresh rows are not regressions)."""
    if fresh is None or base is None:
        return None
    if isinstance(base, (str, bool)) or isinstance(fresh, (str, bool)):
        status = "ok" if base == fresh else "regressed"
        return MetricCheck(row, metric, "equal", base, fresh, 0.0,
                           0.0, status)
    if not (_is_number(base) and _is_number(fresh)):
        return None
    d = direction_of(metric)
    if base == 0:
        # no relative band at zero: any bad-direction move flags
        bad = (fresh > 0 if d == "lower" else
               fresh < 0 if d == "higher" else fresh != 0)
        good = (fresh < 0 if d == "lower" else
                fresh > 0 if d == "higher" else False)
        rel = float("inf") if fresh != 0 else 0.0
        status = "regressed" if bad else ("improved" if good else "ok")
        return MetricCheck(row, metric, d, base, fresh,
                           rel if fresh != 0 else 0.0, tol, status)
    rel = (fresh - base) / abs(base)
    if d == "lower":
        status = ("regressed" if rel > tol else
                  "improved" if rel < -tol else "ok")
    elif d == "higher":
        status = ("regressed" if rel < -tol else
                  "improved" if rel > tol else "ok")
    else:
        status = "regressed" if abs(rel) > tol else "ok"
    return MetricCheck(row, metric, d, float(base), float(fresh),
                       float(rel), tol, status)


def compare_rows(base_rows: Sequence[dict], fresh_rows: Sequence[dict],
                 *, default_tol: float = DEFAULT_TOL,
                 tol: Optional[dict] = None) -> RegressionReport:
    """Gate ``fresh_rows`` against ``base_rows``.

    ``tol`` maps metric names (or ``"row.metric"``, more specific wins)
    to per-metric relative tolerances overriding ``default_tol``.
    Baseline rows absent from the fresh run fail the gate; fresh rows
    absent from the baseline are reported but do not fail (new
    benchmarks land before their baselines do).
    """
    tol = tol or {}
    fresh_by = {r.get("name"): r for r in fresh_rows}
    checked = 0
    regs: list[MetricCheck] = []
    imps: list[MetricCheck] = []
    missing = []
    for row in base_rows:
        rname = row.get("name", "?")
        fresh = fresh_by.get(rname)
        if fresh is None:
            missing.append(rname)
            continue
        for metric, base_v in row.items():
            if metric in _SKIP:
                continue
            t = tol.get(f"{rname}.{metric}", tol.get(metric,
                                                     default_tol))
            c = _check(rname, metric, base_v, fresh.get(metric), t)
            if c is None:
                continue
            checked += 1
            if c.status == "regressed":
                regs.append(c)
            elif c.status == "improved":
                imps.append(c)
    base_names = {r.get("name") for r in base_rows}
    extra = [n for n in fresh_by if n not in base_names]
    return RegressionReport(checked=checked, regressions=regs,
                            improvements=imps, missing_rows=missing,
                            extra_rows=extra)


def load_rows(path: str) -> list[dict]:
    """Load a ``results/`` rows JSON; a bare dict wraps into one row."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        data = [{"name": data.get("name", "summary"), **data}]
    if not isinstance(data, list) or not all(
            isinstance(r, dict) for r in data):
        raise ValueError(f"{path}: expected a JSON list of row dicts")
    return data


def compare_files(base_path: str, fresh_path: str, *,
                  default_tol: float = DEFAULT_TOL,
                  tol: Optional[dict] = None) -> RegressionReport:
    return compare_rows(load_rows(base_path), load_rows(fresh_path),
                        default_tol=default_tol, tol=tol)


def selftest(base_rows: Sequence[dict], *,
             default_tol: float = DEFAULT_TOL,
             tol: Optional[dict] = None) -> tuple[bool, str]:
    """Prove the gate works on this baseline without fresh data:
    (1) baseline vs itself must pass with zero regressions; (2) a copy
    with every eligible numeric metric perturbed past its band in the
    bad direction must be flagged on every perturbed metric.  Returns
    ``(ok, report_text)``."""
    clean = compare_rows(base_rows, base_rows,
                         default_tol=default_tol, tol=tol)
    lines = ["-- selftest: baseline vs itself --", clean.table_str()]
    ok = clean.ok and not clean.regressions
    if not ok:
        lines.append("selftest FAIL: baseline does not match itself")
        return False, "\n".join(lines)
    tol = tol or {}
    perturbed = copy.deepcopy(list(base_rows))
    expected: set[tuple[str, str]] = set()
    for row in perturbed:
        rname = row.get("name", "?")
        for metric, v in list(row.items()):
            if metric in _SKIP or not _is_number(v) or v == 0:
                continue
            t = tol.get(f"{rname}.{metric}", tol.get(metric,
                                                     default_tol))
            d = direction_of(metric)
            factor = 1.0 + 3.0 * max(t, 1e-9)
            row[metric] = v * factor if d != "higher" else v / factor
            expected.add((rname, metric))
    dirty = compare_rows(base_rows, perturbed,
                         default_tol=default_tol, tol=tol)
    flagged = {(c.row, c.metric) for c in dirty.regressions}
    unflagged = sorted(expected - flagged)
    lines.append(f"-- selftest: perturbed copy — "
                 f"{len(flagged)}/{len(expected)} perturbations "
                 f"flagged --")
    if unflagged:
        ok = False
        for r, m in unflagged:
            lines.append(f"selftest FAIL: perturbed {r}.{m} "
                         f"not flagged")
    else:
        lines.append("selftest PASS")
    return ok, "\n".join(lines)
