"""Columnar trace tables: the analysis-side view of a traced run.

:class:`TraceTable` ingests a :class:`repro.obs.Tracer`, a validated
``trace.json`` (Chrome trace-event JSON, the format
:func:`repro.obs.export_chrome` writes), or a finished
:class:`repro.sim.telemetry.Telemetry` into parallel numpy columns —
one row per span and one per instant, in ingestion order.  Everything
downstream (attribution, diff, the miss classifier) reads these columns
instead of walking event objects.

:meth:`TraceTable.lifecycles` reconstructs the per-task lifecycle table
(:class:`TaskTable`): for every ``sojourn`` span it collects the nested
``queue_wait`` / ``service`` / ``transfer`` children on the same
``(track, tid)`` row and emits one columnar task row with the phase
durations, the residual (``sojourn − wait − service − transfer``,
~1e-15 by construction), and the ``deadline_s`` / ``split`` args the
engines stamp on the sojourn span.  Rows keep the sojourn spans'
ingestion order — the same completion order ``Telemetry`` records — so
aggregates computed from spans alone reproduce
``Telemetry.summary()`` exactly (pinned in
``tests/test_obs_analyze.py``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Union

import numpy as np

__all__ = ["TraceTable", "TaskTable", "load"]

#: the lifecycle phase names task_spans emits, in timeline order
PHASES = ("queue_wait", "service", "transfer")


@dataclasses.dataclass
class TaskTable:
    """One row per completed task lifecycle, columnar (see module
    docstring).  ``deadline_s`` is NaN and ``split`` is −1 where the
    trace carried none."""
    task: list[str]
    track: list[str]
    tid: np.ndarray
    arrived_s: np.ndarray
    started_s: np.ndarray
    finished_s: np.ndarray
    sojourn_s: np.ndarray
    queue_wait_s: np.ndarray
    service_s: np.ndarray
    transfer_s: np.ndarray
    residual_s: np.ndarray
    deadline_s: np.ndarray
    split: np.ndarray

    def __len__(self) -> int:
        return len(self.task)

    @property
    def missed(self) -> np.ndarray:
        """Boolean mask of deadline misses (False where no deadline)."""
        with np.errstate(invalid="ignore"):
            return np.where(np.isnan(self.deadline_s), False,
                            self.finished_s > self.deadline_s)

    def phase_matrix(self) -> np.ndarray:
        """``[n, 4]`` columns ``(queue_wait, service, transfer,
        residual)`` — rows sum to ``sojourn_s`` within float residue."""
        return np.stack([self.queue_wait_s, self.service_s,
                         self.transfer_s, self.residual_s], axis=1)


class TraceTable:
    """Columnar spans + instants for one traced run."""

    def __init__(self, *, span_track, span_tid, span_name, span_t0,
                 span_t1, span_args, inst_track, inst_tid, inst_name,
                 inst_ts, inst_args):
        self.span_track: list[str] = span_track
        self.span_tid = np.asarray(span_tid, np.int64)
        self.span_name: list[str] = span_name
        self.span_t0 = np.asarray(span_t0, np.float64)
        self.span_t1 = np.asarray(span_t1, np.float64)
        self.span_args: list[Optional[dict]] = span_args
        self.inst_track: list[str] = inst_track
        self.inst_tid = np.asarray(inst_tid, np.int64)
        self.inst_name: list[str] = inst_name
        self.inst_ts = np.asarray(inst_ts, np.float64)
        self.inst_args: list[Optional[dict]] = inst_args
        self._lifecycles: Optional[TaskTable] = None

    @property
    def n_spans(self) -> int:
        return len(self.span_name)

    @property
    def n_instants(self) -> int:
        return len(self.inst_name)

    # -- constructors -----------------------------------------------------
    @classmethod
    def from_tracer(cls, tracer) -> "TraceTable":
        """Ingest a live :class:`repro.obs.Tracer` (exact float
        endpoints — the path the equivalence pins use)."""
        spans = tracer.all_spans()
        instants = tracer.all_instants()
        return cls(
            span_track=[s.track for s in spans],
            span_tid=[s.tid for s in spans],
            span_name=[s.name for s in spans],
            span_t0=[s.t0 for s in spans],
            span_t1=[s.t1 for s in spans],
            span_args=[s.args for s in spans],
            inst_track=[i.track for i in instants],
            inst_tid=[i.tid for i in instants],
            inst_name=[i.name for i in instants],
            inst_ts=[i.ts for i in instants],
            inst_args=[i.args for i in instants])

    @classmethod
    def from_chrome(cls, trace: Union[str, dict, list]) -> "TraceTable":
        """Ingest an exported ``trace.json`` (path, trace dict, or
        traceEvents list).  The file is validated first
        (:func:`repro.obs.validate_chrome`) so malformed traces fail
        loudly, then B/E pairs re-pair LIFO per ``(pid, tid)``.
        Timestamps come back from the format's microseconds, so
        endpoints round-trip to ~1e-10 s — use :meth:`from_tracer` when
        exactness matters."""
        from repro.obs.chrome import validate_chrome
        validate_chrome(trace)
        if isinstance(trace, str):
            with open(trace) as f:
                trace = json.load(f)
        events = trace["traceEvents"] if isinstance(trace, dict) else trace
        track_of: dict[int, str] = {}
        for ev in events:
            if ev.get("ph") == "M" and ev.get("name") == "process_name":
                track_of[ev.get("pid", 0)] = ev["args"]["name"]
        s_track, s_tid, s_name, s_t0, s_t1, s_args = \
            [], [], [], [], [], []
        i_track, i_tid, i_name, i_ts, i_args = [], [], [], [], []
        stacks: dict[tuple, list] = {}
        for ev in events:
            ph = ev.get("ph")
            pid, tid = ev.get("pid", 0), ev.get("tid", 0)
            track = track_of.get(pid, str(pid))
            if ph == "B":
                stacks.setdefault((pid, tid), []).append(
                    (ev.get("name"), float(ev["ts"]) / 1e6,
                     ev.get("args")))
            elif ph == "E":
                name, t0, args = stacks[(pid, tid)].pop()
                s_track.append(track)
                s_tid.append(tid)
                s_name.append(name)
                s_t0.append(t0)
                s_t1.append(float(ev["ts"]) / 1e6)
                s_args.append(args)
            elif ph == "i":
                i_track.append(track)
                i_tid.append(tid)
                i_name.append(ev.get("name"))
                i_ts.append(float(ev["ts"]) / 1e6)
                i_args.append(ev.get("args"))
        return cls(span_track=s_track, span_tid=s_tid, span_name=s_name,
                   span_t0=s_t0, span_t1=s_t1, span_args=s_args,
                   inst_track=i_track, inst_tid=i_tid, inst_name=i_name,
                   inst_ts=i_ts, inst_args=i_args)

    @classmethod
    def from_telemetry(cls, telemetry) -> "TraceTable":
        """The rows → analyze bridge: build the lifecycle spans a
        tracer would have recorded from a finished
        :class:`~repro.sim.telemetry.Telemetry`'s task records — so
        attribution and diff work on runs that carried no tracer (no
        instants, so miss causes lose their corroboration column)."""
        s_track, s_tid, s_name, s_t0, s_t1, s_args = \
            [], [], [], [], [], []
        for rid, r in enumerate(telemetry.records):
            track = f"{r.node}@{r.node_id}" if r.node else "run"
            tid = r.node_id if r.node_id is not None else rid
            args = {"task": r.name}
            if r.split is not None:
                args["split"] = r.split
            if r.deadline_s is not None:
                args["deadline_s"] = r.deadline_s
            rows = [("sojourn", r.arrived_s, r.finished_s, args)]
            if r.started_s > r.arrived_s:
                rows.append(("queue_wait", r.arrived_s, r.started_s,
                             None))
            service_end = r.finished_s - r.transfer_s
            rows.append(("service", r.started_s, service_end, None))
            if r.transfer_s > 0.0:
                rows.append(("transfer", service_end, r.finished_s,
                             None))
            for name, t0, t1, a in rows:
                s_track.append(track)
                s_tid.append(rid)
                s_name.append(name)
                s_t0.append(t0)
                s_t1.append(t1)
                s_args.append(a)
        return cls(span_track=s_track, span_tid=s_tid, span_name=s_name,
                   span_t0=s_t0, span_t1=s_t1, span_args=s_args,
                   inst_track=[], inst_tid=[], inst_name=[], inst_ts=[],
                   inst_args=[])

    # -- the lifecycle table ----------------------------------------------
    def lifecycles(self) -> TaskTable:
        """The per-task lifecycle table (cached).  One row per
        ``sojourn`` span in ingestion order; ``queue_wait`` / ``service``
        / ``transfer`` children are matched by containment on the same
        ``(track, tid)`` row.  Spans that are not part of a task
        lifecycle (serving ``prefill``/``decode``, custom spans) are
        ignored."""
        if self._lifecycles is not None:
            return self._lifecycles
        children: dict[tuple, list[int]] = {}
        sojourns: list[int] = []
        for k, name in enumerate(self.span_name):
            key = (self.span_track[k], int(self.span_tid[k]))
            if name == "sojourn":
                sojourns.append(k)
            elif name in PHASES:
                children.setdefault(key, []).append(k)
        n = len(sojourns)
        task, track = [], []
        tid = np.zeros(n, np.int64)
        arrived = np.zeros(n)
        started = np.zeros(n)
        finished = np.zeros(n)
        wait = np.zeros(n)
        service = np.zeros(n)
        transfer = np.zeros(n)
        deadline = np.full(n, np.nan)
        split = np.full(n, -1, np.int64)
        for i, k in enumerate(sojourns):
            key = (self.span_track[k], int(self.span_tid[k]))
            t0, t1 = self.span_t0[k], self.span_t1[k]
            args = self.span_args[k] or {}
            task.append(str(args.get("task", f"tid{key[1]}")))
            track.append(key[0])
            tid[i] = key[1]
            arrived[i] = t0
            finished[i] = t1
            started[i] = t0                       # no queue_wait → 0
            if args.get("deadline_s") is not None:
                deadline[i] = float(args["deadline_s"])
            if args.get("split") is not None:
                split[i] = int(args["split"])
            for c in children.get(key, ()):
                if not (t0 <= self.span_t0[c]
                        and self.span_t1[c] <= t1):
                    continue
                dur = self.span_t1[c] - self.span_t0[c]
                name = self.span_name[c]
                if name == "queue_wait":
                    # duration is started − arrived, the exact float
                    # Telemetry's wait_s computes
                    wait[i] = dur
                elif name == "service":
                    service[i] = dur
                    started[i] = self.span_t0[c]
                else:
                    transfer[i] = dur
        sojourn = finished - arrived
        self._lifecycles = TaskTable(
            task=task, track=track, tid=tid, arrived_s=arrived,
            started_s=started, finished_s=finished, sojourn_s=sojourn,
            queue_wait_s=wait, service_s=service, transfer_s=transfer,
            residual_s=sojourn - wait - service - transfer,
            deadline_s=deadline, split=split)
        return self._lifecycles

    def instants_in(self, t0: float, t1: float,
                    names: Optional[tuple] = None) -> list[int]:
        """Indices of instants with ``t0 <= ts <= t1`` (optionally
        restricted to ``names``) — the cross-referencing window the
        miss classifier uses."""
        idx = np.flatnonzero((self.inst_ts >= t0) & (self.inst_ts <= t1))
        if names is not None:
            idx = [int(k) for k in idx if self.inst_name[k] in names]
        return [int(k) for k in idx]


def load(source) -> TraceTable:
    """Polymorphic entry point: a :class:`TraceTable` passes through; a
    :class:`repro.obs.Tracer` ingests exactly; a ``Telemetry`` takes
    the rows bridge; a path / trace dict / traceEvents list parses as
    Chrome trace JSON (validated first)."""
    if isinstance(source, TraceTable):
        return source
    if hasattr(source, "all_spans"):             # a Tracer
        return TraceTable.from_tracer(source)
    if hasattr(source, "records"):               # a Telemetry
        return TraceTable.from_telemetry(source)
    return TraceTable.from_chrome(source)
