import sys

from repro.obs.analyze.cli import main

sys.exit(main())
