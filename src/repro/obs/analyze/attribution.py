"""Phase attribution: where did each task's sojourn go, and why were
deadlines missed.

:func:`attribute` ingests any trace source (``Tracer`` / ``trace.json``
/ ``Telemetry``) and answers the question monitoring alone cannot:
**decomposition** — ``sojourn = queue_wait + service + transfer +
residual`` per task and aggregated per run (the per-run aggregates
reproduce ``Telemetry.summary()`` exactly from spans alone, pinned in
``tests/test_obs_analyze.py``); **critical path** — the gap-free
segment chain covering each task's lifecycle with its dominant phase;
and **miss attribution** — each deadline miss classified by dominant
cause, cross-referenced against the control-plane instants
(``pool_saturation`` / ``link_drift`` / ``ph_drift``) the engines
emitted in the same window.

The miss-cause taxonomy (deterministic, documented in
``docs/observability.md``):

``pool_contention``
    queue wait is the phase most inflated over its run median —
    corroborated when a ``pool_saturation`` or ``pool_wait`` instant
    fell inside the task's ``[arrived, finished]`` window.
``link_drift``
    transfer is the most inflated phase *and* a ``link_drift`` instant
    fell inside the window: bandwidth moved under the task.
``rtt_tail``
    transfer is the most inflated phase with no drift instant in the
    window — a heavy-tailed RTT draw, not a channel change
    (corroborated when the transfer exceeds the run's p90 transfer).
``service_underprediction``
    service is the most inflated phase: the placement-time ETC was
    wrong — corroborated when a ``ph_drift`` (Page–Hinkley) instant
    fell inside the window, i.e. the oracle saw it too.

Ties break toward ``queue_wait`` then ``transfer`` then ``service`` —
contention and the network are actionable (add capacity, re-pick the
split); underprediction is the residual explanation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.obs.analyze.tables import TaskTable, TraceTable, load

__all__ = ["RunAttribution", "attribute", "MISS_CAUSES"]

#: classifier output classes, in tie-break priority order
MISS_CAUSES = ("pool_contention", "link_drift", "rtt_tail",
               "service_underprediction")

#: phase columns the classifier ranks, mapped to their cause families
_PHASE_ORDER = ("queue_wait", "transfer", "service")


@dataclasses.dataclass
class RunAttribution:
    """Attribution results for one run: the lifecycle table plus the
    trace it came from (instants feed the miss classifier)."""

    table: TraceTable
    tasks: TaskTable

    # -- per-run ----------------------------------------------------------
    def summary(self) -> dict:
        """Run-level aggregates recomputed *from spans alone* — the
        keys shared with ``Telemetry.summary()`` (``p50/p99/
        mean_completion_s``, ``p90_completion_s``, ``p99/mean_wait_s``,
        ``n_tasks``, ``deadline_misses``, ``miss_rate``) are float-exact
        equal to it on a traced run, because the spans carry the same
        values in the same completion order."""
        t = self.tasks
        soj, waits = t.sojourn_s, t.queue_wait_s
        n = len(t)
        misses = int(t.missed.sum())
        out = {
            "n_tasks": n,
            "p50_completion_s": float(np.percentile(soj, 50)) if n else 0.0,
            "p90_completion_s": float(np.percentile(soj, 90)) if n else 0.0,
            "p99_completion_s": float(np.percentile(soj, 99)) if n else 0.0,
            "mean_completion_s": float(soj.mean()) if n else 0.0,
            "p99_wait_s": float(np.percentile(waits, 99)) if n else 0.0,
            "mean_wait_s": float(waits.mean()) if n else 0.0,
            "deadline_misses": misses,
            "miss_rate": misses / n if n else 0.0,
        }
        out.update({f"total_{k}_s": v for k, v in
                    self.phase_totals().items()})
        return out

    def phase_totals(self) -> dict[str, float]:
        """Total seconds per phase across the run — the pie chart of
        where sojourn went.  Keys: ``queue_wait``, ``service``,
        ``transfer``, ``residual``, ``sojourn``."""
        t = self.tasks
        return {
            "queue_wait": float(t.queue_wait_s.sum()),
            "service": float(t.service_s.sum()),
            "transfer": float(t.transfer_s.sum()),
            "residual": float(t.residual_s.sum()),
            "sojourn": float(t.sojourn_s.sum()),
        }

    def phase_shares(self) -> dict[str, float]:
        """Phase totals as fractions of total sojourn."""
        totals = self.phase_totals()
        denom = totals["sojourn"] or 1.0
        return {k: v / denom for k, v in totals.items()
                if k != "sojourn"}

    def by_track(self) -> dict[str, dict[str, float]]:
        """Phase totals per track (per node/pool): which node the
        queueing actually accrued on."""
        t = self.tasks
        out: dict[str, dict[str, float]] = {}
        for i, track in enumerate(t.track):
            d = out.setdefault(track, {"queue_wait": 0.0, "service": 0.0,
                                       "transfer": 0.0, "n_tasks": 0})
            d["queue_wait"] += float(t.queue_wait_s[i])
            d["service"] += float(t.service_s[i])
            d["transfer"] += float(t.transfer_s[i])
            d["n_tasks"] += 1
        return out

    # -- per-task ---------------------------------------------------------
    def critical_path(self, i: int) -> list[tuple[str, float, float]]:
        """Task ``i``'s lifecycle as the ordered gap-free segment chain
        ``(phase, duration_s, fraction_of_sojourn)`` — queue_wait,
        service, transfer (zero-length phases omitted), with any float
        residue folded into a trailing ``residual`` segment.  The
        chain IS the critical path of a single-task lifecycle: every
        segment delays completion one-for-one."""
        t = self.tasks
        soj = float(t.sojourn_s[i]) or 1.0
        segs = [("queue_wait", float(t.queue_wait_s[i])),
                ("service", float(t.service_s[i])),
                ("transfer", float(t.transfer_s[i]))]
        out = [(name, d, d / soj) for name, d in segs if d > 0.0]
        res = float(t.residual_s[i])
        if abs(res) > 1e-12 * max(soj, 1.0):
            out.append(("residual", res, res / soj))
        return out

    def dominant_phase(self, i: int) -> str:
        """The phase that ate most of task ``i``'s sojourn."""
        path = self.critical_path(i)
        return max(path, key=lambda seg: seg[1])[0] if path else "service"

    def per_task(self) -> list[dict]:
        """One plain-dict breakdown per task (reports / JSON export)."""
        t = self.tasks
        return [{
            "task": t.task[i], "track": t.track[i], "tid": int(t.tid[i]),
            "arrived_s": float(t.arrived_s[i]),
            "finished_s": float(t.finished_s[i]),
            "sojourn_s": float(t.sojourn_s[i]),
            "queue_wait_s": float(t.queue_wait_s[i]),
            "service_s": float(t.service_s[i]),
            "transfer_s": float(t.transfer_s[i]),
            "dominant_phase": self.dominant_phase(i),
            "missed": bool(t.missed[i]),
        } for i in range(len(t))]

    # -- miss attribution -------------------------------------------------
    def miss_attribution(self) -> dict:
        """Classify every deadline miss by dominant cause (taxonomy in
        the module docstring).  Returns ``{"n_tasks", "n_misses",
        "miss_rate", "by_cause": {cause: count}, "misses": [...]}``
        with one record per miss carrying the cause, the corroborating
        instant evidence, and the phase breakdown."""
        t = self.tasks
        n = len(t)
        missed = np.flatnonzero(t.missed)
        med = {
            "queue_wait": float(np.median(t.queue_wait_s)) if n else 0.0,
            "service": float(np.median(t.service_s)) if n else 0.0,
            "transfer": float(np.median(t.transfer_s)) if n else 0.0,
        }
        p90_transfer = float(np.percentile(t.transfer_s, 90)) if n else 0.0
        phase_cols = {"queue_wait": t.queue_wait_s,
                      "service": t.service_s, "transfer": t.transfer_s}
        by_cause = {c: 0 for c in MISS_CAUSES}
        misses = []
        for i in missed:
            i = int(i)
            window = (float(t.arrived_s[i]), float(t.finished_s[i]))
            names_in = {self.table.inst_name[k] for k in
                        self.table.instants_in(*window)}
            # inflation of each phase over its run-wide median; ties
            # resolve in _PHASE_ORDER priority (max is stable on order)
            inflation = {p: float(phase_cols[p][i]) - med[p]
                         for p in _PHASE_ORDER}
            dominant = max(_PHASE_ORDER, key=lambda p: inflation[p])
            if dominant == "queue_wait":
                cause = "pool_contention"
                evidence = sorted(names_in
                                  & {"pool_saturation", "pool_wait"})
            elif dominant == "transfer":
                if "link_drift" in names_in:
                    cause = "link_drift"
                    evidence = ["link_drift"]
                else:
                    cause = "rtt_tail"
                    evidence = (["transfer>p90"] if
                                float(t.transfer_s[i]) > p90_transfer
                                else [])
            else:
                cause = "service_underprediction"
                evidence = sorted(names_in & {"ph_drift", "oracle_refit"})
            by_cause[cause] += 1
            misses.append({
                "task": t.task[i], "track": t.track[i],
                "tid": int(t.tid[i]),
                "deadline_s": float(t.deadline_s[i]),
                "finished_s": float(t.finished_s[i]),
                "excess_s": float(t.finished_s[i] - t.deadline_s[i]),
                "cause": cause,
                "dominant_phase": dominant,
                "corroborated": bool(evidence),
                "evidence": evidence,
                "phases": {p: float(phase_cols[p][i])
                           for p in _PHASE_ORDER},
            })
        return {"n_tasks": n, "n_misses": len(misses),
                "miss_rate": len(misses) / n if n else 0.0,
                "by_cause": by_cause, "misses": misses}

    # -- report -----------------------------------------------------------
    def table_str(self) -> str:
        """Human-readable attribution report (CLI / examples)."""
        s = self.summary()
        shares = self.phase_shares()
        lines = ["== run attribution =="]
        lines += [f"  {k:>20}: {v:.6g}" if isinstance(v, float)
                  else f"  {k:>20}: {v}" for k, v in s.items()]
        lines.append("  -- sojourn breakdown (share of total) --")
        lines += [f"  {k:>20}: {100 * v:6.2f}%"
                  for k, v in shares.items()]
        ma = self.miss_attribution()
        if ma["n_misses"]:
            lines.append("  -- deadline-miss attribution --")
            for cause, cnt in ma["by_cause"].items():
                if cnt:
                    lines.append(f"  {cause:>24}: {cnt}")
        return "\n".join(lines)


def attribute(source) -> RunAttribution:
    """Attribution entry point: accepts a ``Tracer``, a ``Telemetry``,
    a ``trace.json`` path / dict / event list, or a prebuilt
    :class:`TraceTable`."""
    table = load(source)
    return RunAttribution(table=table, tasks=table.lifecycles())
