"""Mergeable fixed-centroid streaming quantile sketch.

The serving plane needs *live* rolling p50/p99 sojourn without storing
every sample — the seam the ROADMAP's load-shedding and autoscaling
items read.  :class:`QuantileSketch` is a bounded-memory streaming
histogram in the Ben-Haim–Tom-Tov style (P²'s fixed-marker idea
generalised to many markers): it keeps at most ``max_centroids``
weighted centroids ``(value, weight)``; a new observation lands as a
weight-1 centroid, and when the budget overflows the two *closest*
adjacent centroids merge into their weighted mean.  Closest-gap merging
collapses dense regions first, so sparse tails keep near-singleton
centroids — which is what makes tail quantiles (p99) accurate at a few
hundred centroids.

Properties the tests pin (``tests/test_obs_analyze.py``):

  * **bounded**: never more than ``max_centroids`` centroids, O(1)
    memory regardless of stream length;
  * **accurate**: p99 within 2% relative error of the exact
    ``np.percentile`` on ≥10⁴-sample streams (uniform / lognormal /
    exponential mixes);
  * **mergeable**: ``merge(other)`` folds another sketch in —
    ``sketch(a) ⊕ sketch(b) ≈ sketch(a ++ b)`` — the multi-replica
    roll-up the serving tier needs;
  * **exact when small**: with fewer observations than centroids the
    sketch holds every sample and quantiles interpolate the exact
    order statistics.

The class doubles as a :class:`repro.obs.MetricsRegistry` metric kind
(``kind = "summary"``): ``MetricsRegistry.quantile(name)`` registers
one, and it renders in the Prometheus text exposition as a summary
series (``name{quantile="0.99"} ...`` plus ``_sum``/``_count``).
Only numpy is used; no third-party deps.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["QuantileSketch", "DEFAULT_QUANTILES"]

#: quantiles exposed in the Prometheus summary series
DEFAULT_QUANTILES = (0.5, 0.9, 0.99)

#: how many raw samples to buffer before a compaction pass — bounds the
#: per-compaction cost while amortising the argmin loop over many inserts
_CHUNK = 2048


class QuantileSketch:
    """Fixed-budget mergeable quantile sketch (see module docstring).

    ``max_centroids`` trades memory for accuracy: 128 centroids hold
    p99 of 10⁴-sample latency streams within ~1% in practice (2% is the
    tested bound).  ``quantiles`` only selects which points the
    Prometheus exposition prints; :meth:`quantile` answers any q.
    """

    kind = "summary"

    def __init__(self, name: str = "", *, max_centroids: int = 128,
                 quantiles: Sequence[float] = DEFAULT_QUANTILES,
                 help: str = ""):
        if max_centroids < 8:
            raise ValueError(f"max_centroids must be >= 8, got "
                             f"{max_centroids}")
        self.name = name
        self.help = help
        self.max_centroids = int(max_centroids)
        self.quantiles = tuple(float(q) for q in quantiles)
        self._v = np.empty(0, np.float64)        # centroid values, sorted
        self._w = np.empty(0, np.float64)        # centroid weights
        self._buf: list[np.ndarray] = []         # uncompacted raw samples
        self._buffered = 0
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    # -- ingestion --------------------------------------------------------
    def observe(self, v: float) -> None:
        self.observe_many([v])

    def observe_many(self, values) -> None:
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        if not np.isfinite(v).all():
            raise ValueError(f"sketch {self.name or '<anon>'}: "
                             f"non-finite observation")
        self.count += int(v.size)
        self.sum += float(v.sum())
        self.min = min(self.min, float(v.min()))
        self.max = max(self.max, float(v.max()))
        self._buf.append(v)
        self._buffered += int(v.size)
        if self._buffered >= _CHUNK:
            self._compact()

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into this sketch (the multi-replica roll-up).
        Centroid budgets need not match; this sketch keeps its own."""
        if other.count == 0:
            return self
        other._compact()
        self._compact()
        self._v = np.concatenate([self._v, other._v])
        self._w = np.concatenate([self._w, other._w])
        order = np.argsort(self._v, kind="stable")
        self._v, self._w = self._v[order], self._w[order]
        self._shrink()
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    # -- compaction -------------------------------------------------------
    def _compact(self) -> None:
        if not self._buf:
            return
        fresh = np.concatenate(self._buf)
        self._buf, self._buffered = [], 0
        self._v = np.concatenate([self._v, fresh])
        self._w = np.concatenate([self._w, np.ones(fresh.size)])
        order = np.argsort(self._v, kind="stable")
        self._v, self._w = self._v[order], self._w[order]
        self._shrink()

    def _shrink(self) -> None:
        """Merge closest-gap adjacent centroid pairs until the budget
        holds.  One pair per step keeps the estimator monotone; dense
        regions collapse first, sparse tails survive as singletons."""
        v, w = self._v, self._w
        while v.size > self.max_centroids:
            gaps = np.diff(v)
            k = int(np.argmin(gaps))
            wm = w[k] + w[k + 1]
            vm = (v[k] * w[k] + v[k + 1] * w[k + 1]) / wm
            v = np.concatenate([v[:k], [vm], v[k + 2:]])
            w = np.concatenate([w[:k], [wm], w[k + 2:]])
        self._v, self._w = v, w

    @property
    def n_centroids(self) -> int:
        self._compact()
        return int(self._v.size)

    # -- queries ----------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Estimate the q-quantile.  Centroids are treated as mass
        points at their mean with cumulative rank ``W_{<i} + w_i/2``
        (the Ben-Haim–Tom-Tov sum rule); the answer linearly
        interpolates between bracketing centroids, clamped to the exact
        observed ``[min, max]``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        self._compact()
        if self.count == 0:
            return 0.0
        v, w = self._v, self._w
        if v.size == 1:
            return float(v[0])
        # centroid i sits at cumulative rank (fraction of total mass)
        ranks = (np.cumsum(w) - 0.5 * w) / self.count
        t = q
        if t <= ranks[0]:
            # below the first centroid: interpolate from the exact min
            f = t / ranks[0] if ranks[0] > 0 else 1.0
            return float(self.min + f * (v[0] - self.min))
        if t >= ranks[-1]:
            span = 1.0 - ranks[-1]
            f = (t - ranks[-1]) / span if span > 0 else 1.0
            return float(v[-1] + f * (self.max - v[-1]))
        k = int(np.searchsorted(ranks, t, side="right")) - 1
        f = (t - ranks[k]) / (ranks[k + 1] - ranks[k])
        return float(v[k] + f * (v[k + 1] - v[k]))

    def quantiles_dict(self) -> dict[str, float]:
        """The exposed quantile points as ``{"0.5": ..., ...}``."""
        return {repr(q).rstrip("0").rstrip(".") or "0": self.quantile(q)
                for q in self.quantiles}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def __len__(self) -> int:
        return self.count

    # -- Prometheus metric-kind surface -----------------------------------
    def expose(self) -> list[str]:
        """Prometheus summary series: one sample per exposed quantile
        plus ``_sum`` / ``_count`` (matches the text-exposition format
        :meth:`repro.obs.MetricsRegistry.to_prometheus` renders)."""
        from repro.obs.metrics import _fmt
        out = []
        for q in self.quantiles:
            label = repr(float(q))
            out.append(f'{self.name}{{quantile="{label}"}} '
                       f"{_fmt(self.quantile(q))}")
        out.append(f"{self.name}_sum {_fmt(self.sum)}")
        out.append(f"{self.name}_count {self.count}")
        return out

    def to_row(self, prefix: str = "") -> dict:
        """One ``results/``-schema record row for this sketch."""
        self._compact()
        return {
            "name": f"{prefix}quantiles_{self.name}" if prefix or self.name
            else "quantiles",
            "quantiles": {str(q): self.quantile(q)
                          for q in self.quantiles},
            "count": self.count, "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "n_centroids": int(self._v.size),
        }
