"""``python -m repro.obs.analyze`` — trace analytics from the shell.

Three subcommands, mirroring the library entry points:

``attribution TRACE [--json OUT] [--misses]``
    Phase attribution + deadline-miss report for one exported
    ``trace.json``.

``diff TRACE_A TRACE_B [--align task|arrival] [--top-k N] [--json OUT]``
    Differential profile of run B against baseline A.

``regress BASE [FRESH] [--tol T] [--tol-metric NAME=T ...]
[--selftest] [--json OUT]``
    Regression gate: exit 0 clean, **1 on regression** (the CI
    contract), 2 on usage/IO error.  ``--selftest`` needs no FRESH:
    the baseline must pass against itself and a perturbed copy must be
    flagged.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

__all__ = ["main"]


def _dump(obj: dict, path: Optional[str]) -> None:
    if path:
        with open(path, "w") as f:
            json.dump(obj, f, indent=1, default=float)
        print(f"wrote {path}")


def _cmd_attribution(ns: argparse.Namespace) -> int:
    from repro.obs.analyze.attribution import attribute
    run = attribute(ns.trace)
    print(run.table_str())
    ma = run.miss_attribution()
    if ns.misses and ma["misses"]:
        print("  -- per-miss detail --")
        for m in ma["misses"]:
            ev = ",".join(m["evidence"]) or "-"
            print(f"  {m['task']:>14} on {m['track']:>10}: "
                  f"{m['cause']} (+{m['excess_s']:.4g}s past deadline, "
                  f"evidence: {ev})")
    _dump({"summary": run.summary(), "phase_shares": run.phase_shares(),
           "by_track": run.by_track(), "miss_attribution": ma},
          ns.json)
    return 0


def _cmd_diff(ns: argparse.Namespace) -> int:
    from repro.obs.analyze.diff import diff
    rep = diff(ns.trace_a, ns.trace_b, align=ns.align, top_k=ns.top_k)
    print(rep.table_str())
    _dump(rep.to_dict(), ns.json)
    return 0


def _parse_tols(specs: Sequence[str]) -> dict:
    out = {}
    for spec in specs:
        name, _, val = spec.partition("=")
        if not name or not val:
            raise ValueError(f"--tol-metric wants NAME=TOL, got "
                             f"{spec!r}")
        out[name] = float(val)
    return out


def _cmd_regress(ns: argparse.Namespace) -> int:
    from repro.obs.analyze.regress import (compare_rows, load_rows,
                                           selftest)
    tols = _parse_tols(ns.tol_metric)
    base = load_rows(ns.base)
    if ns.selftest:
        ok, text = selftest(base, default_tol=ns.tol, tol=tols)
        print(text)
        return 0 if ok else 1
    if not ns.fresh:
        raise ValueError("regress needs FRESH (or --selftest)")
    rep = compare_rows(base, load_rows(ns.fresh),
                       default_tol=ns.tol, tol=tols)
    print(rep.table_str())
    _dump(rep.to_dict(), ns.json)
    return 0 if rep.ok else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.analyze",
        description="trace analytics: attribution, diff, regression "
                    "gate")
    sub = p.add_subparsers(dest="cmd", required=True)

    pa = sub.add_parser("attribution",
                        help="phase + deadline-miss attribution")
    pa.add_argument("trace", help="exported trace.json")
    pa.add_argument("--json", default=None, help="write report JSON")
    pa.add_argument("--misses", action="store_true",
                    help="print per-miss detail lines")
    pa.set_defaults(fn=_cmd_attribution)

    pd = sub.add_parser("diff", help="differential profile B vs A")
    pd.add_argument("trace_a")
    pd.add_argument("trace_b")
    pd.add_argument("--align", choices=("task", "arrival"),
                    default="task")
    pd.add_argument("--top-k", type=int, default=10)
    pd.add_argument("--json", default=None)
    pd.set_defaults(fn=_cmd_diff)

    pr = sub.add_parser("regress",
                        help="regression gate (exit 1 on regression)")
    pr.add_argument("base", help="committed baseline rows JSON")
    pr.add_argument("fresh", nargs="?", default=None,
                    help="fresh rows JSON (omit with --selftest)")
    pr.add_argument("--tol", type=float, default=0.2,
                    help="default relative tolerance band")
    pr.add_argument("--tol-metric", action="append", default=[],
                    metavar="NAME=TOL",
                    help="per-metric override (repeatable; "
                         "'row.metric=T' is most specific)")
    pr.add_argument("--selftest", action="store_true",
                    help="gate the baseline against itself + a "
                         "perturbed copy")
    pr.add_argument("--json", default=None)
    pr.set_defaults(fn=_cmd_regress)

    ns = p.parse_args(argv)
    try:
        return ns.fn(ns)
    except (OSError, ValueError, KeyError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
