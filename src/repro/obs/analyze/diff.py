"""Differential profiling: what moved between two traced runs.

:func:`diff` aligns two runs — by task id (name) or by arrival order —
and reports, per lifecycle phase (``sojourn``, ``queue_wait``,
``service``, ``transfer``):

  * mean / p50 / p90 / p99 deltas (run B − run A, positive = B slower);
  * the two-sample Kolmogorov–Smirnov statistic (max ECDF distance, no
    scipy) as a scale-free distribution-shift score;
  * the top-k *regressed* tasks by sojourn delta, each with its phase
    breakdown — the "which requests got slower and where" view.

This is the comparison seam for ``engine="event"`` vs ``"fleet"``
(identical seeds must diff to all-zero — pinned), ``backend=`` choices,
mean vs tail-aware cost models, and canary predictor versions.
``diff(run, run)`` is identically zero: every delta ``0.0``, every K-S
statistic ``0.0``, no unmatched tasks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.obs.analyze.tables import TaskTable, load

__all__ = ["DiffReport", "PhaseDiff", "diff", "ks_statistic"]

#: the distributions compared, in report order
DIFF_PHASES = ("sojourn", "queue_wait", "service", "transfer")


def ks_statistic(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample Kolmogorov–Smirnov statistic ``sup |F_a − F_b|``
    (statistic only — no p-value, no scipy).  Exactly ``0.0`` for
    identical samples."""
    a = np.sort(np.asarray(a, np.float64))
    b = np.sort(np.asarray(b, np.float64))
    if a.size == 0 or b.size == 0:
        return 0.0 if a.size == b.size else 1.0
    grid = np.concatenate([a, b])
    cdf_a = np.searchsorted(a, grid, side="right") / a.size
    cdf_b = np.searchsorted(b, grid, side="right") / b.size
    return float(np.abs(cdf_a - cdf_b).max())


@dataclasses.dataclass
class PhaseDiff:
    """Distribution comparison for one phase (B − A deltas)."""
    phase: str
    mean_a: float
    mean_b: float
    mean_delta: float
    p50_delta: float
    p90_delta: float
    p99_delta: float
    ks: float

    @property
    def is_zero(self) -> bool:
        return (self.mean_delta == 0.0 and self.p50_delta == 0.0
                and self.p90_delta == 0.0 and self.p99_delta == 0.0
                and self.ks == 0.0)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class DiffReport:
    """Full differential-profiling report for two runs."""
    phases: dict[str, PhaseDiff]
    n_a: int
    n_b: int
    matched: int
    only_a: int
    only_b: int
    align: str
    top_regressions: list[dict]

    @property
    def is_zero(self) -> bool:
        """True iff nothing moved: all phase deltas and K-S statistics
        are exactly zero and every task matched."""
        return (self.only_a == 0 and self.only_b == 0
                and all(p.is_zero for p in self.phases.values())
                and all(r["sojourn_delta_s"] == 0.0
                        for r in self.top_regressions))

    def to_dict(self) -> dict:
        return {
            "align": self.align, "n_a": self.n_a, "n_b": self.n_b,
            "matched": self.matched, "only_a": self.only_a,
            "only_b": self.only_b, "is_zero": self.is_zero,
            "phases": {k: p.to_dict() for k, p in self.phases.items()},
            "top_regressions": self.top_regressions,
        }

    def table_str(self) -> str:
        lines = [f"== diff (B − A, align={self.align}) ==",
                 f"  tasks: {self.matched} matched, {self.only_a} only "
                 f"in A, {self.only_b} only in B"]
        hdr = (f"  {'phase':>12} {'mean_a':>10} {'mean_b':>10} "
               f"{'Δmean':>10} {'Δp50':>10} {'Δp99':>10} {'KS':>6}")
        lines.append(hdr)
        for p in self.phases.values():
            lines.append(
                f"  {p.phase:>12} {p.mean_a:10.4g} {p.mean_b:10.4g} "
                f"{p.mean_delta:+10.3g} {p.p50_delta:+10.3g} "
                f"{p.p99_delta:+10.3g} {p.ks:6.3f}")
        if self.top_regressions:
            lines.append("  -- top regressed tasks (Δsojourn) --")
            for r in self.top_regressions:
                lines.append(
                    f"  {r['task']:>12}: {r['sojourn_delta_s']:+.4g}s "
                    f"(Δwait {r['queue_wait_delta_s']:+.3g}, "
                    f"Δservice {r['service_delta_s']:+.3g}, "
                    f"Δtransfer {r['transfer_delta_s']:+.3g})")
        if self.is_zero:
            lines.append("  (runs are identical)")
        return "\n".join(lines)


def _phase_arrays(t: TaskTable) -> dict[str, np.ndarray]:
    return {"sojourn": t.sojourn_s, "queue_wait": t.queue_wait_s,
            "service": t.service_s, "transfer": t.transfer_s}


def _align(ta: TaskTable, tb: TaskTable, align: str
           ) -> tuple[np.ndarray, np.ndarray]:
    """Matched row-index pairs ``(idx_a, idx_b)``."""
    if align == "task":
        # task names are the ids; duplicate names pair off in order
        slots: dict[str, list[int]] = {}
        for j, name in enumerate(tb.task):
            slots.setdefault(name, []).append(j)
        ia, ib = [], []
        for i, name in enumerate(ta.task):
            if slots.get(name):
                ia.append(i)
                ib.append(slots[name].pop(0))
        return np.asarray(ia, np.int64), np.asarray(ib, np.int64)
    if align == "arrival":
        # pair the k-th arrival of A with the k-th arrival of B
        n = min(len(ta), len(tb))
        oa = np.argsort(ta.arrived_s, kind="stable")[:n]
        ob = np.argsort(tb.arrived_s, kind="stable")[:n]
        return oa, ob
    raise ValueError(f"unknown align {align!r}; use 'task' or 'arrival'")


def diff(a, b, *, align: str = "task", top_k: int = 10) -> DiffReport:
    """Differential profile of run ``b`` against baseline ``a``.

    ``a`` / ``b`` accept anything :func:`repro.obs.analyze.load` does
    (Tracer, Telemetry, trace.json path/dict, TraceTable).
    Distribution statistics (deltas at the quantiles, K-S) compare the
    *full* per-run distributions; the top-k regression list uses the
    aligned pairs (``align="task"`` by task name, ``"arrival"`` by
    arrival order).
    """
    ta, tb = load(a).lifecycles(), load(b).lifecycles()
    pa, pb = _phase_arrays(ta), _phase_arrays(tb)
    phases = {}
    for ph in DIFF_PHASES:
        xa, xb = pa[ph], pb[ph]
        ea = float(xa.mean()) if xa.size else 0.0
        eb = float(xb.mean()) if xb.size else 0.0
        qa = np.percentile(xa, [50, 90, 99]) if xa.size \
            else np.zeros(3)
        qb = np.percentile(xb, [50, 90, 99]) if xb.size \
            else np.zeros(3)
        phases[ph] = PhaseDiff(
            phase=ph, mean_a=ea, mean_b=eb, mean_delta=eb - ea,
            p50_delta=float(qb[0] - qa[0]),
            p90_delta=float(qb[1] - qa[1]),
            p99_delta=float(qb[2] - qa[2]),
            ks=ks_statistic(xa, xb))
    ia, ib = _align(ta, tb, align)
    deltas = tb.sojourn_s[ib] - ta.sojourn_s[ia] if ia.size \
        else np.empty(0)
    order = np.argsort(-deltas, kind="stable")[:max(int(top_k), 0)]
    top = [{
        "task": ta.task[int(ia[k])],
        "sojourn_a_s": float(ta.sojourn_s[ia[k]]),
        "sojourn_b_s": float(tb.sojourn_s[ib[k]]),
        "sojourn_delta_s": float(deltas[k]),
        "queue_wait_delta_s": float(tb.queue_wait_s[ib[k]]
                                    - ta.queue_wait_s[ia[k]]),
        "service_delta_s": float(tb.service_s[ib[k]]
                                 - ta.service_s[ia[k]]),
        "transfer_delta_s": float(tb.transfer_s[ib[k]]
                                  - ta.transfer_s[ia[k]]),
        "track_a": ta.track[int(ia[k])], "track_b": tb.track[int(ib[k])],
    } for k in order]
    return DiffReport(
        phases=phases, n_a=len(ta), n_b=len(tb), matched=int(ia.size),
        only_a=len(ta) - int(ia.size), only_b=len(tb) - int(ib.size),
        align=align, top_regressions=top)
