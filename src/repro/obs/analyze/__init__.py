"""repro.obs.analyze — the consumption layer over the trace/metrics
substrate: turn recorded spans into answers.

:mod:`repro.obs` (the layer below) records with zero perturbation;
this package reads what it recorded:

  * :func:`attribute` / :class:`RunAttribution` — per-task and per-run
    phase attribution (``sojourn = queue_wait + service + transfer``),
    critical paths, and the deadline-miss classifier
    (:mod:`~repro.obs.analyze.attribution`);
  * :func:`diff` — differential profiling of two runs: per-phase
    quantile deltas, K-S statistics, top-k regressed tasks
    (:mod:`~repro.obs.analyze.diff`);
  * :class:`QuantileSketch` — mergeable fixed-centroid streaming
    quantiles; also a :class:`repro.obs.MetricsRegistry` kind via
    ``registry.quantile(name)`` (:mod:`~repro.obs.analyze.sketch`);
  * :func:`compare_rows` / the ``regress`` CLI — baseline regression
    gating for CI (:mod:`~repro.obs.analyze.regress`).

CLI: ``python -m repro.obs.analyze {attribution,diff,regress} ...``.

Import note: :mod:`repro.obs.metrics` lazily imports
:class:`QuantileSketch` *inside* ``MetricsRegistry.quantile`` — keep
this package's module-scope imports pointed at sibling submodules only
so that deferral never re-enters a half-initialised ``repro.obs``.
"""
from repro.obs.analyze.attribution import (MISS_CAUSES, RunAttribution,
                                           attribute)
from repro.obs.analyze.diff import DiffReport, PhaseDiff, diff, \
    ks_statistic
from repro.obs.analyze.regress import (RegressionReport, compare_files,
                                       compare_rows, load_rows, selftest)
from repro.obs.analyze.sketch import DEFAULT_QUANTILES, QuantileSketch
from repro.obs.analyze.tables import PHASES, TaskTable, TraceTable, load

__all__ = [
    "attribute", "RunAttribution", "MISS_CAUSES",
    "diff", "DiffReport", "PhaseDiff", "ks_statistic",
    "compare_rows", "compare_files", "load_rows", "selftest",
    "RegressionReport",
    "QuantileSketch", "DEFAULT_QUANTILES",
    "TraceTable", "TaskTable", "load", "PHASES",
]
