"""Chrome trace-event JSON export + the span-pairing validator.

The `trace-event format <https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
is the lingua franca of timeline viewers: Perfetto and
``chrome://tracing`` load it directly.  :func:`export_chrome` maps the
tracer's model onto it:

  * each *track* becomes a Chrome **process** (``pid``), named via a
    ``process_name`` metadata event — one per node / pool / engine;
  * each *tid* becomes a **thread** within it — one per task, so each
    task's lifecycle renders as its own row;
  * spans emit matched ``B``/``E`` duration events.  Within one
    ``(pid, tid)`` row the exporter *orders* the B/E stream itself
    (children open after parents, close before them — ties broken by
    span length), so properly-nested input always produces a
    well-formed stream; partially-overlapping spans on one row are
    rejected rather than silently emitting an unbalanced trace;
  * instants emit ``i`` events (thread scope).

Timestamps are seconds on the caller's clock (virtual or wall) and are
exported in microseconds, the format's unit.

:func:`validate_chrome` is the matching checker — every ``B`` has a
matching ``E``, stacks close LIFO with children inside parents,
timestamps are monotone per track — used by the tests, the benchmark
smoke gate, and anyone handed a ``trace.json`` of unknown provenance.
"""
from __future__ import annotations

import json
import os
from typing import Optional

__all__ = ["export_chrome", "validate_chrome"]

#: scale: tracer seconds -> trace-event microseconds
_US = 1e6


def _trace_events(tracer) -> list[dict]:
    """The ordered traceEvents list for one tracer's contents."""
    pids: dict[str, int] = {}
    events: list[dict] = []

    def pid_of(track: str) -> int:
        pid = pids.get(track)
        if pid is None:
            pid = len(pids)
            pids[track] = pid
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": track}})
        return pid

    # group spans per (track, tid) row and emit each row's B/E stream in
    # stack order: sort by (start, -duration) so parents open before
    # their children, close everything that ends at or before the next
    # span's start, then drain the stack LIFO
    rows: dict[tuple, list] = {}
    for sp in tracer.all_spans():
        rows.setdefault((sp.track, sp.tid), []).append(sp)
    for (track, tid), spans in rows.items():
        pid = pid_of(track)
        stack: list = []

        def close_until(t: Optional[float]) -> None:
            while stack and (t is None or stack[-1].t1 <= t):
                top = stack.pop()
                events.append({"name": top.name, "ph": "E", "pid": pid,
                               "tid": tid, "ts": top.t1 * _US})

        for sp in sorted(spans, key=lambda s: (s.t0, s.t0 - s.t1)):
            close_until(sp.t0)
            if stack and stack[-1].t1 < sp.t1:
                raise ValueError(
                    f"spans on track {track!r} tid {tid} partially "
                    f"overlap: {stack[-1].name!r} [{stack[-1].t0}, "
                    f"{stack[-1].t1}] vs {sp.name!r} [{sp.t0}, {sp.t1}]")
            ev = {"name": sp.name, "ph": "B", "pid": pid, "tid": tid,
                  "ts": sp.t0 * _US}
            if sp.args:
                ev["args"] = dict(sp.args)
            events.append(ev)
            stack.append(sp)
        close_until(None)

    for inst in tracer.all_instants():
        ev = {"name": inst.name, "ph": "i", "pid": pid_of(inst.track),
              "tid": inst.tid, "ts": inst.ts * _US, "s": "t"}
        if inst.args:
            ev["args"] = dict(inst.args)
        events.append(ev)
    # final ordering: metadata first, then a *stable* global sort by
    # timestamp.  Each row's B/E stream is already monotone in ts, so
    # the stable sort preserves its internal order while interleaving
    # instants (whose ingestion order need not be time order — the
    # fleet engine batches them per phase) and other rows time-sorted.
    meta = [e for e in events if e["ph"] == "M"]
    rest = sorted((e for e in events if e["ph"] != "M"),
                  key=lambda e: e["ts"])
    return meta + rest


def export_chrome(tracer, path: Optional[str]) -> dict:
    """Export ``tracer`` as a Chrome trace object; write it to ``path``
    as JSON when given.  Returns the trace dict (callers can validate
    or post-process without re-reading the file)."""
    trace = {"traceEvents": _trace_events(tracer),
             "displayTimeUnit": "ms"}
    if path is not None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(trace, f, indent=1, default=float)
    return trace


def validate_chrome(trace) -> dict:
    """Span-pairing checker for a Chrome trace (dict, traceEvents list,
    or a path to a ``trace.json``).

    Verifies, per ``(pid, tid)`` track, in file order:

      * every ``B`` has a matching ``E`` (same name, LIFO) and no ``E``
        arrives on an empty stack;
      * children nest inside parents (an enclosing span never ends
        before one it contains — guaranteed by LIFO closing with
        monotone timestamps, checked explicitly anyway);
      * ``B``/``E`` timestamps are monotone non-decreasing per track;
      * all ``B`` stacks are closed at end of trace.

    Returns ``{"n_events", "n_spans", "n_instants", "n_tracks"}``;
    raises :class:`ValueError` on the first violation.
    """
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    events = trace["traceEvents"] if isinstance(trace, dict) else trace
    stacks: dict[tuple, list] = {}
    last_ts: dict[tuple, float] = {}
    n_spans = n_instants = 0
    for k, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        key = (ev.get("pid", 0), ev.get("tid", 0))
        ts = float(ev["ts"])
        if ph in ("B", "E", "i"):
            prev = last_ts.get(key)
            if prev is not None and ts < prev:
                raise ValueError(
                    f"event {k} ({ev.get('name')!r}): timestamp {ts} "
                    f"goes backwards on track {key} (prev {prev})")
            last_ts[key] = ts
        if ph == "B":
            stack = stacks.setdefault(key, [])
            stack.append((ev.get("name"), ts))
            n_spans += 1
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(
                    f"event {k}: 'E' {ev.get('name')!r} on track {key} "
                    f"with no open 'B'")
            name, t0 = stack.pop()
            if name != ev.get("name"):
                raise ValueError(
                    f"event {k}: 'E' {ev.get('name')!r} does not match "
                    f"open 'B' {name!r} on track {key} (spans must "
                    f"close LIFO)")
            if ts < t0:
                raise ValueError(
                    f"event {k}: span {name!r} on track {key} ends at "
                    f"{ts} before it begins at {t0}")
        elif ph == "i":
            n_instants += 1
        else:
            raise ValueError(f"event {k}: unknown phase {ph!r}")
    for key, stack in stacks.items():
        if stack:
            raise ValueError(
                f"track {key}: {len(stack)} unmatched 'B' events at end "
                f"of trace (first: {stack[0][0]!r})")
    return {"n_events": len(events), "n_spans": n_spans,
            "n_instants": n_instants, "n_tracks": len(last_ts)}
