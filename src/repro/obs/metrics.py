"""Metrics registry: counters, gauges, fixed-boundary histograms, and
Prometheus text exposition.

:class:`MetricsRegistry` is the standard metrics surface the ROADMAP's
production serving plane scrapes and autoscales against — the
structured superset of the ad-hoc ``Counter``/``gauges`` dicts
:class:`repro.sim.telemetry.Telemetry` accumulated historically
(``Telemetry.registry()`` lifts a run's counters, gauges, and
sojourn/wait/transfer distributions into one, and
``Telemetry.to_prometheus()`` dumps it).

Four metric kinds, deliberately matching the Prometheus data model:

``Counter``
    monotone float total (``inc``); exposed as ``# TYPE ... counter``.
``Gauge``
    last-write-wins float (``set`` / ``inc``); ``# TYPE ... gauge``.
``Histogram``
    fixed-boundary cumulative-bucket histogram (``observe`` /
    ``observe_many``); boundaries are chosen at construction —
    :data:`LATENCY_BOUNDARIES` covers the sojourn/wait/transfer scales
    the simulators produce — so :meth:`Histogram.merge` and scraping
    never re-bin.
``summary``
    a live :class:`repro.obs.analyze.QuantileSketch`
    (:meth:`MetricsRegistry.quantile`): mergeable fixed-centroid
    streaming quantiles — rolling p50/p99 without stored samples,
    exposed as a Prometheus summary series.

:meth:`MetricsRegistry.to_prometheus` renders the text exposition
format (``HELP``/``TYPE`` comments, ``_bucket``/``_sum``/``_count``
histogram series with cumulative ``le`` labels, ``+Inf`` bucket);
:meth:`MetricsRegistry.to_rows` renders the same data in the flat
``[{"name": ..., metric: ...}]`` record schema the ``results/``
benchmark JSONs use, so one plotting path covers both.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LATENCY_BOUNDARIES"]

#: default seconds-scale boundaries for sojourn / wait / transfer
#: histograms: ~1 ms to ~2 min in roughly-2.5x steps
LATENCY_BOUNDARIES = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                      0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 120.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integers render bare."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


@dataclasses.dataclass
class Counter:
    """Monotone total."""
    name: str
    help: str = ""
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({n}))")
        self.value += n

    def expose(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]

    kind = "counter"


@dataclasses.dataclass
class Gauge:
    """Last-write-wins value."""
    name: str
    help: str = ""
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def expose(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]

    kind = "gauge"


class Histogram:
    """Fixed-boundary histogram with cumulative Prometheus buckets.

    ``boundaries`` are the upper bounds of the finite buckets (strictly
    increasing); an implicit ``+Inf`` bucket catches the rest.  Counts,
    sum, and count are plain floats/ints — ``observe_many`` takes a
    vector so a run's whole sojourn column lands in one ``searchsorted``
    rather than a Python loop per task.
    """

    kind = "histogram"

    def __init__(self, name: str, boundaries: Sequence[float] =
                 LATENCY_BOUNDARIES, help: str = ""):
        self.name = name
        self.help = help
        b = tuple(float(x) for x in boundaries)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram {name}: boundaries must be "
                             f"non-empty and strictly increasing, "
                             f"got {b}")
        self.boundaries = b
        self._bounds = np.asarray(b, np.float64)
        self.counts = np.zeros(len(b) + 1, np.int64)  # [+Inf] last
        self.sum = 0.0
        self.count = 0
        self.observed_max = float("-inf")

    def observe(self, v: float) -> None:
        self.observe_many([v])

    def observe_many(self, values) -> None:
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        # bucket k holds values <= boundaries[k] (Prometheus `le`)
        idx = np.searchsorted(self._bounds, v, side="left")
        self.counts += np.bincount(idx, minlength=len(self.counts))
        self.sum += float(v.sum())
        self.count += int(v.size)
        self.observed_max = max(self.observed_max, float(v.max()))

    def percentile_bound(self, q: float) -> float:
        """Upper bound on the q-quantile recoverable without raw
        samples: the upper boundary of the bucket the quantile falls
        in.  Always *finite*: when the quantile lands in the ``+Inf``
        bucket the exact observed maximum is returned instead (a
        histogram that answered ``inf`` is useless to an autoscaler).
        ``q`` below the observed mass clamps to the bucket holding the
        smallest observation (``q=0`` → the first non-empty bucket's
        bound), ``q=1`` to the one holding the largest."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        cum = np.cumsum(self.counts)
        # clamp the target rank into [1, count]: ranks below one
        # observation resolve to the first observation's bucket
        target = min(max(q * self.count, 1.0), float(self.count))
        k = int(np.searchsorted(cum, target, side="left"))
        return self.boundaries[k] if k < len(self.boundaries) \
            else self.observed_max

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold a same-boundary histogram in (the multi-replica
        roll-up: fixed boundaries mean merging never re-bins)."""
        if not isinstance(other, Histogram) \
                or other.boundaries != self.boundaries:
            raise ValueError(
                f"histogram {self.name!r}: can only merge a histogram "
                f"with identical boundaries (got "
                f"{getattr(other, 'boundaries', type(other))})")
        self.counts += other.counts
        self.sum += other.sum
        self.count += other.count
        self.observed_max = max(self.observed_max, other.observed_max)
        return self

    def expose(self) -> list[str]:
        out = []
        cum = 0
        for b, c in zip(self.boundaries, self.counts):
            cum += int(c)
            out.append(f'{self.name}_bucket{{le="{_fmt(b)}"}} {cum}')
        out.append(f'{self.name}_bucket{{le="+Inf"}} {self.count}')
        out.append(f"{self.name}_sum {_fmt(self.sum)}")
        out.append(f"{self.name}_count {self.count}")
        return out


class MetricsRegistry:
    """A named collection of metrics with get-or-create accessors.

    Accessors are idempotent: asking for an existing name returns the
    live metric (so instrumentation sites never coordinate), but asking
    with a *different* kind (or different histogram boundaries) is an
    error — silently merging incompatible series is how dashboards lie.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, kind: str):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r} (want "
                             f"[a-zA-Z_:][a-zA-Z0-9_:]*)")
        m = self._metrics.get(name)
        if m is not None and m.kind != kind:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        m = self._get(name, "counter")
        if m is None:
            m = self._metrics[name] = Counter(name, help)
        return m

    def gauge(self, name: str, help: str = "") -> Gauge:
        m = self._get(name, "gauge")
        if m is None:
            m = self._metrics[name] = Gauge(name, help)
        return m

    def histogram(self, name: str,
                  boundaries: Sequence[float] = LATENCY_BOUNDARIES,
                  help: str = "") -> Histogram:
        m = self._get(name, "histogram")
        if m is None:
            m = self._metrics[name] = Histogram(name, boundaries, help)
        elif m.boundaries != tuple(float(b) for b in boundaries):
            raise ValueError(f"histogram {name!r} already registered "
                             f"with boundaries {m.boundaries}")
        return m

    def quantile(self, name: str, *, max_centroids: int = 128,
                 quantiles: Sequence[float] = (0.5, 0.9, 0.99),
                 help: str = ""):
        """Get-or-create a live :class:`repro.obs.analyze.
        QuantileSketch` (Prometheus ``summary`` kind): a mergeable
        fixed-centroid sketch answering rolling p50/p99 without storing
        samples — what the serving engines expose live sojourn tails
        through."""
        # deferred: the sketch lives in the analyze layer above this one
        from repro.obs.analyze.sketch import QuantileSketch
        m = self._get(name, "summary")
        if m is None:
            m = self._metrics[name] = QuantileSketch(
                name, max_centroids=max_centroids, quantiles=quantiles,
                help=help)
        elif m.max_centroids != int(max_centroids):
            raise ValueError(f"quantile sketch {name!r} already "
                             f"registered with max_centroids="
                             f"{m.max_centroids}")
        return m

    def __len__(self) -> int:
        return len(self._metrics)

    def __iter__(self):
        return iter(self._metrics.values())

    def get(self, name: str):
        return self._metrics.get(name)

    # -- export -----------------------------------------------------------
    def to_prometheus(self) -> str:
        """The Prometheus text exposition format (version 0.0.4): what
        a ``/metrics`` endpoint returns to a scrape."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def to_rows(self, name: str = "metrics") -> list[dict]:
        """Flat benchmark-style rows (the ``results/`` record schema):
        one row carrying every counter/gauge, plus one row per
        histogram with its bucket counts."""
        scalars = {m.name: m.value for m in self._metrics.values()
                   if m.kind in ("counter", "gauge")}
        rows = [{"name": name, **dict(sorted(scalars.items()))}]
        for hname in sorted(self._metrics):
            m = self._metrics[hname]
            if m.kind == "histogram":
                rows.append({
                    "name": f"{name}_hist_{hname}",
                    "boundaries": list(m.boundaries),
                    "counts": [int(c) for c in m.counts],
                    "sum": m.sum, "count": m.count,
                })
            elif m.kind == "summary":
                rows.append({
                    "name": f"{name}_quantiles_{hname}",
                    "quantiles": {str(q): m.quantile(q)
                                  for q in m.quantiles},
                    "sum": m.sum, "count": m.count,
                })
        return rows

    def save(self, path: str, name: str = "metrics") -> None:
        import json
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_rows(name), f, indent=1, default=float)
