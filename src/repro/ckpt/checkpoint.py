"""Pytree checkpointing: atomic npz save/restore with step metadata.

Sharded arrays are gathered to host before writing (single-controller
semantics); restore re-places leaves onto the current sharding via the
caller's ``like`` tree.  Kept dependency-free (no orbax) per the
build-everything mandate.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(path: str, tree: PyTree, *, step: int = 0,
         metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(tree)
    meta = {"step": step, **(metadata or {})}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **arrays)
        os.replace(tmp, path)            # atomic
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure (and shardings) of ``like``."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_keys, leaf in flat:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path_keys)
            arr = z[key]
            if hasattr(leaf, "sharding") and leaf.sharding is not None:
                try:
                    leaves.append(jax.device_put(arr, leaf.sharding))
                    continue
                except Exception:        # noqa: BLE001
                    pass
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def latest(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = [f for f in os.listdir(directory)
             if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    cands.sort(key=lambda f: int(f[len(prefix):-4]))
    return os.path.join(directory, cands[-1])
