from repro.ckpt import checkpoint
from repro.ckpt.checkpoint import latest, restore, save

__all__ = ["checkpoint", "latest", "restore", "save"]
