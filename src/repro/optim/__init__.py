from repro.optim.optimizers import (
    Optimizer,
    adagrad,
    adam,
    adamw,
    apply_updates,
    get_optimizer,
    rmsprop,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer",
    "adagrad",
    "adam",
    "adamw",
    "apply_updates",
    "get_optimizer",
    "rmsprop",
    "sgd",
    "constant",
    "cosine_decay",
    "warmup_cosine",
]
