"""First-party optimiser library (no optax dependency).

The paper's Table I sweeps four optimisers — Adam, SGD, RMSprop, Adagrad —
as profiling variables, so all four are first-class here.  The API is a
minimal gradient-transformation pair:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``lr`` may be a float or a ``step -> lr`` schedule (see
:mod:`repro.optim.schedules`); schedules read the step counter stored in the
optimiser state, so the state pytree stays jit/pjit friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]
LR = Union[float, Schedule]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "optimizer"


def _as_schedule(lr: LR) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params + updates, preserving each leaf's dtype (bf16-safe)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates
    )


def _zeros_like_f32(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params
    )


def _decay(updates: PyTree, params: PyTree, weight_decay: float, lr: jnp.ndarray) -> PyTree:
    if weight_decay == 0.0:
        return updates
    return jax.tree_util.tree_map(
        lambda u, p: u - lr * weight_decay * p.astype(u.dtype), updates, params
    )


def sgd(lr: LR, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = _zeros_like_f32(params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], g32)
            if nesterov:
                d = jax.tree_util.tree_map(
                    lambda m, g: momentum * m + g, mu, g32)
            else:
                d = mu
            new_state = {"step": step, "mu": mu}
        else:
            d = g32
            new_state = {"step": step}
        updates = jax.tree_util.tree_map(lambda v: -lr_t * v, d)
        updates = _decay(updates, params, weight_decay, lr_t)
        return updates, new_state

    return Optimizer(init, update, name="sgd")


def adam(lr: LR, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": _zeros_like_f32(params),
            "v": _zeros_like_f32(params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], g32)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return -lr_t * mhat / (jnp.sqrt(vhat) + eps)

        updates = jax.tree_util.tree_map(upd, m, v)
        updates = _decay(updates, params, weight_decay, lr_t)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, name="adam")


def adamw(lr: LR, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    opt = adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    return Optimizer(opt.init, opt.update, name="adamw")


def rmsprop(lr: LR, decay: float = 0.9, eps: float = 1e-8,
            momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        state = {
            "step": jnp.zeros((), jnp.int32),
            "nu": _zeros_like_f32(params),
        }
        if momentum:
            state["mu"] = _zeros_like_f32(params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        nu = jax.tree_util.tree_map(
            lambda n, g: decay * n + (1 - decay) * jnp.square(g),
            state["nu"], g32)
        scaled = jax.tree_util.tree_map(
            lambda g, n: g / (jnp.sqrt(n) + eps), g32, nu)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, s: momentum * m + s, state["mu"], scaled)
            updates = jax.tree_util.tree_map(lambda m: -lr_t * m, mu)
            new_state = {"step": step, "nu": nu, "mu": mu}
        else:
            updates = jax.tree_util.tree_map(lambda s: -lr_t * s, scaled)
            new_state = {"step": step, "nu": nu}
        updates = _decay(updates, params, weight_decay, lr_t)
        return updates, new_state

    return Optimizer(init, update, name="rmsprop")


def adagrad(lr: LR, eps: float = 1e-10, initial_accumulator: float = 0.1,
            weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        acc = jax.tree_util.tree_map(
            lambda p: jnp.full(p.shape, initial_accumulator, jnp.float32),
            params)
        return {"step": jnp.zeros((), jnp.int32), "acc": acc}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g), state["acc"], g32)
        updates = jax.tree_util.tree_map(
            lambda g, a: -lr_t * g / (jnp.sqrt(a) + eps), g32, acc)
        updates = _decay(updates, params, weight_decay, lr_t)
        return updates, {"step": step, "acc": acc}

    return Optimizer(init, update, name="adagrad")


_REGISTRY: dict[str, Callable[..., Optimizer]] = {
    "sgd": sgd,
    "adam": adam,
    "adamw": adamw,
    "rmsprop": rmsprop,
    "adagrad": adagrad,
}


def get_optimizer(name: str, lr: LR, **kwargs) -> Optimizer:
    """Look up an optimiser by the name used in the paper's Table I."""
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown optimiser {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key](lr, **kwargs)
