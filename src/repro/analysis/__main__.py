"""``python -m repro.analysis`` dispatch."""
import sys

from repro.analysis.cli import main

sys.exit(main())
