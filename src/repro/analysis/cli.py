"""Command-line entry: ``python -m repro.analysis [options] paths...``

Exit status: 0 when no finding reaches ``--fail-level`` (default:
warning), 1 when at least one does, 2 on usage errors.  ``--format
json`` emits a machine-readable report for CI annotation.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

import repro.analysis.rules  # noqa: F401  (registers the rule set)
from repro.analysis.core import Severity
from repro.analysis.reporters import json_report, rule_catalog, text_report
from repro.analysis.runner import iter_py_files, run_paths


def _csv(value: str) -> List[str]:
    return [v for v in value.replace(",", " ").split() if v]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the repro codebase: "
                    "determinism, bit-for-bit, RNG-stream, jit-trace and "
                    "kernel-layout contracts.")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", type=_csv, default=None, metavar="IDS",
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--ignore", type=_csv, default=None, metavar="IDS",
                    help="comma-separated rule ids to skip")
    ap.add_argument("--fail-level", default="warning",
                    choices=("info", "warning", "error"),
                    help="lowest severity that makes the exit status "
                         "non-zero (default: warning)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(rule_catalog())
        return 0

    paths = args.paths or ["src"]
    try:
        findings = run_paths(paths, select=args.select, ignore=args.ignore)
        n_files = len(iter_py_files(paths))
    except (FileNotFoundError, ValueError) as err:
        print(f"error: {err}", file=sys.stderr)
        return 2

    report = (json_report if args.format == "json" else text_report)(
        findings, n_files)
    print(report)
    fail_at = Severity.parse(args.fail_level)
    return 1 if any(f.severity >= fail_at for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
