"""Render findings as human text or machine JSON (``--format``)."""
from __future__ import annotations

import json
from typing import List

from repro.analysis.core import REGISTRY, Finding
from repro.analysis.runner import severity_counts


def text_report(findings: List[Finding], n_files: int) -> str:
    lines = [f.render() for f in findings]
    c = severity_counts(findings)
    lines.append(
        f"{len(findings)} finding(s) ({c['error']} error, "
        f"{c['warning']} warning, {c['info']} info) in {n_files} file(s)")
    return "\n".join(lines)


def json_report(findings: List[Finding], n_files: int) -> str:
    return json.dumps({
        "findings": [f.to_dict() for f in findings],
        "counts": severity_counts(findings),
        "n_files": n_files,
    }, indent=2)


def rule_catalog() -> str:
    """``--list-rules``: one line per registered rule."""
    width = max((len(i) for i in REGISTRY), default=0)
    return "\n".join(
        f"{rid:<{width}}  [{REGISTRY[rid].severity}] {REGISTRY[rid].title}"
        for rid in sorted(REGISTRY))
