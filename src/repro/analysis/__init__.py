"""repro.analysis — AST-based invariant linter for the repro codebase.

Machine-checks the conventions the reproducibility guarantees rest on:
seeded-RNG-stream hygiene (RNG001/RNG002), FMA-contraction and
wall-clock determinism contracts (DET001/DET002), jax.jit trace hazards
(JIT001/JIT002), kernel-triple signature/SPEC-layout alignment
(KRN001), and unit-suffix arithmetic (UNIT001).

CLI::

    PYTHONPATH=src python -m repro.analysis [--format json] \\
        [--select RNG001,KRN001] [--fail-level warning] src tests

Library::

    from repro.analysis import analyze_source, run_paths
    findings = run_paths(["src"])          # [] == invariants hold

See ``docs/analysis-rules.md`` for the full rule catalog with examples
and suppression syntax (``# repro: disable=RULE`` per line,
``# repro: disable-file=RULE`` per file, ``# repro:
module-tags=fma-sensitive`` to opt a module into tagged rules).
"""
import repro.analysis.rules  # noqa: F401  (registers the shipped rules)
from repro.analysis.core import (REGISTRY, FileContext, Finding, Rule,
                                 Severity, register)
from repro.analysis.runner import (analyze_source, analyze_sources,
                                   run_paths)

__all__ = ["REGISTRY", "FileContext", "Finding", "Rule", "Severity",
           "register", "analyze_source", "analyze_sources", "run_paths"]
