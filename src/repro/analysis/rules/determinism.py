"""Determinism rules: bit-for-bit and virtual-clock contracts.

DET001 — the numpy ≡ jax f64 bit-for-bit guarantee (PR 3) exists only
because the scalarisation / cumulative-sum paths accumulate term by term
with one eager primitive per step; a ``@`` / ``dot`` / ``matmul`` lets
BLAS or XLA fuse multiply-adds (FMA contraction) and the two backends
round differently.  Modules that carry this guarantee declare it with a
``# repro: module-tags=fma-sensitive`` directive and this rule keeps
them honest.

DET002 — ``repro.sim`` is virtual-clock-only (event time comes from the
``Clock`` / slab timeline, never the host), and ``repro.serve``'s
admission control runs on the same virtual clock.  A stray
``time.time()`` makes seeded runs diverge across hosts.  Genuine
wall-time *measurement* of real model execution (ServeEngine stats)
carries an explicit per-line suppression instead.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import (FileContext, Finding, Rule, Severity,
                                 dotted, register)

FMA_TAG = "fma-sensitive"

#: dense-contraction callables whose FMA fusion breaks bitwise equality
_MATMUL_CALLS = frozenset({
    f"{mod}.{fn}"
    for mod in ("np", "numpy", "jnp", "jax.numpy")
    for fn in ("dot", "matmul", "vdot", "inner", "tensordot", "einsum")
})

#: wall-clock reads (virtual-clock modules must never call these)
_WALL_CLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns",
})
_WALL_CLOCK_SUFFIXES = ("datetime.now", "datetime.utcnow",
                        "datetime.today", "date.today")


@register
class MatmulInFmaSensitive(Rule):
    """DET001: no matmul-family ops in fma-sensitive modules."""

    id = "DET001"
    severity = Severity.ERROR
    title = ("no @ / dot / matmul / einsum in modules tagged "
             "fma-sensitive (FMA contraction breaks numpy ≡ jax "
             "bit-for-bit); accumulate sequentially")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if FMA_TAG not in ctx.tags:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(
                    node.op, ast.MatMult):
                yield self.finding(
                    ctx, node,
                    "`@` matmul in an fma-sensitive module: BLAS/XLA "
                    "FMA contraction rounds differently per backend — "
                    "accumulate term-by-term instead")
            elif isinstance(node, ast.Call):
                name = dotted(node.func)
                if name in _MATMUL_CALLS:
                    yield self.finding(
                        ctx, node,
                        f"`{name}` in an fma-sensitive module: dense "
                        f"contraction is FMA-fusible and backend-"
                        f"dependent — accumulate term-by-term instead")


@register
class WallClockInVirtualTime(Rule):
    """DET002: no wall-clock reads in virtual-clock modules."""

    id = "DET002"
    severity = Severity.ERROR
    title = ("no wall-clock (time.time / perf_counter / datetime.now) "
             "in repro.sim / repro.serve — event time is virtual")

    SCOPES = ("repro.sim", "repro.serve")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not ctx.in_module(*self.SCOPES):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name is None:
                continue
            if name in _WALL_CLOCK_CALLS or any(
                    name == suf or name.endswith("." + suf)
                    for suf in _WALL_CLOCK_SUFFIXES):
                yield self.finding(
                    ctx, node,
                    f"wall-clock `{name}()` inside {ctx.module}: this "
                    f"module runs on the virtual clock — seeded runs "
                    f"must not observe host time (suppress explicitly "
                    f"if measuring real execution)")
