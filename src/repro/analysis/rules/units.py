"""Unit-suffix hygiene: seconds / bytes / bandwidth never mix raw.

The repo's naming convention carries units in suffixes — ``sojourn_s``,
``act_bytes``, ``link_bw`` — and conversions are always explicit
divisions/multiplications (``bytes / bw → s``).  Adding or subtracting
across suffixes (``lat_s + ship_bytes``) is therefore always a bug:
a transfer time that forgot to divide by bandwidth, an energy term fed
raw bytes.  UNIT001 flags ``+`` / ``-`` between operands whose inferred
unit suffixes differ; products and quotients are unit conversions and
never flagged.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import (FileContext, Finding, Rule, Severity,
                                 register)

#: suffix → canonical unit; longest-match wins (``_bytes`` before ``_s``)
_SUFFIX_UNITS = (("_bytes", "bytes"), ("_bw", "bw"), ("_s", "s"))


def unit_of(node: ast.AST) -> Optional[str]:
    """Infer the unit of an expression from naming suffixes.

    Names/attributes carry their suffix unit; indexing keeps the unit of
    what is indexed; ``a + b`` / ``a - b`` keep the unit when both sides
    agree.  Anything else (products, calls, literals) is unknown — a
    multiply or divide is exactly where units legitimately change.
    """
    if isinstance(node, ast.Name):
        return _suffix_unit(node.id)
    if isinstance(node, ast.Attribute):
        return _suffix_unit(node.attr)
    if isinstance(node, ast.Subscript):
        return unit_of(node.value)
    if isinstance(node, ast.UnaryOp):
        return unit_of(node.operand)
    if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                  (ast.Add, ast.Sub)):
        left, right = unit_of(node.left), unit_of(node.right)
        if left is not None and left == right:
            return left
    return None


def _suffix_unit(name: str) -> Optional[str]:
    for suffix, unit in _SUFFIX_UNITS:
        if name.endswith(suffix) and name != suffix.lstrip("_"):
            return unit
    return None


@register
class MixedUnitArithmetic(Rule):
    """UNIT001: no +/- across _s / _bytes / _bw suffixed operands."""

    id = "UNIT001"
    severity = Severity.WARNING
    title = ("adding/subtracting operands with different unit suffixes "
             "(_s / _bytes / _bw) without an explicit conversion")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, (ast.Add, ast.Sub))):
                continue
            left, right = unit_of(node.left), unit_of(node.right)
            if left is not None and right is not None and left != right:
                op = "+" if isinstance(node.op, ast.Add) else "-"
                yield self.finding(
                    ctx, node,
                    f"`{ast.unparse(node.left)} {op} "
                    f"{ast.unparse(node.right)}` mixes _{left} and "
                    f"_{right} quantities — convert explicitly "
                    f"(e.g. bytes / bw → s) before combining")
