"""Kernel-triple contracts: signature alignment and SPEC row layout.

Every accelerator kernel lives as a ``kernels/<name>/`` triple —
``ref.py`` (host-numpy oracle), ``ops.py`` (jitted/dispatch entry),
``kernel.py`` (the Pallas body).  Two contracts have historically been
maintained by hand and broken by hand:

* the host oracle ``<fn>_ref`` and its accelerated counterpart ``<fn>``
  must agree on the leading parameters (ops may append tuning knobs like
  ``block_e`` / ``interpret``), or equivalence tests silently compare
  different computations;
* the SMEM spec-vector layout — ``SPEC_*`` row-index constants packed by
  ``pack_spec`` and read by the kernel — must exactly tile
  ``0..SPEC_LEN-1``.  The decide_split spec has been re-laid twice
  (9 → 12); a constant added without bumping ``SPEC_LEN`` (or vice
  versa) ships a kernel that silently reads garbage rows.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.core import (FileContext, Finding, Rule, Severity,
                                 register)

_KERNEL_DIR = re.compile(r"kernels[/\\]([A-Za-z0-9_]+)[/\\]"
                         r"(ref|ops|kernel)\.py$")


def _public_defs(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {node.name: node for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and not node.name.startswith("_")}


def _sig_names(fn: ast.FunctionDef) -> Tuple[List[str], List[str]]:
    """(positional parameter names, keyword-only parameter names)."""
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args],
            [p.arg for p in a.kwonlyargs])


def _spec_indices(tree: ast.Module) -> Tuple[Optional[int],
                                             Dict[str, Tuple[int, int]]]:
    """(SPEC_LEN value, {SPEC_* name: (index, lineno)}) at module scope.

    Understands the two layout idioms used in kernel files::

        SPEC_LEN = 12
        SPEC_RADIO, SPEC_PPS = range(2)        # or range(lo, hi)
        SPEC_ETOT = 8
    """
    spec_len: Optional[int] = None
    indices: Dict[str, Tuple[int, int]] = {}
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign):
            continue
        value = stmt.value
        for target in stmt.targets:
            if isinstance(target, ast.Name) and target.id == "SPEC_LEN" \
                    and isinstance(value, ast.Constant) \
                    and isinstance(value.value, int):
                spec_len = value.value
            elif isinstance(target, ast.Name) \
                    and target.id.startswith("SPEC_") \
                    and isinstance(value, ast.Constant) \
                    and isinstance(value.value, int):
                indices[target.id] = (value.value, stmt.lineno)
            elif isinstance(target, ast.Tuple) and all(
                    isinstance(e, ast.Name) and e.id.startswith("SPEC_")
                    for e in target.elts):
                rng = _range_values(value)
                if rng is not None and len(rng) == len(target.elts):
                    for name_node, idx in zip(target.elts, rng):
                        indices[name_node.id] = (idx, stmt.lineno)
    return spec_len, indices


def _range_values(node: ast.AST) -> Optional[List[int]]:
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "range":
        args = []
        for a in node.args:
            if not (isinstance(a, ast.Constant)
                    and isinstance(a.value, int)):
                return None
            args.append(a.value)
        if 1 <= len(args) <= 3:
            return list(range(*args))
    return None


@register
class KernelTripleContracts(Rule):
    """KRN001: ref/ops signature alignment + SPEC row-layout checks."""

    id = "KRN001"
    severity = Severity.ERROR
    title = ("kernels/<name>/ ref.py and ops.py public signatures must "
             "stay aligned, and SPEC_* row constants must exactly tile "
             "0..SPEC_LEN-1")
    scope = "project"

    def check_project(self,
                      ctxs: List[FileContext]) -> Iterator[Finding]:
        triples: Dict[str, Dict[str, FileContext]] = {}
        for ctx in ctxs:
            m = _KERNEL_DIR.search(os.path.normpath(ctx.path))
            if m:
                triples.setdefault(m.group(1), {})[m.group(2)] = ctx
            yield from self._check_spec_layout(ctx)
        for name, files in sorted(triples.items()):
            if "ref" in files and "ops" in files:
                yield from self._check_signatures(name, files["ref"],
                                                  files["ops"])

    # -- signature alignment ------------------------------------------------
    def _check_signatures(self, name: str, ref: FileContext,
                          ops: FileContext) -> Iterator[Finding]:
        ref_defs = _public_defs(ref.tree)
        ops_defs = _public_defs(ops.tree)
        for ref_name, ref_fn in sorted(ref_defs.items()):
            if not ref_name.endswith("_ref"):
                continue
            stem = ref_name[:-len("_ref")]
            for candidate in (stem, stem + "_jax"):
                ops_fn = ops_defs.get(candidate)
                if ops_fn is not None:
                    yield from self._compare(name, ref, ref_fn, ops,
                                             ops_fn)
            # differently-named entries (e.g. attention_ref vs
            # flash_attention) carry no name-derived contract

    def _compare(self, kernel: str, ref: FileContext,
                 ref_fn: ast.FunctionDef, ops: FileContext,
                 ops_fn: ast.FunctionDef) -> Iterator[Finding]:
        ref_pos, ref_kw = _sig_names(ref_fn)
        ops_pos, ops_kw = _sig_names(ops_fn)
        if ops_pos[:len(ref_pos)] != ref_pos:
            yield self.finding(
                ops, ops_fn,
                f"kernels/{kernel}: `{ops_fn.name}{tuple(ops_pos)}` "
                f"positional parameters diverge from host oracle "
                f"`{ref_fn.name}{tuple(ref_pos)}` — equivalence tests "
                f"would compare different computations")
        missing = [k for k in ref_kw if k not in ops_kw + ops_pos]
        if missing:
            yield self.finding(
                ops, ops_fn,
                f"kernels/{kernel}: `{ops_fn.name}` is missing keyword "
                f"parameter(s) {missing} that the host oracle "
                f"`{ref_fn.name}` accepts")

    # -- SPEC row layout ----------------------------------------------------
    def _check_spec_layout(self, ctx: FileContext) -> Iterator[Finding]:
        spec_len, indices = _spec_indices(ctx.tree)
        if not indices and spec_len is None:
            return
        if indices and spec_len is None:
            first = min(indices.values(), key=lambda v: v[1])
            yield Finding(
                path=ctx.path, line=first[1], col=0, rule=self.id,
                severity=self.severity,
                message=f"SPEC_* row constants defined but no "
                        f"`SPEC_LEN = <int>` in {ctx.path} — the kernel "
                        f"cannot size its SMEM spec vector")
            return
        if spec_len is None:
            return
        covered = {}
        for name, (idx, line) in sorted(indices.items()):
            if idx >= spec_len or idx < 0:
                yield Finding(
                    path=ctx.path, line=line, col=0, rule=self.id,
                    severity=self.severity,
                    message=f"`{name} = {idx}` is out of range for "
                            f"SPEC_LEN = {spec_len}: the kernel would "
                            f"read past its spec vector")
            covered.setdefault(idx, name)
        if indices:
            missing = sorted(set(range(spec_len)) - set(covered))
            if missing:
                line = max(v[1] for v in indices.values())
                yield Finding(
                    path=ctx.path, line=line, col=0, rule=self.id,
                    severity=self.severity,
                    message=f"SPEC row constants cover "
                            f"{sorted(covered)} but SPEC_LEN = "
                            f"{spec_len} expects every row in "
                            f"0..{spec_len - 1} (missing {missing}) — "
                            f"layout and length are desynced")
