"""Rule modules self-register on import; importing this package loads
the full shipped rule set into :data:`repro.analysis.core.REGISTRY`."""
from repro.analysis.rules import (determinism, jit, kernels, rng,  # noqa: F401
                                  units)
