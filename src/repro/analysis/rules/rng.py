"""RNG hygiene rules: seeded-stream pinning.

Every stochastic process in this repo draws from an explicitly seeded
``np.random.Generator`` — usually a ``SeedSequence`` child spawned by
``repro.sim.queueing.spawn_streams`` so arrival/link/RTT streams stay
independent and seeded runs stay pinned bit-for-bit (PR 7).  Two ways
code has historically broken that:

* touching the legacy *global* ``np.random.*`` API (hidden process-wide
  state, order-dependent draws) — RNG001;
* constructing a fresh ``default_rng(<literal>)`` (or worse,
  ``default_rng()`` = OS entropy) deep inside ``repro.sim`` /
  ``repro.oracle`` instead of threading the caller's seed — two
  components silently share or fork a stream and the equivalence matrix
  rots — RNG002.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import (FileContext, Finding, Rule, Severity,
                                 dotted, register)

#: legacy numpy global-state RNG attributes (the pre-Generator API)
LEGACY_NP_RANDOM = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "ranf", "sample", "random_integers", "choice", "shuffle",
    "permutation", "uniform", "normal", "standard_normal", "lognormal",
    "exponential", "poisson", "weibull", "gamma", "beta", "binomial",
    "geometric", "pareto", "multivariate_normal", "get_state",
    "set_state", "RandomState",
})

_NP_RANDOM_PREFIXES = ("np.random.", "numpy.random.")

#: constructors RNG002 audits inside repro.sim / repro.oracle
_RNG_CTORS = frozenset({
    "np.random.default_rng", "numpy.random.default_rng", "default_rng",
    "np.random.Generator", "numpy.random.Generator",
    "np.random.SeedSequence", "numpy.random.SeedSequence",
    "SeedSequence",
})


def _literal_seed(node: ast.AST) -> bool:
    if isinstance(node, ast.UnaryOp):           # -1 parses as USub(1)
        node = node.operand
    return isinstance(node, ast.Constant) and isinstance(
        node.value, (int, float))


@register
class LegacyGlobalRandom(Rule):
    """RNG001: no legacy global ``np.random.*`` state."""

    id = "RNG001"
    severity = Severity.ERROR
    title = ("legacy global np.random.* API forbidden — use a seeded "
             "np.random.default_rng(...) Generator")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute):
                name = dotted(node)
                if name and any(
                        name == pre + attr
                        for pre in _NP_RANDOM_PREFIXES
                        for attr in (node.attr,)) \
                        and node.attr in LEGACY_NP_RANDOM:
                    yield self.finding(
                        ctx, node,
                        f"legacy global RNG `{name}` pins hidden "
                        f"process-wide state; draw from a seeded "
                        f"Generator (np.random.default_rng) instead")
            elif isinstance(node, ast.ImportFrom):
                if node.module in ("numpy.random", "numpy.random.mtrand"):
                    for alias in node.names:
                        if alias.name in LEGACY_NP_RANDOM:
                            yield self.finding(
                                ctx, node,
                                f"importing legacy `{alias.name}` from "
                                f"numpy.random; use a seeded Generator")


@register
class FreshSeedInSim(Rule):
    """RNG002: sim/oracle Generators must flow from an argument."""

    id = "RNG002"
    severity = Severity.WARNING
    title = ("repro.sim / repro.oracle RNG construction must thread a "
             "seed argument or spawn_streams child, not a fresh "
             "literal / OS-entropy seed")

    SCOPES = ("repro.sim", "repro.oracle")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not ctx.in_module(*self.SCOPES):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name not in _RNG_CTORS:
                continue
            short = name.rsplit(".", 1)[-1]
            if not node.args and not node.keywords:
                yield self.finding(
                    ctx, node,
                    f"`{short}()` seeds from OS entropy — runs become "
                    f"unreproducible; thread the caller's seed or a "
                    f"spawn_streams(...) child")
            elif node.args and _literal_seed(node.args[0]):
                yield self.finding(
                    ctx, node,
                    f"`{short}({ast.unparse(node.args[0])})` hardcodes a "
                    f"fresh literal seed inside {ctx.module}; seeds must "
                    f"flow from an argument or spawn_streams(...) so "
                    f"streams stay independent and pinnable")
