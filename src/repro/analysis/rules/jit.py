"""jax.jit trace-hazard rules.

JIT001 — a ``@jax.jit`` function that reads a *mutable* module global
(a dict/list/set literal, or a global rebound after definition) bakes
the trace-time value into the compiled executable: later mutations are
silently ignored (or worse, trigger retraces keyed on identity).  The
same goes for mutating ``self``/object attributes inside the traced
body — the write happens once, at trace time.  Reading immutable
module constants (``np.array(...)`` tables, ints) is fine and common.

JIT002 — Python ``if``/``while`` on a *traced* argument raises
``TracerBoolConversionError`` at best and silently specialises at
worst; branch on traced values with ``jnp.where`` / ``lax.cond``, or
mark the argument static (``static_argnames``), which the rule
understands and exempts.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from repro.analysis.core import (FileContext, Finding, Rule, Severity,
                                 dotted, register, walk_skipping_functions)

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)


def _jit_decorator(dec: ast.AST) -> Optional[Tuple[Set[str], Set[int]]]:
    """If ``dec`` is a jit decorator, return (static names, static nums).

    Recognises ``@jax.jit``, ``@jit``, ``@jax.jit(...)`` and
    ``@functools.partial(jax.jit, static_argnames=(...), ...)`` forms.
    """
    jit_names = {"jax.jit", "jit"}
    if dotted(dec) in jit_names:
        return set(), set()
    if not isinstance(dec, ast.Call):
        return None
    callee = dotted(dec.func)
    kwargs = dec.keywords
    if callee in jit_names:
        pass                                   # @jax.jit(static_argnames=...)
    elif callee in ("functools.partial", "partial") and dec.args \
            and dotted(dec.args[0]) in jit_names:
        pass                                   # @partial(jax.jit, ...)
    else:
        return None
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in kwargs:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
    return names, nums


def _param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def _jitted_functions(tree: ast.Module):
    """Yield (FunctionDef, traced-param set) for every jit-decorated def."""
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            spec = _jit_decorator(dec)
            if spec is None:
                continue
            static_names, static_nums = spec
            params = _param_names(node)
            positional = ([p.arg for p in node.args.posonlyargs]
                          + [p.arg for p in node.args.args])
            for i in static_nums:
                if 0 <= i < len(positional):
                    static_names.add(positional[i])
            traced = [p for p in params
                      if p not in static_names and p != "self"]
            yield node, set(traced)
            break


def _mutable_globals(tree: ast.Module) -> Set[str]:
    """Module-level names it is hazardous for a jitted fn to close over:
    bound to a mutable literal, rebound 2+ times, or `global`-assigned."""
    bind_counts: dict = {}
    mutable: Set[str] = set()

    def note(target: ast.AST, value: Optional[ast.AST]):
        if isinstance(target, ast.Name):
            bind_counts[target.id] = bind_counts.get(target.id, 0) + 1
            if value is not None and isinstance(value, _MUTABLE_LITERALS):
                mutable.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                note(elt, None)

    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                note(t, stmt.value)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            note(stmt.target, stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            note(stmt.target, None)
            if isinstance(stmt.target, ast.Name):
                mutable.add(stmt.target.id)    # rebinding in place
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            mutable.update(node.names)
    mutable.update(n for n, c in bind_counts.items() if c > 1)
    return mutable


def _local_names(fn: ast.FunctionDef) -> Set[str]:
    """Names bound inside the function body (params included)."""
    names = set(_param_names(fn))
    if fn.args.vararg:
        names.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        names.add(fn.args.kwarg.arg)
    for node in walk_skipping_functions(fn):
        if isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
    # nested defs are skipped by the walker above but still bind a name
    for node in ast.iter_child_nodes(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
    return names


@register
class JitMutableClosure(Rule):
    """JIT001: jitted functions must not close over mutable state."""

    id = "JIT001"
    severity = Severity.WARNING
    title = ("@jax.jit functions must not read mutable module globals "
             "or mutate object attributes — trace-time values are "
             "baked into the executable")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        hazards = _mutable_globals(ctx.tree)
        for fn, _traced in _jitted_functions(ctx.tree):
            local = _local_names(fn)
            for node in walk_skipping_functions(fn):
                if isinstance(node, ast.Name) and isinstance(
                        node.ctx, ast.Load) \
                        and node.id in hazards and node.id not in local:
                    yield self.finding(
                        ctx, node,
                        f"jitted `{fn.name}` reads mutable module "
                        f"global `{node.id}`: its trace-time value is "
                        f"frozen into the compiled fn — pass it as an "
                        f"argument instead")
                elif isinstance(node, ast.Attribute) and isinstance(
                        node.ctx, (ast.Store, ast.Del)):
                    yield self.finding(
                        ctx, node,
                        f"jitted `{fn.name}` assigns attribute "
                        f"`{ast.unparse(node)}`: the write happens once "
                        f"at trace time, not per call — return the "
                        f"value instead")
                elif isinstance(node, ast.Global):
                    yield self.finding(
                        ctx, node,
                        f"jitted `{fn.name}` declares `global "
                        f"{', '.join(node.names)}`: side effects under "
                        f"trace run once, at trace time")


@register
class JitPythonBranchOnTracer(Rule):
    """JIT002: no Python if/while on traced arguments."""

    id = "JIT002"
    severity = Severity.WARNING
    title = ("Python if/while on a traced @jax.jit argument — use "
             "jnp.where / lax.cond, or mark the argument static")

    @staticmethod
    def _names_outside_is_compares(test: ast.AST) -> Set[str]:
        """Names used in ``test``, minus those only inside ``is [not]
        None``-style identity compares (concrete under trace)."""
        under_is: Set[int] = set()
        for node in ast.walk(test):
            if isinstance(node, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                for sub in ast.walk(node):
                    under_is.add(id(sub))
        return {n.id for n in ast.walk(test)
                if isinstance(n, ast.Name) and id(n) not in under_is}

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn, traced in _jitted_functions(ctx.tree):
            if not traced:
                continue
            for node in walk_skipping_functions(fn):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                used = sorted(
                    self._names_outside_is_compares(node.test) & traced)
                if used:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self.finding(
                        ctx, node,
                        f"`{kind}` on traced argument(s) "
                        f"{', '.join(used)} of jitted `{fn.name}`: "
                        f"Python control flow cannot branch on tracers "
                        f"— use jnp.where/lax.cond or static_argnames")
