"""Drive the rule set over files, directories, or in-memory snippets.

:func:`run_paths` is what the CLI calls; :func:`analyze_source` /
:func:`analyze_sources` exist so fixture tests can feed the exact same
pipeline synthetic files with chosen module names (e.g. a fake
``repro.sim`` module) without touching disk.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.core import (FileContext, Finding, Rule, Severity,
                                 build_context, selected_rules)

#: directories never descended into when expanding path arguments
_SKIP_DIRS = {"__pycache__", ".git", ".hypothesis", ".pytest_cache",
              "node_modules", ".venv", "venv"}


def iter_py_files(paths: Iterable[str]) -> List[str]:
    """Expand files/directories into a sorted, de-duplicated .py list."""
    out: List[str] = []
    seen = set()
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        elif p.endswith(".py"):
            out.append(p)
        else:
            raise FileNotFoundError(f"not a .py file or directory: {p}")
    uniq = []
    for p in out:
        key = os.path.normpath(p)
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def _syntax_finding(path: str, err: SyntaxError) -> Finding:
    return Finding(path=path, line=err.lineno or 1,
                   col=(err.offset or 1) - 1, rule="SYNTAX",
                   severity=Severity.ERROR,
                   message=f"file does not parse: {err.msg}")


def run_contexts(ctxs: List[FileContext], rules: List[Rule],
                 pre: Optional[List[Finding]] = None) -> List[Finding]:
    """Run rules over parsed contexts; apply suppressions; sort."""
    findings: List[Finding] = list(pre or ())
    by_path = {ctx.path: ctx for ctx in ctxs}
    for rule in rules:
        if rule.scope == "project":
            findings.extend(rule.check_project(ctxs))
        else:
            for ctx in ctxs:
                findings.extend(rule.check(ctx))
    kept = []
    for f in findings:
        ctx = by_path.get(f.path)
        if ctx is not None and ctx.directives.suppresses(f.rule, f.line):
            continue
        kept.append(f)
    return sorted(kept)


def run_paths(paths: Iterable[str], *,
              select: Optional[Iterable[str]] = None,
              ignore: Optional[Iterable[str]] = None) -> List[Finding]:
    """Analyze every .py file under ``paths`` with the selected rules."""
    rules = selected_rules(select, ignore)
    ctxs: List[FileContext] = []
    pre: List[Finding] = []
    for path in iter_py_files(paths):
        try:
            ctxs.append(build_context(path))
        except SyntaxError as err:
            pre.append(_syntax_finding(path, err))
    return run_contexts(ctxs, rules, pre)


def analyze_source(source: str, *, path: str = "<snippet>.py",
                   module: str = "",
                   select: Optional[Iterable[str]] = None,
                   ignore: Optional[Iterable[str]] = None) -> List[Finding]:
    """Analyze one in-memory snippet (fixture-test entry point)."""
    return analyze_sources([(path, module, source)], select=select,
                           ignore=ignore)


def analyze_sources(files: Iterable[Tuple[str, str, str]], *,
                    select: Optional[Iterable[str]] = None,
                    ignore: Optional[Iterable[str]] = None) -> List[Finding]:
    """Analyze ``(path, module, source)`` triples as one project — the
    way to exercise cross-file rules (KRN001) from fixtures."""
    rules = selected_rules(select, ignore)
    ctxs: List[FileContext] = []
    pre: List[Finding] = []
    for path, module, source in files:
        try:
            ctxs.append(build_context(path, source=source, module=module))
        except SyntaxError as err:
            pre.append(_syntax_finding(path, err))
    return run_contexts(ctxs, rules, pre)


def severity_counts(findings: List[Finding]) -> Dict[str, int]:
    counts = {str(s): 0 for s in Severity}
    for f in findings:
        counts[str(f.severity)] += 1
    return counts
