"""Framework core for the repro invariant linter.

The repo's reproducibility guarantees — bit-for-bit event ≡ fleet,
numpy ≡ jax in f64, seeded-stream pinning — rest on conventions that no
type checker or test can see directly: *how* code is written (sequential
accumulation, not ``@``), *where* RNGs come from (spawned streams, not
fresh literals), *which* clock a module is allowed to read.  This module
turns those conventions into machine-checked contracts: an AST-based
analysis pass with a rule registry, severity levels, and per-line /
per-file suppressions, built on nothing but ``ast`` + ``tokenize``.

Vocabulary
----------

* A :class:`Rule` inspects one :class:`FileContext` (``scope="file"``) or
  the whole set of parsed files at once (``scope="project"``, for
  cross-file contracts like kernel-triple signature alignment) and yields
  :class:`Finding` objects.
* Rules self-register via the :func:`register` decorator; the registry
  maps rule id → singleton instance.  ``--select`` / ``--ignore`` on the
  CLI filter by id.
* Suppressions and module tags are comment directives, recognised only
  in real comment tokens (``tokenize``-derived, so a ``# repro:`` inside
  a string literal never triggers)::

      x = legacy_call()   # repro: disable=RNG001      (this line only)
      # repro: disable-file=DET002                     (whole file)
      # repro: module-tags=fma-sensitive               (tag the module)

  ``# repro: disable=all`` suppresses every rule on the line.
"""
from __future__ import annotations

import ast
import dataclasses
import enum
import io
import os
import re
import tokenize
from typing import Dict, FrozenSet, Iterable, Iterator, List, Optional, Set


class Severity(enum.IntEnum):
    """Ordered severity levels; the CLI fails on findings >= fail-level."""

    INFO = 10
    WARNING = 20
    ERROR = 30

    @classmethod
    def parse(cls, name: str) -> "Severity":
        try:
            return cls[name.upper()]
        except KeyError:
            raise ValueError(
                f"unknown severity {name!r}; "
                f"expected one of {[s.name.lower() for s in cls]}") from None

    def __str__(self) -> str:          # 'error', not 'Severity.ERROR'
        return self.name.lower()


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""

    path: str
    line: int
    col: int
    rule: str
    severity: Severity
    message: str

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "severity": str(self.severity),
                "message": self.message}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")


# --------------------------------------------------------------------------
# Comment directives: suppressions and module tags
# --------------------------------------------------------------------------
_DIRECTIVE = re.compile(
    r"#\s*repro:\s*(disable-file|disable|module-tags)\s*=\s*"
    r"([A-Za-z0-9_-]+(?:\s*[,\s]\s*[A-Za-z0-9_-]+)*)")


@dataclasses.dataclass
class Directives:
    """Parsed ``# repro:`` comment directives for one file."""

    line_disables: Dict[int, Set[str]] = dataclasses.field(
        default_factory=dict)
    file_disables: Set[str] = dataclasses.field(default_factory=set)
    tags: FrozenSet[str] = frozenset()

    def suppresses(self, rule_id: str, line: int) -> bool:
        if rule_id in self.file_disables or "all" in self.file_disables:
            return True
        on_line = self.line_disables.get(line, ())
        return rule_id in on_line or "all" in on_line


def parse_directives(source: str) -> Directives:
    """Extract directives from comment tokens (strings never match)."""
    out = Directives()
    tags: Set[str] = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(tok.start[0], tok.string) for tok in tokens
                    if tok.type == tokenize.COMMENT]
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out                      # unparseable: ast will report it
    for line, text in comments:
        m = _DIRECTIVE.search(text)
        if not m:
            continue
        kind = m.group(1)
        names = {n for n in re.split(r"[,\s]+", m.group(2)) if n}
        if kind == "disable":
            out.line_disables.setdefault(line, set()).update(names)
        elif kind == "disable-file":
            out.file_disables.update(names)
        else:                           # module-tags
            tags.update(names)
    out.tags = frozenset(tags)
    return out


# --------------------------------------------------------------------------
# File context
# --------------------------------------------------------------------------
def module_name(path: str) -> str:
    """Dotted module name for paths under a ``repro`` package root.

    ``src/repro/sim/events.py`` → ``repro.sim.events``; files outside a
    ``repro`` tree (tests, benchmarks) get an empty module name, which
    makes every module-scoped rule a no-op there.
    """
    parts = os.path.normpath(path).split(os.sep)
    if "repro" not in parts:
        return ""
    parts = parts[parts.index("repro"):]
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


@dataclasses.dataclass
class FileContext:
    """One parsed source file plus its directives, handed to every rule."""

    path: str
    module: str
    source: str
    tree: ast.Module
    directives: Directives

    @property
    def tags(self) -> FrozenSet[str]:
        return self.directives.tags

    def in_module(self, *prefixes: str) -> bool:
        """True when the file's dotted module sits under any prefix."""
        return any(self.module == p or self.module.startswith(p + ".")
                   for p in prefixes)


def build_context(path: str, source: Optional[str] = None,
                  module: Optional[str] = None) -> FileContext:
    """Parse one file into a :class:`FileContext`.

    Raises ``SyntaxError`` if the source does not parse; the runner
    converts that into a ``SYNTAX`` finding rather than crashing.
    """
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    tree = ast.parse(source, filename=path)
    return FileContext(path=path,
                       module=module_name(path) if module is None else module,
                       source=source, tree=tree,
                       directives=parse_directives(source))


# --------------------------------------------------------------------------
# Rules and the registry
# --------------------------------------------------------------------------
class Rule:
    """Base class: subclass, set the class attrs, implement ``check``.

    ``scope="file"`` rules get one :class:`FileContext` per call;
    ``scope="project"`` rules get the whole list at once (after every
    file parsed) for cross-file contracts.
    """

    id: str = ""
    severity: Severity = Severity.WARNING
    title: str = ""
    scope: str = "file"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        return iter(())

    def check_project(self,
                      ctxs: List[FileContext]) -> Iterator[Finding]:
        return iter(())

    def finding(self, ctx: FileContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(path=ctx.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0),
                       rule=self.id, severity=self.severity,
                       message=message)


REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate and add to the global registry."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if inst.id in REGISTRY:
        raise ValueError(f"duplicate rule id {inst.id}")
    REGISTRY[inst.id] = inst
    return cls


def selected_rules(select: Optional[Iterable[str]] = None,
                   ignore: Optional[Iterable[str]] = None) -> List[Rule]:
    """Registry filtered by ``--select`` / ``--ignore`` id lists."""
    ids = sorted(REGISTRY)
    if select:
        want = set(select)
        unknown = want - set(ids)
        if unknown:
            raise ValueError(f"unknown rule id(s): {sorted(unknown)}; "
                             f"known: {ids}")
        ids = [i for i in ids if i in want]
    if ignore:
        ids = [i for i in ids if i not in set(ignore)]
    return [REGISTRY[i] for i in ids]


# --------------------------------------------------------------------------
# Shared AST helpers
# --------------------------------------------------------------------------
def dotted(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain (``np.random.seed``)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted(node.func)


def walk_skipping_functions(node: ast.AST) -> Iterator[ast.AST]:
    """Like ``ast.walk`` over a function body, but does not descend into
    nested function/lambda scopes (their parameters shadow)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        stack.extend(ast.iter_child_nodes(child))
