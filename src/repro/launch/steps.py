"""Step builders + ShapeDtypeStruct input specs for every (arch × shape).

These are the functions the dry-run lowers and the launchers execute:

  * ``train_step``  — fwd + bwd + optimiser update        (train_4k)
  * ``prefill_step``— prompt forward, builds the KV cache (prefill_32k)
  * ``serve_step``  — ONE new token against a seq_len cache (decode shapes)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — shardable,
no device allocation — for every model input, per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as shd
from repro.distributed.context import axis_mapping
from repro.launch.mesh import axis_mapping_for
from repro.models import build_model
from repro.optim import adamw, apply_updates

PyTree = Any


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# --------------------------------------------------------------------------
# Input specs (deliverable e.2)
# --------------------------------------------------------------------------
def batch_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the *batch* argument of the step function."""
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    if shape.mode == "train":
        if cfg.family == "audio":
            return {"frames": sds((b, cfg.enc_seq, cfg.d_model), act),
                    "tokens": sds((b, s + 1), jnp.int32)}
        if cfg.family == "vlm":
            return {"embeds": sds((b, s, cfg.d_model), act),
                    "labels": sds((b, s), jnp.int32)}
        return {"tokens": sds((b, s + 1), jnp.int32)}
    if shape.mode == "prefill":
        if cfg.family == "audio":
            return {"frames": sds((b, cfg.enc_seq, cfg.d_model), act),
                    "tokens": sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            return {"embeds": sds((b, s, cfg.d_model), act)}
        return {"tokens": sds((b, s), jnp.int32)}
    # decode: ONE token; the cache is a separate argument
    return {"token": sds((b, 1), jnp.int32)}


def cache_input_specs(api, shape: InputShape) -> PyTree:
    """ShapeDtypeStructs for the decode cache at seq_len occupancy."""
    shapes = api.cache_shapes(shape.global_batch, shape.seq_len)
    return jax.tree_util.tree_map(
        lambda sd: sds(sd[0], sd[1]), shapes,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def input_specs(cfg: ModelConfig, shape: InputShape, api=None) -> dict:
    """All step inputs as ShapeDtypeStructs (params/opt built separately)."""
    specs = {"batch": batch_input_specs(cfg, shape)}
    if shape.mode == "decode":
        api = api or build_model(cfg)
        specs["cache"] = cache_input_specs(api, shape)
    return specs


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------
def build_train_step(api, opt) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.train_loss, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, metrics
    return train_step


def build_prefill_step(api, max_len: Optional[int] = None) -> Callable:
    def prefill_step(params, batch):
        ml = max_len
        if ml is None:
            key = ("tokens" if "tokens" in batch else
                   "embeds" if "embeds" in batch else "frames")
            ml = batch[key].shape[1]
        return api.prefill(params, batch, ml)
    return prefill_step


def build_serve_step(api) -> Callable:
    def serve_step(params, batch, cache):
        return api.decode_step(params, batch, cache)
    return serve_step


# --------------------------------------------------------------------------
# Jit assembly with shardings (used by dryrun + launchers)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class LoweredStep:
    name: str
    jitted: Any
    arg_specs: tuple            # ShapeDtypeStructs to pass to .lower()
    shard_report: shd.ShardingReport


def _opt_state_specs(opt_state_sds, pspecs):
    """Mirror param specs onto optimiser-state trees (m/v/acc/mu/nu)."""
    def mk(leaf_sds, template_name):
        del leaf_sds, template_name
        return None

    out = {}
    for k, v in opt_state_sds.items():
        if k == "step":
            out[k] = P()
        else:
            out[k] = pspecs
    return out


def assemble(cfg: ModelConfig, shape: InputShape, mesh, *,
             opt=None, seq_shard_cache: bool = False,
             extra_cfg_kw: Optional[dict] = None,
             auto_knobs: bool = True) -> LoweredStep:
    """Build the jitted step + arg ShapeDtypeStructs for one (arch, shape)."""
    if extra_cfg_kw:
        cfg = cfg.replace(**extra_cfg_kw)
        auto_knobs = False            # explicit knobs win (perf experiments)
    api = build_model(cfg)
    mode = "train" if shape.mode == "train" else "serve"
    pshapes = api.param_shapes()
    # §Perf B1 (adopted): FSDP all-gathers cost more than they save for
    # small models — replicate params below 0.5B
    from repro.models import param_count
    import os as _os
    no_fsdp = (mode == "train" and param_count(pshapes) < 5e8
               and not _os.environ.get("REPRO_FORCE_FSDP"))
    pspecs, report = shd.param_specs(cfg, pshapes, mesh, mode=mode,
                                     no_fsdp=no_fsdp)
    params_sds = jax.eval_shape(api.init_params, jax.random.key(0))
    bspecs = shd.batch_specs(cfg, jax.tree_util.tree_map(
        lambda x: x.shape, batch_input_specs(cfg, shape)), mesh)
    batch_sds = batch_input_specs(cfg, shape)
    named = lambda t: shd.to_named(t, mesh)
    mapping = axis_mapping_for(mesh)

    def with_mapping(fn):
        # the mapping must be active while the function is TRACED (at
        # .lower()), not merely when jax.jit is constructed
        def wrapped(*args):
            with axis_mapping(mapping, mesh=mesh):
                return fn(*args)
        return wrapped

    if shape.mode == "train":
        axis = dict(mesh.shape)
        tp = axis.get("model", 1)
        kw = {}
        # §Perf C: SP's residual all-gathers around the MoE shard_map cost
        # ~15x more collective time than they save — SP is dense-only
        if auto_knobs and tp > 1 and shape.seq_len % tp == 0 \
                and not cfg.seq_parallel and not cfg.num_experts:
            kw["seq_parallel"] = True        # Megatron-SP residual stream
        if auto_knobs and not cfg.loss_chunk and cfg.vocab_size >= 32000:
            kw["loss_chunk"] = 512           # chunked vocab-parallel xent
        if kw:
            cfg = cfg.replace(**kw)
            api = build_model(cfg)
            pspecs, report = shd.param_specs(cfg, api.param_shapes(), mesh,
                                             mode=mode, no_fsdp=no_fsdp)
            params_sds = jax.eval_shape(api.init_params, jax.random.key(0))
        opt = opt or adamw(1e-4)
        step = build_train_step(api, opt)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = _opt_state_specs(opt_sds, pspecs)
        jitted = jax.jit(
            with_mapping(step),
            in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
            out_shardings=(named(pspecs), named(ospecs), None, None),
            donate_argnums=(0, 1),
        )
        return LoweredStep(f"{cfg.name}/{shape.name}/train", jitted,
                           (params_sds, opt_sds, batch_sds), report)

    if shape.mode == "prefill":
        step = build_prefill_step(api, max_len=shape.seq_len)
        jitted = jax.jit(
            with_mapping(step),
            in_shardings=(named(pspecs), named(bspecs)))
        return LoweredStep(f"{cfg.name}/{shape.name}/prefill", jitted,
                           (params_sds, batch_sds), report)

    # decode
    step = build_serve_step(api)
    cache_sds = cache_input_specs(api, shape)
    cspecs = shd.cache_specs(
        cfg, jax.tree_util.tree_map(lambda x: (x.shape, x.dtype), cache_sds),
        mesh, seq_shard=seq_shard_cache)
    jitted = jax.jit(
        with_mapping(step),
        in_shardings=(named(pspecs), named(bspecs), named(cspecs)),
        out_shardings=(None, named(cspecs)),
        donate_argnums=(2,),
    )
    return LoweredStep(f"{cfg.name}/{shape.name}/decode", jitted,
                       (params_sds, batch_sds, cache_sds), report)


def arch_shape_cfg(cfg: ModelConfig, shape: InputShape) -> Optional[ModelConfig]:
    """Shape-dependent config adaptation + skip policy (DESIGN.md §4).

    Returns the adapted config, or None if the pair is skipped.
    """
    if shape.name.startswith("long_500k"):
        if cfg.family == "audio":
            return None               # principled skip (DESIGN.md §4)
        if cfg.family in ("dense", "vlm"):
            # sliding-window variant bounds cache memory at 512k context
            return cfg.with_window(8192)
    return cfg
