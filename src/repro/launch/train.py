"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 200 [--reduced] [--debug-mesh]

On this CPU host use ``--reduced`` (family-faithful small config); the full
configs are exercised via the dry-run.  ``--debug-mesh`` runs the real
pjit path on a tiny forced-host-device mesh.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="4 forced host devices, (2,2) mesh pjit path")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.debug_mesh:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

    import jax

    from repro.configs import get_config, reduced_config
    from repro.configs.base import InputShape
    from repro.train import TrainConfig, train

    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    if not args.debug_mesh:
        cfg = cfg.replace(dtype="float32")
    tcfg = TrainConfig(steps=args.steps, batch_size=args.batch_size,
                       seq_len=args.seq_len, lr=args.lr,
                       ckpt_every=50 if args.ckpt_dir else 0,
                       ckpt_dir=args.ckpt_dir or "checkpoints")

    if args.debug_mesh:
        from repro.data.synthetic import train_batch
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import assemble
        mesh = make_debug_mesh(4)
        shape = InputShape("debug", args.seq_len, args.batch_size, "train")
        step = assemble(cfg, shape, mesh, auto_knobs=False)
        with mesh:
            api_params = None
            res = train(cfg, tcfg,
                        jit_step=step.jitted,
                        batch_fn=lambda i: train_batch(
                            cfg, args.batch_size, args.seq_len, seed=i))
    else:
        res = train(cfg, tcfg)
    print(f"[train] {args.arch}: loss {res.losses[0]:.3f} -> "
          f"{res.losses[-1]:.3f} at {res.steps_per_s:.2f} steps/s")


if __name__ == "__main__":
    main()
