import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape) pair on the production
mesh — 16×16 single-pod and 2×16×16 multi-pod — and records
``memory_analysis()`` / ``cost_analysis()`` / collective schedule for the
roofline (deliverable g).  The two os.environ lines above MUST stay the very
first statements: JAX locks the device count on first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k [--multi-pod] [--out EXPERIMENTS/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import arch_shape_cfg, assemble
from repro.roofline import analyze, collective_bytes, model_flops_estimate


def _legalization_bytes(hlo: str, arg_specs, mesh, temp_bytes: int = 0) -> int:
    """Estimate bytes of XLA:CPU bf16→f32 legalisation copies.

    Finds ``f32[dims] convert`` results whose dims match a bf16 argument
    leaf under every possible per-device sharding factor (divisors of the
    mesh axis sizes), counting each distinct shape once.
    """
    import itertools
    import re

    import numpy as np

    conv_names = re.findall(
        r"(%[\w.-]+) = f32\[([0-9,]+)\][^ ]* convert\(", hlo)
    conv_count: dict[str, set] = {}
    for name, dims in conv_names:
        conv_count.setdefault(dims, set()).add(name)
    conv_shapes = set(conv_count)
    if not conv_shapes:
        return 0
    axis_sizes = list(mesh.devices.shape)
    factors = {1}
    for r in range(1, len(axis_sizes) + 1):
        for combo in itertools.combinations(axis_sizes, r):
            factors.add(int(np.prod(combo)))
    total = 0
    leaves = jax.tree_util.tree_leaves(arg_specs)
    bf16_shapes: dict[tuple, int] = {}
    for l in leaves:
        if getattr(l, "dtype", None) == jnp_bf16 \
                and np.prod(l.shape) * 2 > 64 * 2**20:
            t = tuple(l.shape)
            bf16_shapes[t] = bf16_shapes.get(t, 0) + 1
    # bf16 TEMPS defined in the HLO itself (e.g. scan carry stacks) whose
    # f32 convert twins are likewise CPU legalisation artefacts
    for dims in set(re.findall(r"= bf16\[([0-9,]+)\]", hlo)):
        shape = tuple(int(d) for d in dims.split(","))
        if np.prod(shape) * 2 > 64 * 2**20:
            bf16_shapes.setdefault(shape, 1)
    # only clearly-dominant long-lived copies qualify (transient per-layer
    # converts share buffers and must not be double-subtracted)
    floor = max(64 * 2**20, int(0.25 * temp_bytes))
    for dims in conv_shapes:
        shape = tuple(int(d) for d in dims.split(","))
        size_f32 = int(np.prod(shape)) * 4
        if size_f32 < floor:
            continue
        for g, n_leaves in bf16_shapes.items():
            if len(g) != len(shape):
                continue
            ratio = 1
            okay = True
            for a, b in zip(g, shape):
                if b == 0 or a % b:
                    okay = False
                    break
                ratio *= a // b
            if okay and ratio in factors:
                # one live copy per matching arg leaf, capped by the number
                # of distinct convert instances in the HLO
                total += size_f32 * min(n_leaves, len(conv_count[dims]))
                break
    if temp_bytes:
        total = min(total, int(0.9 * temp_bytes))
    return total


import jax.numpy as _jnp  # noqa: E402
jnp_bf16 = _jnp.bfloat16


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            seq_shard_cache: bool = False, extra_cfg_kw=None,
            verbose: bool = True) -> dict:
    """Lower+compile one (arch, shape, mesh). Returns the result record."""
    base_cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_shape_cfg(base_cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if cfg is None:
        rec["status"] = "skipped"
        rec["reason"] = "principled skip (DESIGN.md §4)"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    # perf_counter, not time.time: these are durations, and wall-clock
    # steps (NTP slew) would corrupt the lower/compile split
    t0 = time.perf_counter()
    try:
        step = assemble(cfg, shape, mesh, seq_shard_cache=seq_shard_cache,
                        extra_cfg_kw=extra_cfg_kw)
        with mesh:
            lowered = step.jitted.lower(*step.arg_specs)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            from repro.roofline import normalize_cost_analysis
            cost = normalize_cost_analysis(compiled.cost_analysis())
            hlo = compiled.as_text()
    except Exception as e:                         # noqa: BLE001
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} FAILED: {rec['error']}")
        return rec

    rec["status"] = "ok"
    rec["sharding"] = step.shard_report.summary()
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    args_b = rec["memory"].get("argument_size_in_bytes", 0)
    temp_b = rec["memory"].get("temp_size_in_bytes", 0)
    alias_b = rec["memory"].get("alias_size_in_bytes", 0)
    out_b = rec["memory"].get("output_size_in_bytes", 0)
    per_dev = args_b + temp_b + out_b - alias_b
    rec["bytes_per_device"] = int(per_dev)
    # XLA:CPU legalises bf16 dot operands by materialising f32 copies of
    # big bf16 buffers (caches, stacked weights) — copies that do NOT exist
    # on the TPU target (native bf16 MXU).  Subtract f32 convert results
    # whose shape matches a bf16 input leaf (each counted once); report
    # both raw and TPU-adjusted numbers (convention noted in EXPERIMENTS.md).
    rec["cpu_legalization_bytes"] = int(
        _legalization_bytes(hlo, step.arg_specs, mesh, temp_b))
    adj = per_dev - rec["cpu_legalization_bytes"]
    rec["bytes_per_device_tpu_adjusted"] = int(adj)
    rec["fits_hbm16"] = bool(adj < 16 * 2**30)
    rec["fits_hbm16_raw"] = bool(per_dev < 16 * 2**30)
    mf = model_flops_estimate(cfg, shape)
    roof = analyze(f"{arch}/{shape_name}", cost, hlo, chips=chips,
                   model_flops=mf)
    rec["roofline"] = roof.row()
    rec["collectives"] = collective_bytes(hlo)
    from repro.roofline_hlo import corrected_costs
    cc = corrected_costs(hlo)
    rec["hlo_parsed"] = {"flops": cc["flops"],
                         "bytes_noreuse_bound": cc["bytes"],
                         "cost_analysis_flops": float(cost.get("flops", 0)),
                         "cost_analysis_bytes": float(
                             cost.get("bytes accessed", 0))}
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} ({rec['mesh']}): OK "
              f"compile={t_compile:.0f}s mem/dev={per_dev/2**30:.2f}GiB "
              f"dominant={roof.dominant} "
              f"terms=({roof.compute_s:.2e},{roof.memory_s:.2e},"
              f"{roof.collective_s:.2e})s")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--seq-shard-cache", action="store_true",
                    help="sequence-parallel KV cache (perf variant)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shp in shapes:
                results.append(run_one(arch, shp, multi_pod=mp,
                                       seq_shard_cache=args.seq_shard_cache))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skipped" for r in results)
    fail = sum(r["status"] == "FAILED" for r in results)
    print(f"[dryrun] done: {ok} ok, {skip} skipped, {fail} failed "
          f"of {len(results)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
