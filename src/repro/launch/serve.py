"""Serving launcher: batched requests against a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 8 --max-new 16
"""
import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import reduced_config
    from repro.serve import Request, ServeEngine

    cfg = reduced_config(args.arch).replace(dtype="float32")
    engine = ServeEngine(cfg, batch_size=args.batch_size,
                         max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new,
                    # virtual arrival stamps: only their order matters,
                    # and seeded launcher runs stay reproducible
                    arrived_at=i * 1e-3)
            for i in range(args.requests)]
    done = engine.serve(reqs)
    st = engine.stats
    print(f"[serve] {args.arch}: {st.served} requests, "
          f"{st.tokens_out} tokens, {st.tokens_per_s:.1f} tok/s decode, "
          f"prefill {st.prefill_s:.2f}s decode {st.decode_s:.2f}s")
    assert all(r.output is not None for r in done)


if __name__ == "__main__":
    main()
