"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
import, and smoke tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices: int = 4):
    """Tiny mesh for CPU-subprocess dry-run tests (2 × devices//2)."""
    return jax.make_mesh((devices // 2, 2), ("data", "model"))


def axis_mapping_for(mesh) -> dict:
    """Logical→mesh axis mapping used by sharding constraints."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    return {"dp": dp, "tp": ("model",) if "model" in names else (),
            "sp": ("data",) if "data" in names else ()}
