"""Compile fitted profiling regressors to pure array form.

``PredictorCost`` evaluates its regressor through ``model.predict`` —
arbitrary host Python as far as the decision kernels are concerned.
This module closes that gap: :func:`lower_predictor` maps each fitted
regressor family onto an equivalent array program —

  * :class:`~repro.core.predictors.linear.RidgeRegressor` → one f64
    standardise + dot;
  * :class:`~repro.core.predictors.mlp.MLPRegressor` → the jitted f32
    matmul chain (the exact forward the host ``predict`` runs eagerly);
  * :class:`~repro.core.predictors.gbt.GBTRegressor` /
    ``MultiTargetGBT`` → flattened ``(feature, threshold_bin, left,
    right, value)`` node arrays walked by the vectorised
    level-synchronous descent in :mod:`repro.kernels.tree_predict`
    (jitted XLA, *bit-for-bit* with the host ensemble in f64, or the
    fused Pallas batched tree-inference kernel within f32 tolerance) —

and :class:`LoweredLayerTimes` packages the lowered model together with
a ``PredictorCost``'s feature function so the accelerator decision
backends (:mod:`repro.kernels.decide_split.ops`) can reconstruct the
per-layer device/edge time vectors on their own, which is what lets
``decide_all(cost=PredictorCost(...), backend="jax"|"pallas")`` run
predictor-driven sweeps without ever calling back into host Python.

Models outside these families still raise ``TypeError`` from
:func:`lower_predictor` — their ``predict`` evaluates host-side and
cannot lower; use ``backend="numpy"``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.predictors.gbt import GBTRegressor, MultiTargetGBT
from repro.core.predictors.linear import RidgeRegressor
from repro.core.predictors.mlp import MLPRegressor
from repro.kernels.tree_predict.ops import predict_trees
from repro.kernels.tree_predict.ref import TreeArrays, flatten_gbt


class LoweredPredictor:
    """A fitted regressor compiled to array form.  ``predict`` mirrors
    the host model's ``predict`` surface (``[N, F] -> [N]`` or
    ``[N, T]``) but evaluates as jitted XLA (``backend="jax"``) or the
    fused Pallas tree kernel (``backend="pallas"``, trees only)."""

    #: backends this lowered form supports
    backends: tuple[str, ...] = ("jax",)

    def predict(self, x: np.ndarray, *, backend: str = "jax") -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class LoweredLinear(LoweredPredictor):
    """Ridge: standardise + augmented dot, all f64 (matches the host
    ``xs @ w_`` up to BLAS-vs-XLA accumulation order — last-ulp)."""
    x_mu: np.ndarray
    x_sd: np.ndarray
    w: np.ndarray                       # [F+1, T]

    @classmethod
    def lower(cls, model: RidgeRegressor) -> "LoweredLinear":
        return cls(model.x_mu_, model.x_sd_, model.w_)

    def predict(self, x: np.ndarray, *, backend: str = "jax") -> np.ndarray:
        _require_jax_backend(self, backend)
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        with enable_x64():
            xs = (jnp.asarray(np.asarray(x, np.float64))
                  - jnp.asarray(self.x_mu)) / jnp.asarray(self.x_sd)
            xs = jnp.concatenate(
                [xs, jnp.ones((xs.shape[0], 1), xs.dtype)], axis=1)
            out = np.asarray(xs @ jnp.asarray(self.w), np.float64)
        return out


@dataclasses.dataclass
class LoweredMLP(LoweredPredictor):
    """MLP: the jitted twin of the host forward (f32 matmul chain, f32
    destandardisation — the host path's exact dtypes)."""
    params: dict
    n_layers: int
    x_mu: Optional[np.ndarray]
    x_sd: Optional[np.ndarray]
    y_mu: Optional[np.ndarray]
    y_sd: Optional[np.ndarray]

    @classmethod
    def lower(cls, model: MLPRegressor) -> "LoweredMLP":
        std = model.standardize
        return cls(dict(model.params_), model.n_layers_,
                   model.x_mu_ if std else None,
                   model.x_sd_ if std else None,
                   model.y_mu_ if std else None,
                   model.y_sd_ if std else None)

    def _jitted(self):
        fn = getattr(self, "_fwd", None)
        if fn is None:
            import jax
            import jax.numpy as jnp
            params = {k: jnp.asarray(v, jnp.float32)
                      for k, v in self.params.items()}
            n_layers = self.n_layers

            def fwd(x):
                return MLPRegressor._forward(params, x, n_layers)

            fn = jax.jit(fwd)
            self._fwd = fn
        return fn

    def predict(self, x: np.ndarray, *, backend: str = "jax") -> np.ndarray:
        _require_jax_backend(self, backend)
        import jax.numpy as jnp
        x = np.asarray(x, np.float32)
        if self.x_mu is not None:
            x = (x - self.x_mu) / self.x_sd
        pred = np.asarray(self._jitted()(jnp.asarray(x)))
        if self.y_mu is not None:
            pred = pred * self.y_sd + self.y_mu
        return pred


@dataclasses.dataclass
class LoweredTrees(LoweredPredictor):
    """GBT ensemble over flattened node arrays — one :class:`TreeArrays`
    per target, dispatched through :mod:`repro.kernels.tree_predict`."""
    arrays: tuple[TreeArrays, ...]
    multi_target: bool

    backends = ("jax", "pallas")

    @classmethod
    def lower(cls, model) -> "LoweredTrees":
        if isinstance(model, MultiTargetGBT):
            return cls(tuple(flatten_gbt(m) for m in model.models_), True)
        return cls((flatten_gbt(model),), False)

    def predict(self, x: np.ndarray, *, backend: str = "jax") -> np.ndarray:
        cols = [predict_trees(x, a, backend=backend) for a in self.arrays]
        if not self.multi_target:
            return cols[0]
        return np.stack(cols, axis=1)


def _require_jax_backend(lowered, backend: str) -> None:
    if backend not in lowered.backends:
        raise ValueError(
            f"{type(lowered).__name__} supports backends "
            f"{lowered.backends}, got {backend!r} (only tree ensembles "
            "have a fused Pallas inference kernel; dense models already "
            "run as one jitted XLA op)")


_LOWERINGS: list[tuple[type, Callable]] = [
    (RidgeRegressor, LoweredLinear.lower),
    (MLPRegressor, LoweredMLP.lower),
    (GBTRegressor, LoweredTrees.lower),
    (MultiTargetGBT, LoweredTrees.lower),
]


def lower_predictor(model) -> LoweredPredictor:
    """Fitted regressor → :class:`LoweredPredictor`, or ``TypeError``
    if the model is not one of the lowerable families (its ``predict``
    is arbitrary host-side Python — use ``backend='numpy'``).

    Memoised on the model instance (flattening a tree ensemble and
    compiling its descent is the expensive part): treat fitted models
    as immutable, and build a fresh model per refit — the convention
    every identity-keyed memo in this codebase already relies on.
    """
    cached = getattr(model, "_lowered_", None)
    if cached is not None:
        return cached
    for klass, lowering in _LOWERINGS:
        if type(model) is klass:
            lowered = lowering(model)
            try:
                model._lowered_ = lowered
            except (AttributeError, TypeError):
                pass                     # slotted/frozen model: no memo
            return lowered
    raise TypeError(
        f"{type(model).__name__} does not lower to array form: its "
        "predict evaluates host-side, so predictor-driven decisions "
        "must use backend='numpy' (lowerable: RidgeRegressor, "
        "MLPRegressor, GBTRegressor, MultiTargetGBT)")


# --------------------------------------------------------------------------
# The layer-times seam the accelerator decision backends consume
# --------------------------------------------------------------------------
@dataclasses.dataclass
class LoweredLayerTimes:
    """Per-layer device/edge execution times from a lowered predictor.

    The accelerator twin of ``PredictorCost.layer_times``: features are
    built host-side by the same ``feature_fn`` (cheap, O(L)), inference
    runs through the lowered model, and the result replays the host
    pipeline op-for-op — multi-target column select, clamp to ≥ 0, and
    the oracle's affine residual correction ``t*gain + bias`` (identity
    short-circuited, re-clamped otherwise) — so the jax decide backend
    stays bit-for-bit with the host for tree models.  Memoised on the
    layers object identity, mirroring the host memo: one predict per
    decision sweep.
    """
    predictor: LoweredPredictor
    feature_fn: Callable
    device: object                      # DeviceSpec
    edge: object
    target_index: int = 0
    correction: tuple[float, float] = (1.0, 0.0)

    def __post_init__(self):
        self._cache: tuple = (None, None, None)

    def times(self, layers: Sequence, *, backend: str = "jax"
              ) -> tuple[np.ndarray, np.ndarray]:
        """``(t_dev [L], t_edge [L])`` f64 — the lowered twin of the
        host ``PredictorCost.layer_times`` + correction."""
        cached = self._cache
        if cached[0] is layers and cached[1] == backend:
            return cached[2]
        feats = np.concatenate([self.feature_fn(layers, self.device),
                                self.feature_fn(layers, self.edge)], axis=0)
        pred = np.asarray(self.predictor.predict(feats, backend=backend),
                          np.float64)
        if pred.ndim == 2:
            pred = pred[:, self.target_index]
        pred = np.maximum(pred, 0.0)
        gain, bias = self.correction
        if gain != 1.0 or bias != 0.0:
            pred = np.maximum(pred * gain + bias, 0.0)
        out = (pred[:len(layers)], pred[len(layers):])
        self._cache = (layers, backend, out)
        return out


def lower_layer_times(cost, correction: tuple[float, float] = (1.0, 0.0)
                      ) -> LoweredLayerTimes:
    """Lower a ``PredictorCost``-shaped cost model's layer-time pipeline
    (raises ``TypeError`` through :func:`lower_predictor` when the
    wrapped regressor has no array form)."""
    return LoweredLayerTimes(lower_predictor(cost.model), cost.feature_fn,
                             cost.device, cost.edge,
                             target_index=cost.target_index,
                             correction=correction)
