"""repro.oracle — accelerator-lowered predictor serving with online
profiling-in-the-loop.

Closes the paper's loop end to end: fitted profiling regressors are
*compiled to pure array form* (``lowered``) so predictor-driven
offloading sweeps run on ``backend="jax"``/``"pallas"`` next to the
model; live ``(features, realised time)`` observations from the
streaming simulator feed an ``OnlineOracle`` (``online``) that applies
an always-on cheap residual correction, detects drift with a
Page–Hinkley test on normalised residuals, and refits on trigger; and a
versioned ``PredictorRegistry`` (``registry``) snapshots every
published model with an atomic current-pointer swap so serving never
observes a half-written predictor.

Seams (pinned by ``tests/test_oracle.py``):

  * lowered  — ``lower_predictor`` / ``LoweredLayerTimes``: ridge → dot,
               MLP → jitted matmul chain, GBT → flattened node arrays
               through :mod:`repro.kernels.tree_predict`
  * online   — ``OnlineOracle`` + ``OracleCost`` (the CostModel the
               streaming scheduler plugs in), ``PageHinkley``
  * registry — ``PredictorRegistry`` versioned snapshots, optional
               on-disk persistence via ``repro.core.predictors.persist``
"""
from repro.oracle.lowered import (LoweredLayerTimes, lower_layer_times,
                                  lower_predictor)
from repro.oracle.online import OnlineOracle, OracleCost, PageHinkley
from repro.oracle.registry import PredictorRegistry, Snapshot

__all__ = [
    "LoweredLayerTimes",
    "lower_layer_times",
    "lower_predictor",
    "OnlineOracle",
    "OracleCost",
    "PageHinkley",
    "PredictorRegistry",
    "Snapshot",
]
