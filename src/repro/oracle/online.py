"""Online profiling-in-the-loop: drift detection, residual correction,
window refits.

The offline loop fits a predictor once on a static ``ProfileRecord``
dataset; this module keeps it honest while it serves.  An
:class:`OnlineOracle` ingests ``(features, realised_time)`` observations
— in a streaming run, one per :func:`repro.sim.stream.simulate_stream`
completion event — into a sliding window and runs three mechanisms on
the prediction residuals:

  * **always-on cheap correction** — an EWMA affine map ``t·gain +
    bias`` over the predictor's output (multiplicative ``gain`` tracks
    machine-speed drift, additive ``bias`` tracks constant offsets),
    updated per observation for a few flops.  Residuals inside
    ``deadband`` (float noise from ``finish − start`` round trips) leave
    the correction *exactly* at identity, which is what makes a
    no-drift streaming run bit-for-bit identical to the oracle-free
    path.
  * **Page–Hinkley drift detection** — two-sided PH test on normalised
    residuals: cumulative deviation from the running mean beyond
    ``ph_delta``, drift when the excursion exceeds ``ph_lambda``.
  * **full refit on drift** — a fresh clone of the current model is
    refit on the observation window, published to the versioned
    :class:`~repro.oracle.registry.PredictorRegistry` (atomic swap), and
    the detector/correction reset — the paper's continuous-profiling
    loop closed.

:class:`OracleCost` is the :class:`~repro.core.costs.CostModel` face of
the oracle: a ``PredictorCost`` whose model tracks the registry's
current version and whose predictions pass through the live correction,
so every consumer — ``decide_all`` sweeps (any backend), scheduler ETC
rows, serving engines — picks up refits at the next call.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.costs import (AccelSpec, PredictorCost,
                              default_layer_features)
from repro.core.offload import DEFAULT_EFFICIENCY, LayerCost
from repro.core.predictors.common import normalised_rmse
from repro.obs.trace import NULL_TRACER
from repro.oracle.registry import PredictorRegistry


@dataclasses.dataclass
class PageHinkley:
    """Two-sided Page–Hinkley change detector on a residual stream.

    The raw residuals are standardised online (Welford running
    mean/variance) so ``delta``/``lamb`` are in *sigma units* and one
    parameterisation works across predictors of very different innate
    accuracy: the cumulative deviation of the z-scored signal from its
    running mean (minus/plus the drift allowance ``delta``) is tracked
    against its running extremum, and drift fires when the excursion
    exceeds ``lamb``.  A drift-free unit-variance stream drifts the
    statistic *down* by ``delta`` per step, bounding false alarms; a
    sustained mean shift of ``k`` sigmas crosses ``lamb`` in about
    ``lamb / (min(k, z_clip) - delta)`` observations.  ``min_samples``
    suppresses triggers before the variance estimate is meaningful, and
    ``z_clip`` bounds any single observation's contribution — early
    variance estimates are noisy and profiling residuals heavy-tailed,
    and without the clip a couple of outliers can fake a mean shift.
    """
    delta: float = 0.05
    lamb: float = 30.0
    min_samples: int = 50
    z_clip: float = 8.0

    def __post_init__(self):
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0          # Welford sum of squared deviations
        self._m_lo = 0.0        # cumulative (z - delta), mean-rose side
        self._m_hi = 0.0        # cumulative (z + delta), mean-fell side
        self._lo_min = 0.0
        self._hi_max = 0.0

    @property
    def std(self) -> float:
        if self.n < 2:
            return 0.0
        return float(np.sqrt(self._m2 / (self.n - 1)))

    def update(self, x: float) -> bool:
        """Feed one residual; returns True when drift is detected."""
        x = float(x)
        # z-score against the statistics *before* this sample, so a
        # genuine jump is not absorbed into its own baseline
        z = 0.0 if self.n < 2 or self._m2 <= 0.0 \
            else (x - self.mean) / self.std
        z = min(max(z, -self.z_clip), self.z_clip)
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self._m2 += d * (x - self.mean)
        self._m_lo += z - self.delta
        self._m_hi += z + self.delta
        self._lo_min = min(self._lo_min, self._m_lo)
        self._hi_max = max(self._hi_max, self._m_hi)
        if self.n < self.min_samples:
            return False
        return (self._m_lo - self._lo_min > self.lamb      # mean rose
                or self._hi_max - self._m_hi > self.lamb)  # mean fell


class OnlineOracle:
    """Serves a fitted profiling predictor while learning from realised
    completion times (see the module docstring for the mechanisms).

    ``model`` is the initially-fitted regressor (published as version 0
    of ``registry``); ``device``/``edge`` and ``feature_fn`` define the
    feature space exactly as for :class:`~repro.core.costs.
    PredictorCost`.  ``correction`` is ``"gain"`` (multiplicative EWMA,
    the machine-slowdown model), ``"bias"`` (additive EWMA) or
    ``"none"``.  Set ``telemetry`` (or let ``simulate_stream`` set it)
    to stream counters/gauges into a :class:`repro.sim.telemetry.
    Telemetry`.
    """

    def __init__(self, model, device, edge, *,
                 feature_fn=default_layer_features, target_index: int = 0,
                 window: int = 512, min_refit: int = 64,
                 alpha: float = 0.05, max_ratio: float = 8.0,
                 correction: str = "gain", deadband: float = 1e-9,
                 detector: Optional[PageHinkley] = None,
                 registry: Optional[PredictorRegistry] = None,
                 refit_on_drift: bool = True):
        if correction not in ("gain", "bias", "none"):
            raise ValueError(f"unknown correction {correction!r}; "
                             "use 'gain', 'bias' or 'none'")
        self.device = device
        self.edge = edge
        self.feature_fn = feature_fn
        self.target_index = target_index
        self.window = window
        self.min_refit = min_refit
        self.alpha = float(alpha)
        self.max_ratio = float(max_ratio)
        self.correction = correction
        self.deadband = float(deadband)
        self.refit_on_drift = refit_on_drift
        self.detector = detector if detector is not None else PageHinkley()
        self.registry = registry if registry is not None \
            else PredictorRegistry()
        if self.registry.version < 0:
            self.registry.publish(model, tag="initial")
        self.gain = 1.0
        self.bias = 0.0
        self._obs_x: deque = deque(maxlen=window)
        self._obs_y: deque = deque(maxlen=window)
        self._residuals: deque = deque(maxlen=window)
        self._window_pred: deque = deque(maxlen=window)
        self.observations = 0
        self.drift_triggers = 0
        self.refits = 0
        self._refit_pending = False
        self.telemetry = None
        self.obs = NULL_TRACER                 # set by simulate_stream

    # -- serving ----------------------------------------------------------
    @property
    def model(self):
        return self.registry.current().model

    @property
    def version(self) -> int:
        return self.registry.version

    def cost_model(self) -> "OracleCost":
        """The CostModel face: plug into ``decide_all(cost=...)``,
        ``etc_matrix``, ``StreamScheduler``, serving engines."""
        return OracleCost(self)

    def correct(self, t: np.ndarray) -> np.ndarray:
        """Apply the live affine residual correction (identity is
        short-circuited so an untouched oracle is bit-transparent)."""
        if self.gain == 1.0 and self.bias == 0.0:
            return t
        return np.maximum(t * self.gain + self.bias, 0.0)

    # -- ingestion --------------------------------------------------------
    def observe(self, features: np.ndarray, realised_s: float,
                predicted_s: Optional[float] = None, *,
                refit_y: Optional[float] = None, now: float = 0.0) -> dict:
        """Ingest one ``(features, realised_time)`` observation.

        ``predicted_s`` is what the serving path actually predicted for
        this work (pass the recorded value when available — recomputing
        may disagree in the last ulp); ``refit_y`` overrides the target
        stored for refits (default ``realised_s``).  Returns
        ``{"residual", "drift", "refit_version"}``.
        """
        features = np.asarray(features, np.float64).ravel()
        if predicted_s is None:
            predicted_s = float(self.predict_one(features))
        realised_s = float(realised_s)
        self.observations += 1
        self._count("oracle_observations")
        self._obs_x.append(features)
        self._obs_y.append(realised_s if refit_y is None else float(refit_y))
        self._window_pred.append((predicted_s, realised_s))
        scale = max(abs(predicted_s), 1e-12)
        r = (realised_s - predicted_s) / scale
        self._residuals.append(r)
        if abs(r) > self.deadband:
            # cheap always-on correction: EWMA of the observed
            # ratio/offset against the *uncorrected* prediction (the
            # served value has the current correction folded in —
            # tracking against it would converge to the square root of
            # the true ratio).  Inside the deadband the correction
            # stays *exactly* identity.
            if self.correction == "gain" and self.gain > 0:
                raw = predicted_s / self.gain
                if raw > 0 and realised_s > 0:
                    # EWMA in log space: per-observation ratios are
                    # heavy-tailed and right-skewed (near-zero raw
                    # predictions), so a linear EWMA drifts above 1 on
                    # a *correct* noisy model; log-ratios are symmetric
                    # under multiplicative noise.  Clipped so one
                    # outlier cannot whip the gain around.
                    lr = np.log(min(max(realised_s / raw,
                                        1.0 / self.max_ratio),
                                    self.max_ratio))
                    lg = np.log(self.gain) + self.alpha * (
                        lr - np.log(self.gain))
                    self.gain = float(np.exp(lg))
            elif self.correction == "bias":
                raw = predicted_s - self.bias
                self.bias += self.alpha * ((realised_s - raw) - self.bias)
        drift = self.detector.update(r)
        refit_version = None
        if drift:
            self.drift_triggers += 1
            self._count("oracle_drift_triggers")
            if self.obs.enabled:
                self.obs.instant("oracle", "ph_drift", float(now),
                                 args={"residual": r})
            self.detector.reset()
            if self.refit_on_drift:
                # quarantine the window: its labels straddle the change
                # point, so refitting on it would blend two regimes.
                # Collect min_refit *fresh* observations, then refit.
                self._refit_pending = True
                self._obs_x.clear()
                self._obs_y.clear()
        if self._refit_pending and len(self._obs_x) >= self.min_refit:
            refit_version = self.refit(now=now)
            self._refit_pending = False
        self._gauge("oracle_nrmse", self.rolling_nrmse())
        return {"residual": r, "drift": drift,
                "refit_version": refit_version}

    def observe_task(self, task, spec, realised_s: float,
                     predicted_s: Optional[float] = None,
                     now: float = 0.0,
                     extra_transfer_s: float = 0.0) -> dict:
        """Streaming-scheduler adapter: featurise a completed
        :class:`repro.core.scheduler.Task` on the node ``spec`` it ran
        on and ingest its realised service time.  The refit target is
        the compute component (realised minus the analytic input
        transfer and any ``extra_transfer_s`` network delay — e.g. a
        sampled heavy-tailed RTT), matching what the regressor
        predicts.
        """
        layers = [LayerCost(task.name, flops=task.flops, act_bytes=0.0)]
        feats = self.feature_fn(layers, spec)[0]
        transfer = float(task.input_bytes) / max(float(spec.link_bw), 1.0) \
            + float(extra_transfer_s)
        return self.observe(feats, realised_s, predicted_s,
                            refit_y=max(float(realised_s) - transfer, 0.0),
                            now=now)

    def predict_one(self, features: np.ndarray) -> float:
        """Corrected scalar prediction for one feature row."""
        pred = np.asarray(
            self.model.predict(np.asarray(features,
                                          np.float32)[None, :]),
            np.float64)
        if pred.ndim == 2:
            pred = pred[:, self.target_index]
        return float(self.correct(np.maximum(pred, 0.0))[0])

    # -- adaptation -------------------------------------------------------
    def refit(self, now: float = 0.0) -> int:
        """Refit the current model on the observation window and publish
        it (atomic swap); resets the drift detector and the residual
        correction.  Returns the new version.

        Observations carry only the *served* target, so a
        ``MultiTargetGBT`` refits just its ``target_index`` ensemble
        (the other targets keep their previous trees); other
        multi-target models cannot be partially refit and are rejected
        when serving a column beyond the first.
        """
        if not self._obs_x:
            raise ValueError("cannot refit: no observations ingested")
        base = self.registry.current().model
        x = np.stack(list(self._obs_x)).astype(np.float32)
        y = np.asarray(list(self._obs_y), np.float64)
        from repro.core.predictors import MultiTargetGBT
        if isinstance(base, MultiTargetGBT):
            sub = dataclasses.replace(base.models_[self.target_index])
            sub.fit(x, y)
            fresh = dataclasses.replace(base)
            fresh.models_ = list(base.models_)
            fresh.models_[self.target_index] = sub
        elif self.target_index != 0:
            raise TypeError(
                f"cannot refit {type(base).__name__} serving "
                f"target_index={self.target_index}: observations only "
                "cover the served target, and a single-target refit "
                "would drop the other columns — use MultiTargetGBT "
                "(refits its served ensemble in place) or serve "
                "target_index=0")
        else:
            fresh = dataclasses.replace(base)    # unfitted clone
            fresh.fit(x, y)
        version = self.registry.publish(
            fresh, tag=f"refit@{now:.3f}",
            meta={"window": len(y), "nrmse_before": self.rolling_nrmse()},
            ts=now)
        self.gain, self.bias = 1.0, 0.0
        self.detector.reset()
        self.refits += 1
        self._count("oracle_refits")
        if self.obs.enabled:
            self.obs.instant("oracle", "oracle_refit", float(now),
                             args={"version": version, "window": len(y)})
        return version

    # -- telemetry --------------------------------------------------------
    def rolling_nrmse(self) -> float:
        """Windowed normalised RMSE of served predictions vs realised
        times (the paper's Fig. 2 metric, on the live stream)."""
        if not self._window_pred:
            return 0.0
        arr = np.asarray(self._window_pred, np.float64)
        return normalised_rmse(arr[:, 0], arr[:, 1])

    def _count(self, key: str) -> None:
        if self.telemetry is not None:
            self.telemetry.count(key)

    def _gauge(self, key: str, value: float) -> None:
        if self.telemetry is not None:
            self.telemetry.gauge(key, value)


class OracleCost(PredictorCost):
    """:class:`~repro.core.costs.PredictorCost` bound to an oracle: the
    model tracks the registry's current version (refits picked up at the
    next call, caches flushed) and every prediction passes through the
    live residual correction — identity-transparent until the first
    out-of-deadband observation, so a drift-free run is bit-for-bit the
    plain ``PredictorCost`` path.  Lowers to the accelerator backends
    with the correction folded into the lowered layer-time program.
    """

    def __init__(self, oracle: OnlineOracle):
        self._oracle = oracle
        self._version = oracle.version
        PredictorCost.__init__(self, oracle.model, oracle.device,
                               oracle.edge, feature_fn=oracle.feature_fn,
                               target_index=oracle.target_index)

    def _sync(self) -> None:
        if self._oracle.version != self._version:
            self._version = self._oracle.version
            self.model = self._oracle.model
            self._times_cache = (None, None)
            self._parts_cache = (None, None, None)

    def layer_times(self, layers):
        self._sync()
        t_dev, t_edge = PredictorCost.layer_times(self, layers)
        return (self._oracle.correct(t_dev), self._oracle.correct(t_edge))

    def task_matrix(self, tasks, nodes) -> np.ndarray:
        self._sync()
        layers = [LayerCost(t.name, flops=t.flops, act_bytes=0.0)
                  for t in tasks]
        feats = np.concatenate([self.feature_fn(layers, n.spec)
                                for n in nodes], axis=0)
        pred = np.asarray(self.model.predict(feats), np.float64)
        if pred.ndim == 2:
            pred = pred[:, self.target_index]
        comp = self._oracle.correct(np.maximum(pred, 0.0))
        comp = comp.reshape(len(nodes), len(tasks)).T
        link = np.asarray([n.spec.link_bw for n in nodes], np.float64)
        inp = np.asarray([t.input_bytes for t in tasks], np.float64)
        return comp + inp[:, None] / np.maximum(link, 1.0)[None, :]

    def accel_spec(self) -> AccelSpec:
        self._sync()
        from repro.oracle.lowered import lower_layer_times
        correction = (self._oracle.gain, self._oracle.bias)
        cached = getattr(self, "_oracle_accel_cache", None)
        if cached is not None and cached[0] is self.model \
                and cached[1] == correction:
            return cached[2]
        spec = AccelSpec(DEFAULT_EFFICIENCY, (1.0, 0.0, 0.0, 0.0),
                         lowered=lower_layer_times(self,
                                                   correction=correction))
        self._oracle_accel_cache = (self.model, correction, spec)
        return spec
