"""Versioned predictor snapshots with atomic swap.

The serving side of the oracle: every refit *publishes* a new immutable
:class:`Snapshot` and swaps the current-version pointer atomically (one
reference assignment under a lock), so concurrent readers — scheduler
ETC rows mid-sweep, decision sweeps mid-flight — always see a complete
fitted model, never a half-updated one.  With a ``root`` directory the
registry also persists each snapshot through
:mod:`repro.core.predictors.persist` (``.npz`` + ``.json``, temp-file +
``os.replace``) and maintains a ``CURRENT.json`` pointer with the same
discipline, so a crashed process resumes from the last fully-published
version via :meth:`PredictorRegistry.load`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One published predictor version (immutable)."""
    version: int
    model: object
    tag: str = ""
    meta: dict = dataclasses.field(default_factory=dict)


class PredictorRegistry:
    """In-process registry of fitted-predictor versions.

    ``publish`` is the only mutating operation; ``current()`` is a
    lock-free read of the last fully-published snapshot (publication
    happens-before the pointer swap).  ``keep`` bounds the in-memory
    history; on-disk bundles are kept for every version.
    """

    CURRENT = "CURRENT.json"

    def __init__(self, root: Optional[str] = None, keep: int = 8):
        from repro.obs.trace import NULL_TRACER
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.root = root
        self.keep = keep
        self.obs = NULL_TRACER           # set by simulate_stream
        self._lock = threading.Lock()
        self._history: dict[int, Snapshot] = {}
        self._current: Optional[Snapshot] = None
        self._next_version = 0           # monotonic: never re-minted

    # -- reads ------------------------------------------------------------
    @property
    def version(self) -> int:
        """Current version, or -1 before the first publish."""
        snap = self._current
        return -1 if snap is None else snap.version

    def current(self) -> Snapshot:
        snap = self._current
        if snap is None:
            raise LookupError("registry is empty — publish a model first")
        return snap

    def get(self, version: int) -> Snapshot:
        """A specific published version (in-memory history, falling back
        to the on-disk bundle when a ``root`` is configured)."""
        snap = self._history.get(version)
        if snap is not None:
            return snap
        if self.root is not None:
            base = self._base(version)
            if os.path.exists(f"{base}.json"):
                from repro.core.predictors.persist import load_predictor
                return Snapshot(version, load_predictor(base),
                                tag="loaded")
        raise LookupError(f"version {version} not in registry "
                          f"(have {sorted(self._history)})")

    # -- writes -----------------------------------------------------------
    def publish(self, model, tag: str = "",
                meta: Optional[dict] = None,
                ts: float = 0.0) -> int:
        """Register ``model`` as the next version and atomically swap the
        current pointer to it; returns the new version number.  Versions
        come from a monotonic counter — publishing after a rollback
        mints a *fresh* number rather than overwriting the rolled-past
        snapshot (history and on-disk bundles stay intact).  ``ts`` is
        the caller's clock reading for the publish instant a live
        tracer records (virtual time from the oracle's refit path)."""
        with self._lock:
            v = self._next_version
            self._next_version += 1
            snap = Snapshot(v, model, tag, dict(meta or {}))
            if self.root is not None:
                self._persist(snap)
            self._history[v] = snap
            while len(self._history) > self.keep:
                del self._history[min(self._history)]
            self._current = snap                 # the atomic swap
        if self.obs.enabled:
            self.obs.instant("oracle", "registry_publish", float(ts),
                             args={"version": v, "tag": tag})
        return v

    def rollback(self, version: int) -> Snapshot:
        """Point ``current`` back at an older published version (the
        history keeps it addressable; no new version is minted)."""
        snap = self.get(version)
        with self._lock:
            self._history[version] = snap
            self._current = snap
            if self.root is not None:
                self._write_pointer(version, snap.tag)
        return snap

    # -- persistence ------------------------------------------------------
    def _base(self, version: int) -> str:
        return os.path.join(self.root, f"v{version:05d}")

    def _persist(self, snap: Snapshot) -> None:
        from repro.core.predictors.persist import save_predictor
        os.makedirs(self.root, exist_ok=True)
        save_predictor(snap.model, self._base(snap.version))
        self._write_pointer(snap.version, snap.tag)

    def _write_pointer(self, version: int, tag: str) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".tmp-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": version, "tag": tag}, f)
            os.replace(tmp, os.path.join(self.root, self.CURRENT))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, root: str, keep: int = 8) -> "PredictorRegistry":
        """Rebuild a registry from a persisted directory: the
        ``CURRENT.json`` pointer names the version to resume serving."""
        from repro.core.predictors.persist import load_predictor
        with open(os.path.join(root, cls.CURRENT)) as f:
            ptr = json.load(f)
        reg = cls(root=root, keep=keep)
        v = int(ptr["version"])
        snap = Snapshot(v, load_predictor(reg._base(v)),
                        tag=str(ptr.get("tag", "")))
        reg._history[v] = snap
        reg._current = snap
        # resume the counter past every bundle on disk, not just the
        # current pointer (it may have been rolled back)
        published = [int(f[1:-5]) for f in os.listdir(root)
                     if f.startswith("v") and f.endswith(".json")]
        reg._next_version = max(published, default=v) + 1
        return reg
