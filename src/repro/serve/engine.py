"""Batched serving engine: prefill + decode with a static-batch scheduler.

A deliberately complete (if compact) serving path: requests queue in a
broker, get batched to the engine's batch size, prefill builds the KV
cache, greedy/temperature decode runs step-by-step, finished sequences
free their slots.  The *offloading* decision — serve locally vs ship to an
edge node — is delegated to ``repro.core.offload`` policies fed by the
profiling predictor, closing the paper's loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    arrived_at: float = 0.0
    # filled on completion
    output: Optional[np.ndarray] = None
    first_token_s: float = 0.0
    total_s: float = 0.0


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    tokens_out: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)


class ServeEngine:
    """Static-batch serving for one model."""

    def __init__(self, cfg, *, batch_size: int = 4, max_len: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.api = build_model(cfg, impl="naive")
        self.batch_size = batch_size
        self.max_len = max_len
        self.params = self.api.init_params(jax.random.key(seed))
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, max_len))
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(2,))
        self.stats = EngineStats()

    def load_params(self, params):
        self.params = params

    # -- core batched generation ------------------------------------------
    def generate_batch(self, prompts: np.ndarray, max_new: int,
                       temperature: float = 0.0, seed: int = 0
                       ) -> np.ndarray:
        """prompts [B, S] → generated tokens [B, max_new]."""
        b, s = prompts.shape
        assert b == self.batch_size, (b, self.batch_size)
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.family == "audio":
            rng = np.random.default_rng(seed)
            frames = rng.normal(size=(b, self.cfg.enc_seq,
                                      self.cfg.d_model)).astype(np.float32)
            batch["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0

        key = jax.random.key(seed)
        out = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits[:, -1], temperature, key)
        t1 = time.perf_counter()
        for i in range(max_new):
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, {"token": tok}, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1], temperature, sub)
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t1
        self.stats.tokens_out += b * max_new
        return out

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        probs = jax.nn.softmax(logits / temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs))[:, None] \
            .astype(jnp.int32)

    # -- broker loop --------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        """Process a queue of requests in arrival order, batched."""
        queue = sorted(requests, key=lambda r: r.arrived_at)
        done = []
        while queue:
            chunk = queue[:self.batch_size]
            queue = queue[self.batch_size:]
            # pad the batch to engine size with dummy repeats
            while len(chunk) < self.batch_size:
                chunk.append(dataclasses.replace(chunk[-1], rid=-1))
            s = max(len(r.prompt) for r in chunk)
            prompts = np.stack([
                np.pad(r.prompt, (s - len(r.prompt), 0)) for r in chunk])
            max_new = max(r.max_new_tokens for r in chunk)
            t0 = time.perf_counter()
            outs = self.generate_batch(prompts, max_new,
                                       chunk[0].temperature)
            dt = time.perf_counter() - t0
            for r, o in zip(chunk, outs):
                if r.rid < 0:
                    continue
                r.output = o[:r.max_new_tokens]
                r.total_s = dt
                done.append(r)
                self.stats.served += 1
        return done
