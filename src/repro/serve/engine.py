"""Batched serving engine: prefill + decode with a static-batch scheduler.

A deliberately complete (if compact) serving path: requests queue in a
broker, get batched to the engine's batch size, prefill builds the KV
cache, greedy/temperature decode runs step-by-step, finished sequences
free their slots.  The *offloading* decision — serve locally vs ship to an
edge node — is delegated to ``repro.core.offload`` policies fed by the
profiling predictor, closing the paper's loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    arrived_at: float = 0.0
    # virtual time the request was admitted to a slot.  Filled only by
    # ContinuousBatchEngine (which guarantees admitted_at >= arrived_at);
    # stays 0.0 under ServeEngine's static batching
    admitted_at: float = 0.0
    # filled on completion
    output: Optional[np.ndarray] = None
    first_token_s: float = 0.0
    total_s: float = 0.0
    # repro.core.offload.SplitDecision, filled at admission by
    # ContinuousBatchEngine when it carries a cost model (ServeEngine
    # plans per batch via offload_plan instead of per request)
    offload: Optional[object] = None


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    tokens_out: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)


class ServeEngine:
    """Static-batch serving for one model.

    ``cost`` is an optional :class:`repro.core.costs.CostModel`; when set
    it becomes the default cost model for :meth:`offload_plan`, so one
    engine can plan against analytic, predictor-driven, or multi-objective
    costs without per-call plumbing.  ``decision_backend`` picks where
    re-planning sweeps run (``"numpy"`` host default, ``"jax"`` jitted
    next to the model, ``"pallas"`` fused kernel) — see
    :func:`repro.core.decisions.decide_all`.
    """

    def __init__(self, cfg, *, batch_size: int = 4, max_len: int = 256,
                 seed: int = 0, cost=None, decision_backend: str = "numpy",
                 obs=None, metrics=None):
        self.cfg = cfg
        self.api = build_model(cfg, impl="naive")
        self.batch_size = batch_size
        self.max_len = max_len
        self.cost = cost
        self.decision_backend = decision_backend
        self.obs = obs if obs is not None else NULL_TRACER
        # live rolling quantiles: pass a repro.obs.MetricsRegistry and
        # the engine streams per-batch first-token latency and
        # per-request total latency into mergeable sketches (summary
        # kind) — the scrape-time p50/p99 view, reusing the wall
        # readings the stats block already measured
        self.metrics = metrics
        if metrics is not None:
            self._q_first = metrics.quantile(
                "serve_first_token_seconds",
                help="time to first token per batch")
            self._q_total = metrics.quantile(
                "serve_request_total_seconds",
                help="end-to-end request latency")
        self._batches = 0                # obs track row per batch
        self.params = self.api.init_params(jax.random.key(seed))
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, max_len))
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(2,))
        self.stats = EngineStats()
        self.last_first_token_s = 0.0

    def load_params(self, params):
        self.params = params

    # -- core batched generation ------------------------------------------
    def generate_batch(self, prompts: np.ndarray, max_new: int,
                       temperature=0.0, seed: int = 0
                       ) -> np.ndarray:
        """prompts [B, S] → generated tokens [B, max_new].

        ``temperature`` may be a scalar (whole batch) or a ``[B]`` vector
        (per-row sampling temperature; ≤ 0 means greedy for that row).
        """
        b, s = prompts.shape
        assert b == self.batch_size, (b, self.batch_size)
        t0 = time.perf_counter()  # repro: disable=DET002 (real prefill wall time)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.family == "audio":
            rng = np.random.default_rng(seed)
            frames = rng.normal(size=(b, self.cfg.enc_seq,
                                      self.cfg.d_model)).astype(np.float32)
            batch["frames"] = jnp.asarray(frames)
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        t_pf = time.perf_counter()  # repro: disable=DET002 (measurement)
        self.stats.prefill_s += t_pf - t0

        key = jax.random.key(seed)
        out = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits[:, -1], temperature, key)
        jax.block_until_ready(tok)
        t_ft = time.perf_counter()  # repro: disable=DET002 (measurement)
        self.last_first_token_s = t_ft - t0
        t1 = time.perf_counter()  # repro: disable=DET002 (real decode wall time)
        for i in range(max_new):
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, {"token": tok}, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1], temperature, sub)
        jax.block_until_ready(logits)
        t_end = time.perf_counter()  # repro: disable=DET002 (measurement)
        self.stats.decode_s += t_end - t1
        self.stats.tokens_out += b * max_new
        if self.metrics is not None:
            self._q_first.observe(self.last_first_token_s)
        if self.obs.enabled:
            # the spans reuse the already-measured wall readings above —
            # tracing adds no perf_counter calls to the serving path
            bid = self._batches
            self._batches += 1
            self.obs.span("serve_engine", "prefill", t0, t_pf, tid=bid,
                          args={"batch": b})
            self.obs.instant("serve_engine", "first_token", t_ft, tid=bid)
            self.obs.span("serve_engine", "decode", t1, t_end, tid=bid,
                          args={"tokens": b * max_new})
        return out

    @staticmethod
    def _sample(logits, temperature, key):
        temp = jnp.asarray(temperature, jnp.float32)
        if temp.ndim == 0:
            if float(temp) <= 0:
                return jnp.argmax(logits, axis=-1)[:, None] \
                    .astype(jnp.int32)
            temp = jnp.full(logits.shape[:1], temp)
        greedy = jnp.argmax(logits, axis=-1)
        sampled = jax.random.categorical(
            key, logits / jnp.maximum(temp, 1e-6)[:, None])
        return jnp.where(temp > 0, sampled, greedy)[:, None] \
            .astype(jnp.int32)

    # -- broker loop --------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        """Process a queue of requests in arrival order, batched."""
        queue = sorted(requests, key=lambda r: r.arrived_at)
        done = []
        while queue:
            chunk = queue[:self.batch_size]
            queue = queue[self.batch_size:]
            # pad the batch to engine size with dummy repeats
            while len(chunk) < self.batch_size:
                chunk.append(dataclasses.replace(chunk[-1], rid=-1))
            s = max(len(r.prompt) for r in chunk)
            prompts = np.stack([
                np.pad(r.prompt, (s - len(r.prompt), 0)) for r in chunk])
            max_new = max(r.max_new_tokens for r in chunk)
            temps = np.asarray([r.temperature for r in chunk], np.float32)
            t0 = time.perf_counter()  # repro: disable=DET002 (measurement)
            outs = self.generate_batch(prompts, max_new, temps)
            dt = time.perf_counter() - t0  # repro: disable=DET002 (measurement)
            for r, o in zip(chunk, outs):
                if r.rid < 0:
                    continue
                r.output = o[:r.max_new_tokens]
                r.first_token_s = self.last_first_token_s
                r.total_s = dt
                done.append(r)
                self.stats.served += 1
                if self.metrics is not None:
                    self._q_total.observe(dt)
                    self.metrics.counter(
                        "serve_requests_completed").inc()
        return done

    # -- offload delegation -------------------------------------------------
    def offload_plan(self, link_bws, *, device=None, edge=None,
                     seq_len: int = 0, link_latency_s: float = 0.005,
                     cost=None, backend=None):
        """Split-computing plan for this model across candidate link states.

        Delegates to the vectorized decision core: one ``[n_links, L+1]``
        cost matrix and one argmin per link, so the broker can re-plan
        every batch without measurable overhead.  ``cost`` overrides the
        engine's construction-time cost model (``None`` falls back to it,
        then to the analytic latency model); ``backend`` likewise
        overrides the engine's ``decision_backend``.  Returns a
        :class:`repro.core.decisions.DecisionPlan`; index it to get the
        ``SplitDecision`` for one link state.
        """
        from repro.core.decisions import decide_all, make_envs
        from repro.core.offload import transformer_layer_costs
        from repro.hw import get_device
        device = device or get_device("jetson-orin-nano")
        edge = edge or get_device("edge-server-a100")
        seq_len = seq_len or self.max_len
        layers = transformer_layer_costs(self.cfg, seq_len, self.batch_size)
        envs = make_envs(device, edge,
                         link_bw=np.atleast_1d(link_bws).astype(np.float64),
                         link_latency_s=link_latency_s,
                         input_bytes=4.0 * self.batch_size * seq_len)
        return decide_all(layers, envs,
                          cost=cost if cost is not None else self.cost,
                          backend=backend or self.decision_backend)
