from repro.serve.continuous import ContinuousBatchEngine
from repro.serve.engine import EngineStats, Request, ServeEngine

__all__ = ["ContinuousBatchEngine", "EngineStats", "Request", "ServeEngine"]
