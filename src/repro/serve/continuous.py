"""Continuous batching engine (slot-based, vLLM-style scheduling discipline).

Unlike :class:`repro.serve.engine.ServeEngine` (static batches), slots are
freed the moment a sequence finishes and refilled from the broker queue —
the decode step always runs at full batch width.  Prefill for an incoming
request runs as its own (batch=1) call and its KV rows are spliced into the
shared cache; per-slot position masking handles ragged sequence states.

Works with every cache family exposing per-slot batch rows (GQA k/v, MLA
latents, SSM/xLSTM states): splicing is a pure tree_map over the batch dim.

When constructed with a cost model, the engine also closes the paper's
offloading loop per admitted request: at admission it observes the current
link bandwidth and re-plans the device/edge split for that request through
:func:`repro.core.decisions.decide_all` (mirroring
``ServeEngine.offload_plan``, but continuous — every admission re-plans
against fresh link state instead of one plan per static batch).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.obs.trace import NULL_TRACER
from repro.serve.engine import Request


def _batch_dim_index(path_leafname: str) -> Optional[int]:
    """Index of the batch dim per cache leaf (after layer-stack dims)."""
    name = path_leafname
    if name in ("k", "v", "ckv", "kr", "self_k", "self_v", "cross_k",
                "cross_v", "attn_k", "attn_v", "s_c", "s_n", "s_h", "s_m",
                "s_conv"):
        return 1
    if name in ("ssm", "conv") or name.startswith("m_"):
        return 2
    return None                      # pos etc.


class ContinuousBatchEngine:
    """Slot-based continuous batching for one model.

    ``cost`` is an optional :class:`repro.core.costs.CostModel`; when set,
    every admitted request gets an offload split re-planned against the
    current ``link_bw`` observation (a float, or a zero-arg callable
    returning the observed bytes/s) and recorded on ``request.offload``.
    ``decision_backend`` selects where those re-planning sweeps run
    (``"numpy"`` host default, ``"jax"`` jitted next to the model) — see
    :func:`repro.core.decisions.decide_all`.

    Admission is clocked: the engine keeps a virtual
    :class:`repro.sim.events.Clock` that advances ``step_latency_s``
    per decode step (and jumps forward over idle gaps), and a request is
    only admitted once ``request.arrived_at`` has passed — never the
    moment a slot happens to be free.  Inject ``clock=`` to share one
    virtual time axis with a :mod:`repro.sim` run; each admitted request
    records its admission instant on ``request.admitted_at``.
    """

    def __init__(self, cfg, *, slots: int = 4, max_len: int = 256,
                 seed: int = 0, cost=None, link_bw=1.25e9,
                 offload_device=None, offload_edge=None,
                 decision_backend: str = "numpy",
                 clock=None, step_latency_s: float = 5e-3, obs=None,
                 metrics=None):
        assert cfg.family in ("dense", "moe", "vlm") \
            and cfg.attn_kind == "gqa", \
            "continuous batching requires the vector-position GQA decode path"
        self.cfg = cfg
        self.api = build_model(cfg, impl="naive")
        self.slots = slots
        self.max_len = max_len
        self.cost = cost
        self.decision_backend = decision_backend
        self.link_bw = link_bw           # float or () -> float observation
        self.offload_device = offload_device
        self.offload_edge = offload_edge
        if clock is None:
            # deferred: the serving layer must not pull in the whole
            # simulator at import time — any object with .now/.advance/
            # .advance_to (e.g. an injected sim Clock) works
            from repro.sim.events import Clock
            clock = Clock()
        self.clock = clock
        self.step_latency_s = float(step_latency_s)
        self.obs = obs if obs is not None else NULL_TRACER
        # live rolling quantiles: pass a repro.obs.MetricsRegistry and
        # the engine streams per-request sojourn / queue-wait into
        # mergeable sketches (summary kind) a /metrics scrape reads as
        # p50/p90/p99 without stored samples
        self.metrics = metrics
        if metrics is not None:
            self._q_sojourn = metrics.quantile(
                "serve_sojourn_seconds",
                help="request sojourn (arrival to completion)")
            self._q_wait = metrics.quantile(
                "serve_queue_wait_seconds",
                help="request queue wait (arrival to admission)")
        self.replans = 0
        self.params = self.api.init_params(jax.random.key(seed))
        self.cache = self.api.init_cache(slots, max_len)
        # per-slot state (host side)
        self.slot_pos = np.zeros(slots, np.int32)        # tokens consumed
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_remaining = np.zeros(slots, np.int32)
        self.slot_last_tok = np.zeros(slots, np.int32)
        self._prefill1 = jax.jit(lambda p, b: self.api.prefill(p, b, max_len))
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(2,))
        self.steps = 0
        self.tokens_out = 0

    # -- cache splicing -----------------------------------------------------
    def _splice(self, slot: int, cache1):
        """Copy request-cache (batch=1) rows into ``slot`` of the shared
        cache."""
        flat_s, treedef = jax.tree_util.tree_flatten_with_path(self.cache)
        flat_1 = jax.tree_util.tree_leaves(cache1)
        out = []
        for (path, big), small in zip(flat_s, flat_1):
            name = str(getattr(path[-1], "key", path[-1]))
            bdim = _batch_dim_index(name)
            if bdim is None:
                out.append(big)
                continue
            idx = [slice(None)] * big.ndim
            idx[bdim] = slice(slot, slot + 1)
            out.append(big.at[tuple(idx)].set(small))
        self.cache = jax.tree_util.tree_unflatten(treedef, out)

    # -- offload re-planning --------------------------------------------------
    def observe_link_bw(self) -> float:
        """Current link-bandwidth observation (bytes/s)."""
        bw = self.link_bw() if callable(self.link_bw) else self.link_bw
        return float(bw)

    def _plan_offload(self, req: Request) -> None:
        """Re-plan the device/edge split for one admitted request against
        the engine's cost model and the fresh link observation."""
        from repro.core.decisions import decide_all, make_envs
        from repro.core.offload import transformer_layer_costs
        from repro.hw import get_device
        device = self.offload_device or get_device("jetson-orin-nano")
        edge = self.offload_edge or get_device("edge-server-a100")
        seq = max(len(req.prompt), 1)
        layers = transformer_layer_costs(self.cfg, seq, 1)
        envs = make_envs(device, edge,
                         link_bw=np.asarray([self.observe_link_bw()]),
                         input_bytes=4.0 * seq)
        req.offload = decide_all(layers, envs, cost=self.cost,
                                 backend=self.decision_backend)[0]
        self.replans += 1
        if self.obs.enabled:
            self.obs.instant("continuous_engine", "replan",
                             self.clock.now, tid=req.rid,
                             args={"split": int(req.offload.split)})

    # -- admission ------------------------------------------------------------
    def _admit(self, req: Request, slot: int):
        req.admitted_at = self.clock.now
        if self.obs.enabled:
            self.obs.instant("continuous_engine", "admit",
                             self.clock.now, tid=req.rid,
                             args={"slot": slot})
        if self.cost is not None:
            self._plan_offload(req)
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        logits, cache1 = self._prefill1(self.params, batch)
        self._splice(slot, cache1)
        self.slot_pos[slot] = len(req.prompt)
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens
        self.slot_last_tok[slot] = int(jnp.argmax(logits[0, -1]))
        req.output = np.zeros(req.max_new_tokens, np.int32)
        req._written = 0              # type: ignore[attr-defined]

    # -- main loop ------------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        queue = sorted(requests, key=lambda r: r.arrived_at)
        done: list[Request] = []
        while queue or any(r is not None for r in self.slot_req):
            # idle engine + future arrivals only: jump the virtual clock
            # to the next arrival instead of spinning empty decode steps
            if queue and not any(r is not None for r in self.slot_req) \
                    and queue[0].arrived_at > self.clock.now:
                self.clock.advance_to(queue[0].arrived_at)
            # fill free slots — only with requests that have arrived
            for s in range(self.slots):
                if self.slot_req[s] is None and queue \
                        and queue[0].arrived_at <= self.clock.now:
                    self._admit(queue.pop(0), s)
            # one decode step for all active slots, ragged per-slot positions
            toks = jnp.asarray(self.slot_last_tok[:, None], jnp.int32)
            self.cache["pos"] = jnp.asarray(self.slot_pos, jnp.int32)
            logits, self.cache = self._decode(self.params, {"token": toks},
                                              self.cache)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            self.steps += 1
            self.clock.advance(self.step_latency_s)
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None:
                    continue
                w = req._written        # type: ignore[attr-defined]
                req.output[w] = self.slot_last_tok[s]
                req._written = w + 1    # type: ignore[attr-defined]
                self.tokens_out += 1
                self.slot_last_tok[s] = nxt[s]
                self.slot_pos[s] += 1
                self.slot_remaining[s] -= 1
                if self.slot_remaining[s] <= 0 \
                        or self.slot_pos[s] >= self.max_len - 1:
                    done.append(req)
                    self.slot_req[s] = None
                    if self.metrics is not None:
                        self._q_sojourn.observe(
                            self.clock.now - req.arrived_at)
                        self._q_wait.observe(
                            req.admitted_at - req.arrived_at)
                        self.metrics.counter(
                            "serve_requests_completed").inc()
                    if self.obs.enabled:
                        # virtual-clock lifecycle on the shared time axis:
                        # sojourn [arrived, now] ⊃ queue_wait [arrived,
                        # admitted] · service [admitted, now]
                        self.obs.task_spans(
                            "continuous_engine", req.rid,
                            f"req{req.rid}", req.arrived_at,
                            req.admitted_at, self.clock.now)
        return done

    @property
    def occupancy(self) -> float:
        """Mean generated tokens per decode step (≤ slots)."""
        return self.tokens_out / max(self.steps, 1)
