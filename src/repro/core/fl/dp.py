"""Differential privacy for federated profiling-model updates (paper §II-B).

Gaussian mechanism on client updates: clip the update's global L2 norm to
``clip_norm`` and add N(0, σ²·clip²) noise, σ derived from (ε, δ) via the
classic analytic bound σ = clip · √(2 ln(1.25/δ)) / ε per round.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DPConfig:
    epsilon: float = 8.0
    delta: float = 1e-5
    clip_norm: float = 1.0

    @property
    def sigma(self) -> float:
        return (self.clip_norm * math.sqrt(2.0 * math.log(1.25 / self.delta))
                / self.epsilon)


def global_norm(tree) -> float:
    leaves = jax.tree_util.tree_leaves(tree)
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in leaves)))


def clip_update(tree, clip_norm: float):
    norm = global_norm(tree)
    scale = min(1.0, clip_norm / max(norm, 1e-12))
    return jax.tree_util.tree_map(lambda l: l * scale, tree)


def privatise_update(tree, cfg: DPConfig, rng: np.random.Generator):
    """Clip + Gaussian noise (applied client-side before aggregation)."""
    clipped = clip_update(tree, cfg.clip_norm)
    return jax.tree_util.tree_map(
        lambda l: l + jnp.asarray(
            rng.normal(0.0, cfg.sigma, size=l.shape), l.dtype),
        clipped)
