"""Federated learning of the *global profiling model* (paper §II-B).

Profiling data is collected on users' devices and is sensitive, so the
global profiling model is trained with FedAvg + differential privacy
(the paper builds on the authors' kubeflower framework; here the
communication pattern — server broadcast → client local steps → weighted
aggregation — is mapped to JAX-native constructs per DESIGN.md §2).

Validation modes (paper §II-B): *federated* (each client holds out a local
test split) and *centralised* (the server evaluates the global model on an
unseen dataset).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl.dp import DPConfig, privatise_update
from repro.core.predictors.mlp import MLPRegressor
from repro.data.synthetic import batches
from repro.optim import apply_updates, get_optimizer


@dataclasses.dataclass
class Client:
    """One edge device holding a private shard of profiling records."""
    name: str
    x: np.ndarray
    y: np.ndarray
    test_frac: float = 0.2

    def splits(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.x))
        k = int(len(idx) * (1 - self.test_frac))
        return (self.x[idx[:k]], self.y[idx[:k]],
                self.x[idx[k:]], self.y[idx[k:]])


def split_clients(x: np.ndarray, y: np.ndarray, n_clients: int,
                  by: Optional[np.ndarray] = None, seed: int = 0
                  ) -> list[Client]:
    """Partition the profiling dataset into per-device shards.

    ``by`` (e.g. a hardware-type column) produces non-IID shards — the
    heterogeneity case the paper targets; None → IID random shards.
    """
    rng = np.random.default_rng(seed)
    if by is not None:
        keys = np.unique(by)
        groups = [np.where(by == k)[0] for k in keys]
        # merge/split groups into n_clients roughly equal shards
        order = rng.permutation(len(x)) if len(groups) < n_clients else None
        if order is not None:
            groups = np.array_split(order, n_clients)
    else:
        groups = np.array_split(rng.permutation(len(x)), n_clients)
    return [Client(f"client{i}", x[g], y[g]) for i, g in enumerate(groups)]


@dataclasses.dataclass
class FedAvgConfig:
    rounds: int = 20
    local_epochs: int = 2
    lr: float = 1e-3
    optimiser: str = "adam"
    batch_size: int = 32
    hidden: tuple = (128, 64)
    dp: Optional[DPConfig] = None
    seed: int = 0


@dataclasses.dataclass
class FedAvgResult:
    model: MLPRegressor
    round_history: list[dict]
    federated_rmse: float
    centralised_rmse: Optional[float]


def _tree_mean(trees: list, weights: np.ndarray):
    total = float(weights.sum())
    def avg(*leaves):
        return sum(w * l for w, l in zip(weights, leaves)) / total
    return jax.tree_util.tree_map(avg, *trees)


def _local_train(model: MLPRegressor, params, x, y, cfg: FedAvgConfig,
                 seed: int):
    """Local client steps; returns the parameter UPDATE (delta)."""
    opt = get_optimizer(cfg.optimiser, cfg.lr)
    state = opt.init(params)
    n_layers = model.n_layers_

    @jax.jit
    def step(p, s, bx, by):
        def loss_fn(q):
            pred = MLPRegressor._forward(q, bx, n_layers)
            return jnp.mean((pred - by) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(p)
        updates, s2 = opt.update(grads, s, p)
        return apply_updates(p, updates), s2, loss

    p = params
    for ep in range(cfg.local_epochs):
        for bx, by in batches(x, y, min(cfg.batch_size, len(x)),
                              seed=seed + ep):
            p, state, _ = step(p, state, jnp.asarray(bx), jnp.asarray(by))
    return jax.tree_util.tree_map(lambda a, b: a - b, p, params)


def run_fedavg(clients: list[Client], cfg: FedAvgConfig,
               central_test: Optional[tuple] = None) -> FedAvgResult:
    """Server loop: broadcast → local training → (DP) aggregate."""
    # bootstrap a model skeleton on the pooled feature stats
    x_all = np.concatenate([c.x for c in clients])
    y_all = np.concatenate([c.y for c in clients])
    model = MLPRegressor(hidden=cfg.hidden, lr=cfg.lr,
                         optimiser=cfg.optimiser, epochs=0,
                         seed=cfg.seed)
    model.fit(x_all, y_all)                  # init params + scalers only
    # (pooled feature scaling is metadata, not raw data — acceptable under
    # the paper's threat model; per-client scaling is a one-line swap)
    params = {k: jnp.asarray(v) for k, v in model.params_.items()}

    def norm_x(x):
        return (x - model.x_mu_) / model.x_sd_

    def norm_y(y):
        return (y - model.y_mu_) / model.y_sd_

    splits = [c.splits(cfg.seed) for c in clients]
    weights = np.array([len(s[0]) for s in splits], np.float64)
    rng = np.random.default_rng(cfg.seed)
    history = []
    for rnd in range(cfg.rounds):
        deltas = []
        for ci, (xtr, ytr, _, _) in enumerate(splits):
            delta = _local_train(model, params, norm_x(xtr), norm_y(ytr),
                                 cfg, seed=cfg.seed + 997 * rnd + ci)
            if cfg.dp:
                delta = privatise_update(delta, cfg.dp, rng)
            deltas.append(delta)
        mean_delta = _tree_mean(deltas, weights)
        params = jax.tree_util.tree_map(lambda p, d: p + d, params,
                                        mean_delta)
        # federated validation
        errs = []
        for xtr, ytr, xte, yte in splits:
            if len(xte) == 0:
                continue
            pred = MLPRegressor._forward(params, jnp.asarray(norm_x(xte)),
                                         model.n_layers_)
            errs.append(float(jnp.mean((pred - norm_y(yte)) ** 2)))
        fed_rmse = float(np.sqrt(np.mean(errs)))
        history.append({"round": rnd, "federated_rmse": fed_rmse})

    model.params_ = jax.device_get(params)
    cen = None
    if central_test is not None:
        xte, yte = central_test
        pred = model.predict(xte)
        cen = float(np.sqrt(np.mean(
            ((pred - yte) / (np.abs(model.y_sd_) + 1e-12)) ** 2)))
    return FedAvgResult(model=model, round_history=history,
                        federated_rmse=history[-1]["federated_rmse"],
                        centralised_rmse=cen)
