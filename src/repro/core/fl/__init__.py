from repro.core.fl.dp import DPConfig, clip_update, global_norm, privatise_update
from repro.core.fl.fedavg import (Client, FedAvgConfig, FedAvgResult,
                                  run_fedavg, split_clients)

__all__ = [
    "Client",
    "DPConfig",
    "FedAvgConfig",
    "FedAvgResult",
    "clip_update",
    "global_norm",
    "privatise_update",
    "run_fedavg",
    "split_clients",
]
