"""Profiling-dataset generation (paper §III-A: ">3,000 runs").

Runs the Table-I grid through the profiler and assembles the tabular
regression dataset.  ``max_steps`` truncates each run (per-step time is
measured, total time extrapolated) so a >100-run grid stays tractable on
this host; benchmarks validate the extrapolation on full runs.

Heterogeneity augmentation: each measured record is re-projected onto the
other edge-device specs analytically (scaled by relative roofline), giving
the multi-hardware dataset of the paper's roadmap without owning the
physical devices. Augmented rows are flagged ``measured=False``.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

from repro.core.features import records_to_dataset
from repro.core.profiler import ProfileRecord, profile_workload
from repro.core.workloads import WorkloadConfig, sample_grid
from repro.hw import EDGE_DEVICES, get_device


def generate(n_runs: int = 120, *, max_steps: int = 8, seed: int = 0,
             measure: bool = True, augment_hardware: bool = True,
             verbose: bool = False):
    """Returns (records, TabularDataset)."""
    grid = sample_grid(n_runs, seed=seed)
    base_dev = get_device("xps15-i5")
    records: list[ProfileRecord] = []
    t0 = time.time()
    for i, wc in enumerate(grid):
        rec = profile_workload(wc, device=base_dev, measure=measure,
                               max_steps=max_steps, seed=seed + i)
        records.append(rec)
        if verbose and (i + 1) % 20 == 0:
            print(f"[dataset] {i+1}/{len(grid)} runs "
                  f"({time.time()-t0:.0f}s)")
    if augment_hardware:
        records += project_hardware(records)
    return records, records_to_dataset(records)


def project_hardware(records: list[ProfileRecord]) -> list[ProfileRecord]:
    """Analytic re-projection of measured runs onto other device specs."""
    base = get_device("xps15-i5")
    out = []
    for rec in records:
        for name, dev in EDGE_DEVICES.items():
            if name == base.name:
                continue
            # scale times by the inverse compute-throughput ratio, bounded
            # by the memory-bandwidth ratio (roofline projection)
            comp_ratio = base.peak_flops_f32 / dev.peak_flops_f32
            mem_ratio = base.hbm_bw / dev.hbm_bw
            scale = max(comp_ratio, mem_ratio)
            out.append(dataclasses.replace(
                rec,
                label=f"{rec.label}@{name}",
                total_time_s=rec.total_time_s * scale,
                step_time_s=rec.step_time_s * scale,
                hardware=dev.as_features(),
            ))
    return out


def save_records(records: list[ProfileRecord], path: str) -> None:
    with open(path, "w") as f:
        json.dump([dataclasses.asdict(r) for r in records], f)


def load_records(path: str) -> list[ProfileRecord]:
    with open(path) as f:
        return [ProfileRecord(**d) for d in json.load(f)]
