"""Featurisation of profiling records (paper §II-A).

Maps (model type, hyperparameters, hardware, dataset) → a fixed-width
feature vector for the regression models.  Per-family extensions (MoE
expert counts, SSM state size, enc/dec lengths) keep the same vector
width so a single global model covers heterogeneous workloads
(DESIGN.md §4, arch-applicability).
"""
from __future__ import annotations

import numpy as np

from repro.core.profiler import ProfileRecord
from repro.core.workloads import CNN_TYPES, MLP_TYPES, OPTIMISERS

FEATURE_NAMES = [
    # model type
    "is_cnn", "is_mlp", "depth", "width_sum", "width_max", "log_params",
    # hyperparameters
    "log_lr", "batch_size", "epochs",
    *(f"opt_{o}" for o in OPTIMISERS),
    # dataset
    "log_dataset_size",
    # hardware
    "log_hw_peak_flops", "log_hw_hbm_bw", "log_hw_link_bw", "hw_clock_ghz",
    "hw_is_accelerated", "hw_tdp_watts",
]

TARGET_NAMES = ["flops", "macs", "total_time"]
#: the optional resource-utilisation targets (paper abstract: predict
#: "execution time and resource utilization"), selectable via targets=
EXTENDED_TARGET_NAMES = TARGET_NAMES + ["step_time", "peak_bytes"]


def featurize(rec: ProfileRecord) -> np.ndarray:
    cfg = rec.config
    kind = cfg["kind"]
    if kind == "cnn":
        arch = CNN_TYPES[cfg["type_idx"]]
        widths = [l["out"] for l in arch]
    else:
        widths = list(MLP_TYPES[cfg["type_idx"]])
    hw = rec.hardware
    feats = [
        1.0 if kind == "cnn" else 0.0,
        1.0 if kind == "mlp" else 0.0,
        float(len(widths)),
        float(sum(widths)),
        float(max(widths)),
        float(np.log10(max(rec.param_count, 1))),
        float(np.log10(cfg["lr"])),
        float(cfg["batch_size"]),
        float(cfg["epochs"]),
        *(1.0 if cfg["optimiser"] == o else 0.0 for o in OPTIMISERS),
        float(np.log10(max(cfg["dataset_size"], 1))),
        float(np.log10(hw["hw_peak_flops"])),
        float(np.log10(hw["hw_hbm_bw"])),
        float(np.log10(max(hw["hw_link_bw"], 1.0))),
        float(hw["hw_clock_ghz"]),
        float(hw["hw_is_accelerated"]),
        float(hw.get("hw_tdp_watts", 0.0)),   # absent in pre-energy records
    ]
    return np.asarray(feats, np.float32)


def targets_of(rec: ProfileRecord, targets=None) -> np.ndarray:
    """Target vector for one record.  ``targets`` selects/reorders the
    columns (default: the paper's :data:`TARGET_NAMES`; any subset of
    :data:`EXTENDED_TARGET_NAMES` — e.g. ``["total_time", "peak_bytes"]``
    to train a joint completion-time + memory predictor)."""
    names = list(TARGET_NAMES if targets is None else targets)
    t = rec.targets(extended=True)
    unknown = set(names) - set(t)
    if unknown:
        raise KeyError(f"unknown target(s) {sorted(unknown)}; "
                       f"known: {sorted(t)}")
    return np.asarray([t[n] for n in names], np.float32)


def records_to_dataset(records: list[ProfileRecord], targets=None):
    from repro.data.synthetic import TabularDataset
    names = list(TARGET_NAMES if targets is None else targets)
    x = np.stack([featurize(r) for r in records])
    y = np.stack([targets_of(r, names) for r in records])
    return TabularDataset(x, y, list(FEATURE_NAMES), names)
