"""Gradient-boosted regression trees — the paper's winning profiler model
("XGBoost, max_depth=12, subsample=0.8", Fig. 2b / Fig. 3).

From-scratch histogram implementation (no xgboost dependency):

  * features quantile-binned to uint8 codes (default 64 bins);
  * squared loss → gradient = residual, hessian = count;
  * per-node *gradient histograms* per feature (the compute hot-spot — the
    Pallas TPU kernel in ``repro.kernels.gbt_hist`` is its accelerated twin,
    and ``use_kernel=True`` routes through it);
  * best split by the standard gain  GL²/nL + GR²/nR − G²/n;
  * row subsampling per boosting round (the paper's ``subsample``);
  * one ensemble per target (paper: "an individual boosted tree ensemble is
    used for each target").
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold_bin: int = 0
    left: int = -1
    right: int = -1
    value: float = 0.0

    @property
    def is_leaf(self) -> bool:
        return self.feature < 0


def quantile_bins(x: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature bin edges [F, n_bins-1] from quantiles."""
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    return np.quantile(x, qs, axis=0).T.astype(np.float32)   # [F, n_bins-1]


def bin_data(x: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """x [N,F] → uint8 bin codes via per-feature edges."""
    codes = np.empty(x.shape, np.uint8)
    for f in range(x.shape[1]):
        codes[:, f] = np.searchsorted(edges[f], x[:, f]).astype(np.uint8)
    return codes


def grad_histogram(codes: np.ndarray, grad: np.ndarray, n_bins: int,
                   use_kernel: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Per-(feature, bin) gradient sums + counts. The GBT hot-spot.

    codes [N, F] uint8; grad [N]. Returns (gsum [F, n_bins], cnt [F, n_bins]).
    """
    if use_kernel:
        from repro.kernels.gbt_hist import ops
        return ops.grad_histogram(codes, grad, n_bins)
    n, f = codes.shape
    flat = codes.astype(np.int64) + np.arange(f)[None, :] * n_bins
    gsum = np.bincount(flat.ravel(), weights=np.repeat(grad, f),
                       minlength=f * n_bins)
    # repeat(grad, f) interleaves per-row; codes.ravel() is row-major [N,F]
    cnt = np.bincount(flat.ravel(), minlength=f * n_bins)
    return gsum.reshape(f, n_bins), cnt.reshape(f, n_bins).astype(np.float64)


@dataclasses.dataclass
class GBTRegressor:
    """Single-target gradient-boosted trees."""
    n_trees: int = 200
    max_depth: int = 6
    learning_rate: float = 0.1
    subsample: float = 1.0
    n_bins: int = 64
    min_samples_leaf: int = 2
    lambda_reg: float = 1.0
    seed: int = 0
    use_kernel: bool = False

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GBTRegressor":
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float64).ravel()
        rng = np.random.default_rng(self.seed)
        self.edges_ = quantile_bins(x, self.n_bins)
        codes = bin_data(x, self.edges_)
        self.base_ = float(y.mean())
        pred = np.full_like(y, self.base_)
        self.trees_: list[list[_Node]] = []
        n = len(y)
        for _ in range(self.n_trees):
            resid = y - pred
            if self.subsample < 1.0:
                rows = rng.random(n) < self.subsample
                if rows.sum() < 2 * self.min_samples_leaf:
                    rows = np.ones(n, bool)
            else:
                rows = np.ones(n, bool)
            tree = self._build_tree(codes[rows], resid[rows])
            self.trees_.append(tree)
            pred += self.learning_rate * self._tree_predict(tree, codes)
        return self

    # -- tree growing -----------------------------------------------------
    def _build_tree(self, codes: np.ndarray, grad: np.ndarray) -> list[_Node]:
        nodes: list[_Node] = []

        def grow(idx: np.ndarray, depth: int) -> int:
            node_id = len(nodes)
            nodes.append(_Node())
            g = grad[idx]
            n = len(idx)
            value = g.sum() / (n + self.lambda_reg)
            if depth >= self.max_depth or n < 2 * self.min_samples_leaf:
                nodes[node_id].value = value
                return node_id
            gsum, cnt = grad_histogram(codes[idx], g, self.n_bins,
                                       self.use_kernel)
            gl = np.cumsum(gsum, axis=1)                   # [F, B]
            nl = np.cumsum(cnt, axis=1)
            gt, nt = g.sum(), float(n)
            gr, nr = gt - gl, nt - nl
            lam = self.lambda_reg
            gain = (gl ** 2 / (nl + lam) + gr ** 2 / (nr + lam)
                    - gt ** 2 / (nt + lam))
            ok = (nl >= self.min_samples_leaf) & (nr >= self.min_samples_leaf)
            gain = np.where(ok, gain, -np.inf)
            f, b = np.unravel_index(np.argmax(gain), gain.shape)
            if not np.isfinite(gain[f, b]) or gain[f, b] <= 1e-12:
                nodes[node_id].value = value
                return node_id
            mask = codes[idx, f] <= b
            left = grow(idx[mask], depth + 1)
            right = grow(idx[~mask], depth + 1)
            nodes[node_id].feature = int(f)
            nodes[node_id].threshold_bin = int(b)
            nodes[node_id].left = left
            nodes[node_id].right = right
            return node_id

        grow(np.arange(len(grad)), 0)
        return nodes

    def _tree_predict(self, tree: list[_Node], codes: np.ndarray) -> np.ndarray:
        out = np.empty(len(codes))
        # vectorised level-order traversal
        node_idx = np.zeros(len(codes), np.int32)
        active = np.ones(len(codes), bool)
        while active.any():
            for nid in np.unique(node_idx[active]):
                node = tree[nid]
                sel = active & (node_idx == nid)
                if node.is_leaf:
                    out[sel] = node.value
                    active &= ~sel
                else:
                    goes_left = codes[sel, node.feature] <= node.threshold_bin
                    tgt = np.where(goes_left, node.left, node.right)
                    node_idx[sel] = tgt
        return out

    def predict(self, x: np.ndarray) -> np.ndarray:
        codes = bin_data(np.asarray(x, np.float32), self.edges_)
        pred = np.full(len(codes), self.base_)
        for tree in self.trees_:
            pred += self.learning_rate * self._tree_predict(tree, codes)
        return pred


@dataclasses.dataclass
class MultiTargetGBT:
    """One ensemble per target (paper Fig. 2b)."""
    n_trees: int = 200
    max_depth: int = 12
    learning_rate: float = 0.1
    subsample: float = 0.8
    n_bins: int = 64
    seed: int = 0
    use_kernel: bool = False

    def fit(self, x: np.ndarray, y: np.ndarray) -> "MultiTargetGBT":
        y = np.atleast_2d(y)
        if y.shape[0] == len(x) and y.ndim == 2:
            targets = y.T
        else:
            targets = y
        self.models_ = []
        for ti, yt in enumerate(targets):
            m = GBTRegressor(
                n_trees=self.n_trees, max_depth=self.max_depth,
                learning_rate=self.learning_rate, subsample=self.subsample,
                n_bins=self.n_bins, seed=self.seed + ti,
                use_kernel=self.use_kernel).fit(x, yt)
            self.models_.append(m)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.stack([m.predict(x) for m in self.models_], axis=1)
