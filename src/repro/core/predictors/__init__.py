from repro.core.predictors.common import (normalised_rmse, per_target_nrmse,
                                          r2, rmse)
from repro.core.predictors.gbt import GBTRegressor, MultiTargetGBT
from repro.core.predictors.linear import RidgeRegressor
from repro.core.predictors.mlp import SIZE_PRESETS, MLPRegressor
from repro.core.predictors.persist import load_predictor, save_predictor

#: the ridge baseline under the paper's generic name
LinearRegressor = RidgeRegressor

__all__ = [
    "GBTRegressor",
    "MultiTargetGBT",
    "LinearRegressor",
    "MLPRegressor",
    "RidgeRegressor",
    "SIZE_PRESETS",
    "load_predictor",
    "save_predictor",
    "normalised_rmse",
    "per_target_nrmse",
    "r2",
    "rmse",
]
