from repro.core.predictors.common import (normalised_rmse, per_target_nrmse,
                                          r2, rmse)
from repro.core.predictors.gbt import GBTRegressor, MultiTargetGBT
from repro.core.predictors.linear import RidgeRegressor
from repro.core.predictors.mlp import SIZE_PRESETS, MLPRegressor

__all__ = [
    "GBTRegressor",
    "MultiTargetGBT",
    "MLPRegressor",
    "RidgeRegressor",
    "SIZE_PRESETS",
    "normalised_rmse",
    "per_target_nrmse",
    "r2",
    "rmse",
]
