"""Shared predictor API + metrics (paper Fig. 2 reports normalised RMSE)."""
from __future__ import annotations

from typing import Protocol

import numpy as np


class Regressor(Protocol):
    def fit(self, x: np.ndarray, y: np.ndarray) -> "Regressor": ...
    def predict(self, x: np.ndarray) -> np.ndarray: ...


def rmse(pred: np.ndarray, y: np.ndarray) -> float:
    return float(np.sqrt(np.mean((pred - y) ** 2)))


def normalised_rmse(pred: np.ndarray, y: np.ndarray) -> float:
    """RMSE on min-max-normalised targets (the paper's metric).

    Assumes pred/y are already in the normalised [0,1] space; if not,
    normalise per-target by the span of y.
    """
    span = y.max(axis=0) - y.min(axis=0)
    span = np.where(span > 0, span, 1.0)
    return float(np.sqrt(np.mean(((pred - y) / span) ** 2)))


def per_target_nrmse(pred: np.ndarray, y: np.ndarray) -> np.ndarray:
    span = y.max(axis=0) - y.min(axis=0)
    span = np.where(span > 0, span, 1.0)
    return np.sqrt(np.mean(((pred - y) / span) ** 2, axis=0))


def r2(pred: np.ndarray, y: np.ndarray) -> float:
    ss_res = np.sum((pred - y) ** 2)
    ss_tot = np.sum((y - y.mean(axis=0)) ** 2)
    return float(1.0 - ss_res / max(ss_tot, 1e-12))
