"""Predictor persistence: ``.npz`` arrays + ``.json`` metadata.

Every fitted regressor family saves as two sidecar files —
``<path>.npz`` holding the fitted arrays and ``<path>.json`` holding
the constructor hyper-parameters plus fitted scalars — and loads back
to a model whose ``predict`` is *exactly* equivalent (GBT trees
round-trip through the flattened ``tree_predict`` node arrays, so a
saved ensemble is already its accelerator-lowered form).  Writes go
through a temp file + ``os.replace`` so a reader (the predictor
registry's atomic-swap pointer) never observes a half-written model.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Tuple

import numpy as np

from repro.core.predictors.gbt import GBTRegressor, MultiTargetGBT
from repro.core.predictors.linear import RidgeRegressor
from repro.core.predictors.mlp import MLPRegressor

FORMAT_VERSION = 1


def _hyperparams(model) -> dict:
    return {f.name: getattr(model, f.name)
            for f in dataclasses.fields(model)}


def _atomic_write(path: str, write_fn) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-",
                               suffix=os.path.splitext(path)[1])
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _gbt_arrays(model: GBTRegressor, prefix: str = "") -> dict:
    from repro.kernels.tree_predict.ref import flatten_gbt
    t = flatten_gbt(model)
    return {f"{prefix}feature": t.feature,
            f"{prefix}threshold_bin": t.threshold_bin,
            f"{prefix}left": t.left, f"{prefix}right": t.right,
            f"{prefix}value": t.value, f"{prefix}n_nodes": t.n_nodes,
            f"{prefix}edges": t.edges}


def _gbt_restore(model: GBTRegressor, arrays, meta: dict,
                 prefix: str = "") -> GBTRegressor:
    from repro.kernels.tree_predict.ref import TreeArrays, unflatten_gbt
    t = TreeArrays(arrays[f"{prefix}feature"],
                   arrays[f"{prefix}threshold_bin"],
                   arrays[f"{prefix}left"], arrays[f"{prefix}right"],
                   arrays[f"{prefix}value"], arrays[f"{prefix}n_nodes"],
                   arrays[f"{prefix}edges"], meta["base"],
                   model.learning_rate, 0)
    model.edges_ = arrays[f"{prefix}edges"]
    model.base_ = float(meta["base"])
    model.trees_ = unflatten_gbt(t)
    return model


def save_predictor(model, path: str) -> Tuple[str, str]:
    """Save a fitted regressor; returns ``(npz_path, json_path)``.
    ``path`` is the extension-less base path."""
    if not isinstance(model, (RidgeRegressor, MLPRegressor, GBTRegressor,
                              MultiTargetGBT)):
        raise TypeError(
            f"cannot persist {type(model).__name__}: supported families "
            "are RidgeRegressor, MLPRegressor, GBTRegressor, "
            "MultiTargetGBT")
    meta: dict = {"format": FORMAT_VERSION,
                  "type": type(model).__name__,
                  "params": _hyperparams(model)}
    arrays: dict = {}
    if isinstance(model, RidgeRegressor):
        arrays = {"x_mu": model.x_mu_, "x_sd": model.x_sd_, "w": model.w_}
    elif isinstance(model, MLPRegressor):
        arrays = {f"p_{k}": np.asarray(v)
                  for k, v in model.params_.items()}
        meta["n_layers"] = model.n_layers_
        if model.standardize:
            arrays.update(x_mu=model.x_mu_, x_sd=model.x_sd_,
                          y_mu=model.y_mu_, y_sd=model.y_sd_)
    elif isinstance(model, GBTRegressor):
        arrays = _gbt_arrays(model)
        meta["base"] = model.base_
    else:                                # MultiTargetGBT
        meta["n_targets"] = len(model.models_)
        meta["base"] = [m.base_ for m in model.models_]
        for i, m in enumerate(model.models_):
            arrays.update(_gbt_arrays(m, prefix=f"m{i}_"))
    npz_path, json_path = f"{path}.npz", f"{path}.json"
    _atomic_write(npz_path, lambda f: np.savez(f, **arrays))
    _atomic_write(json_path,
                  lambda f: f.write(json.dumps(meta, indent=1,
                                               default=float).encode()))
    return npz_path, json_path


def load_predictor(path: str):
    """Load a regressor saved by :func:`save_predictor` (``path`` is the
    same extension-less base path); ``predict`` round-trips exactly."""
    with open(f"{path}.json") as f:
        meta = json.load(f)
    if meta.get("format", 0) > FORMAT_VERSION:
        raise ValueError(f"predictor bundle {path!r} has format "
                         f"{meta['format']} > supported {FORMAT_VERSION}")
    arrays = dict(np.load(f"{path}.npz"))
    kind = meta["type"]
    classes = {c.__name__: c for c in (RidgeRegressor, MLPRegressor,
                                       GBTRegressor, MultiTargetGBT)}
    if kind not in classes:
        raise ValueError(f"unknown predictor type {kind!r} in {path}.json")
    params = dict(meta["params"])
    for k, v in params.items():          # JSON lists -> ctor tuples
        if isinstance(v, list):
            params[k] = tuple(v)
    model = classes[kind](**params)
    if kind == "RidgeRegressor":
        model.x_mu_, model.x_sd_, model.w_ = (arrays["x_mu"],
                                              arrays["x_sd"], arrays["w"])
    elif kind == "MLPRegressor":
        model.params_ = {k[2:]: v for k, v in arrays.items()
                         if k.startswith("p_")}
        model.n_layers_ = int(meta["n_layers"])
        if model.standardize:
            model.x_mu_, model.x_sd_ = arrays["x_mu"], arrays["x_sd"]
            model.y_mu_, model.y_sd_ = arrays["y_mu"], arrays["y_sd"]
    elif kind == "GBTRegressor":
        _gbt_restore(model, arrays, meta)
    else:                                # MultiTargetGBT
        model.models_ = []
        for i in range(int(meta["n_targets"])):
            sub = GBTRegressor(
                n_trees=model.n_trees, max_depth=model.max_depth,
                learning_rate=model.learning_rate,
                subsample=model.subsample, n_bins=model.n_bins,
                seed=model.seed + i, use_kernel=model.use_kernel)
            _gbt_restore(sub, arrays, {"base": meta["base"][i]},
                         prefix=f"m{i}_")
            model.models_.append(sub)
    return model
