"""Ridge regression baseline (sanity floor for the predictor comparison)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RidgeRegressor:
    alpha: float = 1.0

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RidgeRegressor":
        x = np.asarray(x, np.float64)
        y = np.atleast_2d(np.asarray(y, np.float64))
        if y.shape[0] != len(x):
            y = y.T
        self.x_mu_ = x.mean(0)
        self.x_sd_ = x.std(0) + 1e-8
        xs = (x - self.x_mu_) / self.x_sd_
        xs = np.concatenate([xs, np.ones((len(xs), 1))], axis=1)
        a = xs.T @ xs + self.alpha * np.eye(xs.shape[1])
        self.w_ = np.linalg.solve(a, xs.T @ y)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xs = (np.asarray(x, np.float64) - self.x_mu_) / self.x_sd_
        xs = np.concatenate([xs, np.ones((len(xs), 1))], axis=1)
        return xs @ self.w_
