"""MLP regression baseline (paper Fig. 2a).

The paper stacks individual MLPs per target and sweeps width/depth from
3,143 to 4,169,991 parameters; accuracy plateaus at nRMSE just below 0.02 —
an order of magnitude worse than the tree ensembles.  Built on the repro
optimiser library (the paper's four optimisers are all selectable).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import batches
from repro.models.layers import init_dense
from repro.optim import apply_updates, get_optimizer

# width presets spanning the paper's 3k .. 4.17M parameter range
SIZE_PRESETS: dict[str, list[int]] = {
    "xs": [32, 16],                       # ~3k params
    "s": [128, 64],
    "m": [512, 256],
    "l": [1024, 512, 256],
    "xl": [2048, 1024, 512],              # ~4.2M params
}


@dataclasses.dataclass
class MLPRegressor:
    hidden: tuple = (256, 128)
    lr: float = 1e-3
    optimiser: str = "adam"
    epochs: int = 300
    batch_size: int = 64
    seed: int = 0
    standardize: bool = True

    def _init(self, f_in: int, f_out: int):
        key = jax.random.key(self.seed)
        dims = [f_in, *self.hidden, f_out]
        keys = jax.random.split(key, len(dims))
        params = {}
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            params[f"w{i}"] = init_dense(keys[i], (a, b), jnp.float32)
            params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
        return params

    @staticmethod
    def _forward(params, x, n_layers):
        h = x
        for i in range(n_layers):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    def fit(self, x: np.ndarray, y: np.ndarray) -> "MLPRegressor":
        x = np.asarray(x, np.float32)
        y = np.atleast_2d(np.asarray(y, np.float32))
        if y.shape[0] == len(x) and y.ndim == 2:
            pass
        else:
            y = y.T
        if self.standardize:
            self.x_mu_, self.x_sd_ = x.mean(0), x.std(0) + 1e-8
            self.y_mu_, self.y_sd_ = y.mean(0), y.std(0) + 1e-8
            x = (x - self.x_mu_) / self.x_sd_
            y = (y - self.y_mu_) / self.y_sd_
        self.n_layers_ = len(self.hidden) + 1
        params = self._init(x.shape[1], y.shape[1])
        opt = get_optimizer(self.optimiser, self.lr)
        state = opt.init(params)
        n_layers = self.n_layers_

        @jax.jit
        def step(params, state, bx, by):
            def loss_fn(p):
                pred = self._forward(p, bx, n_layers)
                return jnp.mean((pred - by) ** 2)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, state2 = opt.update(grads, state, params)
            return apply_updates(params, updates), state2, loss

        for ep in range(self.epochs):
            for bx, by in batches(x, y, min(self.batch_size, len(x)),
                                  seed=self.seed + ep):
                params, state, _ = step(params, state,
                                        jnp.asarray(bx), jnp.asarray(by))
        self.params_ = jax.device_get(params)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if self.standardize:
            x = (x - self.x_mu_) / self.x_sd_
        pred = np.asarray(self._forward(
            {k: jnp.asarray(v) for k, v in self.params_.items()},
            jnp.asarray(x), self.n_layers_))
        if self.standardize:
            pred = pred * self.y_sd_ + self.y_mu_
        return pred

    def param_count(self) -> int:
        return int(sum(int(np.prod(v.shape)) for v in self.params_.values()))
