"""The paper's Table I workload grid: CNN and MLP families × hyperparameters.

These are the AI tasks whose execution the profiler characterises (paper
§II-A / §III-A).  Exact configurations from Table I:

  CNN types:
    1. [{out_channels: 32, kernel: 5, pool}]
    2. [{32, 5, pool}, {64, 3, pool}]
    3. [{64, 5, pool}, {64, 3, pool}, {128, 3, pool}]
  MLP types: [100, 50], [150, 100, 50], [200, 150, 100, 50]
  Epochs: 5, 10, 15, 20
  Optimisers: Adam, SGD, RMSprop, Adagrad
  Learning rates: 0.01, 0.05, 0.001, 0.005, 0.0001, 0.0005
  Batch sizes: 16, 32, 64, 128

Images are 28×28×1 (MNIST-like synthetic), 10 classes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense

IMG = 28
NCLASS = 10

CNN_TYPES: list[list[dict]] = [
    [{"out": 32, "kernel": 5, "pool": True}],
    [{"out": 32, "kernel": 5, "pool": True},
     {"out": 64, "kernel": 3, "pool": True}],
    [{"out": 64, "kernel": 5, "pool": True},
     {"out": 64, "kernel": 3, "pool": True},
     {"out": 128, "kernel": 3, "pool": True}],
]
MLP_TYPES: list[list[int]] = [[100, 50], [150, 100, 50], [200, 150, 100, 50]]
EPOCHS = [5, 10, 15, 20]
OPTIMISERS = ["adam", "sgd", "rmsprop", "adagrad"]
LEARNING_RATES = [0.01, 0.05, 0.001, 0.005, 0.0001, 0.0005]
BATCH_SIZES = [16, 32, 64, 128]


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """One cell of the Table I grid."""
    kind: str                   # "cnn" | "mlp"
    type_idx: int               # index into CNN_TYPES / MLP_TYPES
    epochs: int
    optimiser: str
    lr: float
    batch_size: int
    dataset_size: int = 2048    # synthetic samples (paper varies data size)

    @property
    def arch(self):
        return (CNN_TYPES if self.kind == "cnn" else MLP_TYPES)[self.type_idx]

    def label(self) -> str:
        return (f"{self.kind}{self.type_idx}-e{self.epochs}-{self.optimiser}"
                f"-lr{self.lr}-b{self.batch_size}")


def full_grid() -> Iterator[WorkloadConfig]:
    """The complete Table I cross-product (2 kinds × 3 × 4 × 4 × 6 × 4 =
    2,304 runs; the paper reports >3,000 including data-size variations)."""
    for kind, n_types in (("cnn", len(CNN_TYPES)), ("mlp", len(MLP_TYPES))):
        for ti, ep, op, lr, bs in itertools.product(
                range(n_types), EPOCHS, OPTIMISERS, LEARNING_RATES,
                BATCH_SIZES):
            yield WorkloadConfig(kind, ti, ep, op, lr, bs)


def sample_grid(n: int, seed: int = 0) -> list[WorkloadConfig]:
    import numpy as np
    grid = list(full_grid())
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(grid), size=min(n, len(grid)), replace=False)
    return [grid[i] for i in sorted(idx)]


# --------------------------------------------------------------------------
# Model implementations (pure JAX)
# --------------------------------------------------------------------------
def init_workload_params(wc: WorkloadConfig, key) -> dict:
    keys = jax.random.split(key, 16)
    params: dict = {}
    if wc.kind == "mlp":
        dims = [IMG * IMG] + list(wc.arch) + [NCLASS]
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            params[f"w{i}"] = init_dense(keys[2 * i], (a, b), jnp.float32)
            params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
        return params
    # CNN: conv stack then a dense head
    c_in, hw = 1, IMG
    for i, layer in enumerate(wc.arch):
        k = layer["kernel"]
        params[f"conv{i}"] = init_dense(
            keys[2 * i], (k, k, c_in, layer["out"]), jnp.float32,
            scale=(k * k * c_in) ** -0.5)
        params[f"cb{i}"] = jnp.zeros((layer["out"],), jnp.float32)
        c_in = layer["out"]
        if layer["pool"]:
            hw //= 2
    params["head_w"] = init_dense(keys[-1], (hw * hw * c_in, NCLASS),
                                  jnp.float32)
    params["head_b"] = jnp.zeros((NCLASS,), jnp.float32)
    return params


def workload_forward(params: dict, x: jax.Array, wc: WorkloadConfig):
    """x: [B, 28, 28, 1] (cnn) or [B, 784] (mlp) → logits [B, 10]."""
    if wc.kind == "mlp":
        h = x.reshape(x.shape[0], -1)
        n_layers = len(wc.arch) + 1
        for i in range(n_layers):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h
    h = x.reshape(x.shape[0], IMG, IMG, 1)
    for i, layer in enumerate(wc.arch):
        h = jax.lax.conv_general_dilated(
            h, params[f"conv{i}"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + params[f"cb{i}"])
        if layer["pool"]:
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
    h = h.reshape(h.shape[0], -1)
    return h @ params["head_w"] + params["head_b"]


def workload_loss(params, batch, wc: WorkloadConfig):
    logits = workload_forward(params, batch["x"], wc)
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, acc


def synthetic_image_data(n: int, seed: int = 0):
    """Class-conditional gaussian 'digit' blobs — learnable 10-class task."""
    import numpy as np
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(NCLASS, IMG, IMG, 1)).astype(np.float32)
    y = rng.integers(0, NCLASS, size=n)
    x = protos[y] + 0.8 * rng.normal(size=(n, IMG, IMG, 1)).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)
