"""PO-MDP task scheduling (paper §II-D: "... modelled as an MDP or a
Partially Observable (PO)-MDP, depending on the completeness of state
information from all nodes").

The partially-observable case: node load is hidden; the broker only sees
noisy, delayed observations (the realistic monitoring situation the paper
describes).  We maintain a Bayesian belief over each node's load state and
schedule greedily on belief-expected completion time — the standard QMDP
approximation — and compare against (a) the omniscient MDP scheduler and
(b) an oblivious scheduler that ignores monitoring entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.scheduler import Node, Task

# discrete hidden load states: idle / busy / overloaded (slowdown factors)
LOAD_STATES = np.array([1.0, 2.0, 4.0])
N_STATES = len(LOAD_STATES)

# load Markov dynamics between task arrivals
TRANSITION = np.array([
    [0.8, 0.15, 0.05],
    [0.2, 0.6, 0.2],
    [0.05, 0.25, 0.7],
])

# observation model: monitoring reports the true state with prob ``acc``
def observation_matrix(acc: float) -> np.ndarray:
    off = (1.0 - acc) / (N_STATES - 1)
    return np.full((N_STATES, N_STATES), off) + \
        (acc - off) * np.eye(N_STATES)


@dataclasses.dataclass
class BeliefScheduler:
    """QMDP belief-state scheduler."""
    nodes: Sequence[Node]
    obs_accuracy: float = 0.8
    seed: int = 0

    def __post_init__(self):
        n = len(self.nodes)
        self.belief = np.full((n, N_STATES), 1.0 / N_STATES)
        self.obs_m = observation_matrix(self.obs_accuracy)
        self.rng = np.random.default_rng(self.seed)

    def observe(self, node_idx: int, obs_state: int) -> None:
        """Bayes update from a (noisy) monitoring report."""
        b = self.belief[node_idx] @ TRANSITION        # predict
        b = b * self.obs_m[:, obs_state]              # correct
        self.belief[node_idx] = b / b.sum()

    def expected_slowdown(self, node_idx: int) -> float:
        return float(self.belief[node_idx] @ LOAD_STATES)

    def pick(self, task: Task) -> int:
        """Belief-expected earliest completion."""
        etcs = [n.exec_time(task) * self.expected_slowdown(i)
                + n.available_at
                for i, n in enumerate(self.nodes)]
        return int(np.argmin(etcs))


def simulate(tasks: Sequence[Task], nodes: Sequence[Node], *,
             obs_accuracy: float = 0.8, policy: str = "belief",
             seed: int = 0) -> float:
    """Run the arrival process; returns the makespan.

    policy: "belief" (QMDP), "omniscient" (sees true loads), "oblivious"
    (assumes all nodes idle).
    """
    rng = np.random.default_rng(seed)
    nodes = [dataclasses.replace(n, available_at=0.0) for n in nodes]
    true_state = rng.integers(0, N_STATES, size=len(nodes))
    sched = BeliefScheduler(nodes, obs_accuracy=obs_accuracy,
                            seed=seed + 1)
    obs_m = observation_matrix(obs_accuracy)
    for t in tasks:
        # hidden load evolves
        for i in range(len(nodes)):
            true_state[i] = rng.choice(N_STATES,
                                       p=TRANSITION[true_state[i]])
        # monitoring reports (noisy)
        for i in range(len(nodes)):
            obs = rng.choice(N_STATES, p=obs_m[true_state[i]])
            sched.observe(i, int(obs))
        if policy == "belief":
            j = sched.pick(t)
        elif policy == "omniscient":
            j = int(np.argmin([
                n.exec_time(t) * LOAD_STATES[true_state[i]]
                + n.available_at for i, n in enumerate(nodes)]))
        else:                              # oblivious
            j = int(np.argmin([n.exec_time(t) + n.available_at
                               for n in nodes]))
        real = nodes[j].exec_time(t) * LOAD_STATES[true_state[j]]
        nodes[j].available_at += real
    return max(n.available_at for n in nodes)
