"""Pluggable cost models for offloading decisions (the CostModel API).

The decision core in :mod:`repro.core.decisions` is deliberately dumb: it
argmins a ``[n_envs, L+1]`` matrix.  *What* that matrix measures is this
module's job.  A :class:`CostModel` maps ``(layers, EnvArrays)`` to a
``[n_envs, L+1, n_objectives]`` component tensor with named objectives,
plus a scalarisation that collapses the objective axis for argmin-style
consumers.  Everything downstream — ``decisions.decide_all`` /
``sweep_links``, ``scheduler.etc_matrix``, ``ServeEngine.offload_plan``,
``ContinuousBatchEngine`` re-planning — takes a cost model and stays
oblivious to whether costs are analytic, predicted, or multi-objective.

Three implementations ship here:

  * :class:`AnalyticCost`   — the FLOPs/roofline time model; bit-for-bit
    identical to ``decisions.latency_matrix`` (latency is its only
    objective), so ``decide_all(..., cost=AnalyticCost())`` reproduces the
    historical behaviour exactly.
  * :class:`PredictorCost`  — wraps any *fitted* profiling regressor
    (:class:`repro.core.predictors.Regressor`: GBT / MLP / ridge).  The
    model predicts per-layer execution times from layer + hardware
    features (``DeviceSpec.as_features``), in ONE vectorised ``predict``
    call per decision sweep regardless of how many environments are being
    swept — the paper's profiling→prediction→decision loop at fleet scale.
  * :class:`CompositeCost`  — multi-objective: latency, energy (joules
    from ``tdp_watts``), price, and deadline slack, with scalarisation
    weights and :func:`pareto_front` extraction over the batched matrix.

Usage::

    from repro.core import costs as co, decisions as dec

    cost = co.CompositeCost(weights={"latency_s": 1.0, "energy_j": 0.02})
    plan = dec.decide_all(layers, envs, cost=cost)
    plan.objective("energy_j")            # [E] joules at the chosen split
    front = co.pareto_front(cost.components(layers, envs))  # [E, L+1] mask
"""
# repro: module-tags=fma-sensitive
# (scalarize_weighted must accumulate term-by-term — see its docstring;
#  DET001 rejects any @ / dot / matmul creeping back into this module)
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, ClassVar, Mapping, Optional,
                    Protocol, Sequence)

import numpy as np

from repro.core.decisions import (EnvArrays, latency_components, make_envs,
                                  transfer_bytes, transfer_matrix)
from repro.core.offload import DEFAULT_EFFICIENCY, LayerCost
from repro.hw import DeviceSpec

if TYPE_CHECKING:                # typing-only: keep this module numpy-only
    from repro.core.predictors.common import Regressor


class CostModel(Protocol):
    """Maps (layers, envs) to named per-objective cost components.

    ``components`` returns ``[n_envs, L+1, len(objectives)]``; column ``s``
    is the cost of running layers ``[0, s)`` on-device and the rest on the
    edge.  ``scalarize`` collapses the objective axis to the ``[E, L+1]``
    matrix that argmin-style consumers rank splits by.  Implementations
    may additionally expose ``latency_parts(layers, envs) -> (device,
    transfer, edge)`` latency matrices (used to fill the per-split
    breakdown in :class:`repro.core.decisions.DecisionPlan`) and
    ``task_matrix(tasks, nodes)`` (a fast path for
    :func:`repro.core.scheduler.etc_matrix`).
    """

    @property
    def objectives(self) -> tuple[str, ...]: ...

    def components(self, layers: Sequence[LayerCost],
                   envs: EnvArrays) -> np.ndarray: ...

    def scalarize(self, components: np.ndarray) -> np.ndarray: ...


# --------------------------------------------------------------------------
# Pareto-front extraction over batched component tensors
# --------------------------------------------------------------------------
def pareto_front(costs: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated points, all objectives minimised.

    ``costs`` is ``[N, K]`` (one candidate set) or ``[E, S, K]`` (batched:
    one candidate set per environment); the mask has the input's leading
    shape.  Point ``j`` dominates ``i`` iff it is no worse on every
    objective and strictly better on at least one.
    """
    c = np.asarray(costs, np.float64)
    if c.ndim < 2:
        raise ValueError(f"costs must be [N, K] or [E, S, K], got {c.shape}")
    # [..., i, j]: does j weakly/strictly improve on i in every/any objective
    le = np.all(c[..., None, :, :] <= c[..., :, None, :], axis=-1)
    lt = np.any(c[..., None, :, :] < c[..., :, None, :], axis=-1)
    dominated = np.any(le & lt, axis=-1)
    return ~dominated


def pareto_pick(components: np.ndarray, objectives: Sequence[str],
                weights: Optional[Mapping[str, float]] = None, *,
                subset: Optional[Sequence[str]] = None,
                scalar: Optional[np.ndarray] = None
                ) -> tuple[np.ndarray, np.ndarray]:
    """``(front, picks)``: the non-dominated mask and the scalarised
    argmin *restricted to that front*, per environment.

    ``components`` is ``[..., S, K]`` (candidate splits × objectives).
    ``subset`` names the objectives the domination test runs on
    (default: all of ``objectives``).  The ranking over the front is the
    weighted sum over the full stack, or ``scalar`` — a precomputed
    ``[..., S]`` ranking matrix (e.g. ``cost.scalarize(components)``
    from a model with a bespoke scalarisation) — when given.
    Restricting the argmin to the front is what lets a streaming
    scheduler re-pick along a live Pareto front as the environment
    drifts and still guarantee every pick is non-dominated — an
    unrestricted weighted argmin only guarantees that for strictly
    positive weights.
    """
    comp = np.asarray(components, np.float64)
    names = tuple(objectives)
    if subset is None:
        dom = comp
    else:
        unknown = set(subset) - set(names)
        if unknown:
            raise KeyError(f"unknown objective(s) {sorted(unknown)}; "
                           f"known: {list(names)}")
        dom = comp[..., [names.index(n) for n in subset]]
    front = pareto_front(dom)
    if scalar is None:
        scalar = scalarize_weighted(comp, names, weights)
    else:
        scalar = np.asarray(scalar, np.float64)
        if scalar.shape != comp.shape[:-1]:
            raise ValueError(f"scalar must be {comp.shape[:-1]}, "
                             f"got {scalar.shape}")
    picks = np.argmin(np.where(front, scalar, np.inf), axis=-1)
    return front, picks


def weight_vector(objectives: Sequence[str],
                  weights: Optional[Mapping[str, float]]) -> np.ndarray:
    """Objective-ordered weight vector.  ``weights`` maps objective name →
    weight; omitted names weigh 0, ``None`` means equal weight 1 for every
    objective.  Unknown names raise — a typo would otherwise zero the cost
    matrix and silently degenerate the argmin."""
    if weights is None:
        return np.ones(len(objectives), np.float64)
    unknown = set(weights) - set(objectives)
    if unknown:
        raise KeyError(f"unknown objective(s) {sorted(unknown)}; "
                       f"known: {list(objectives)}")
    return np.asarray([float(weights.get(n, 0.0)) for n in objectives],
                      np.float64)


def scalarize_weighted(components: np.ndarray,
                       objectives: Sequence[str],
                       weights: Optional[Mapping[str, float]]) -> np.ndarray:
    """Weighted sum over the trailing objective axis (see
    :func:`weight_vector` for the weight semantics).

    Accumulated term-by-term in objective order rather than via ``@``: the
    accelerator backends (``repro.kernels.decide_split``) replay the exact
    same multiply/add sequence with one eager jnp primitive per step, which
    is what keeps ``backend="jax"`` scalarisations bit-for-bit equal to
    this host path in f64 (BLAS dot kernels round differently).
    """
    comp = np.asarray(components, np.float64)
    w = weight_vector(objectives, weights)
    if w.size == 0:
        return np.zeros(comp.shape[:-1], np.float64)
    out = comp[..., 0] * w[0]
    for k in range(1, w.size):
        out = out + comp[..., k] * w[k]
    return out


# --------------------------------------------------------------------------
# Accelerator lowering: the scalar spec the jit/Pallas kernels consume
# --------------------------------------------------------------------------
#: canonical objective order of the accelerator decision kernels
ACCEL_OBJECTIVES = ("latency_s", "energy_j", "price", "deadline_slack_s")


@dataclasses.dataclass(frozen=True)
class AccelSpec:
    """Parameters that fully determine a lowerable cost model.

    The jit/Pallas decision kernels (``repro.kernels.decide_split``)
    evaluate one fixed objective stack — latency, energy, price, deadline
    slack, in :data:`ACCEL_OBJECTIVES` order — and scalarise it with
    ``weights``.  Latency-only models are the ``weights = (1, 0, 0, 0)``
    special case.  *Where* per-layer compute times come from is the
    ``lowered`` seam: ``None`` means the analytic roofline (``flops /
    (peak × efficiency)`` from the shared ``EnvArrays`` tensors);
    otherwise it is a :class:`repro.oracle.lowered.LoweredLayerTimes` —
    a fitted profiling regressor compiled to array form, whose
    environment-invariant ``(t_dev, t_edge)`` vectors the kernels turn
    into cumulative-split times on-device.
    """
    efficiency: float
    weights: tuple[float, float, float, float]
    radio_watts: float = 0.0
    price_per_edge_s: float = 0.0
    price_per_gb: float = 0.0
    deadline_s: float = float("inf")
    #: predicted queueing delay at the edge pool (s) — added to the
    #: latency of every offloading split (all but the run-local last
    #: column).  0.0 keeps the historical zero-contention math exactly.
    queue_wait_s: float = 0.0
    #: tail-aware objective: predicted excess of the tail statistic
    #: (p99 or CVaR) of the RTT distribution over its mean, and the
    #: scalarisation weight of the resulting ``tail_latency_s``
    #: objective.  Both 0.0 → the tail column is dropped entirely.
    tail_excess_s: float = 0.0
    tail_weight: float = 0.0
    #: objective names the resulting DecisionPlan carries (a prefix view
    #: of the canonical stack: just latency, or all four)
    objectives: tuple[str, ...] = ("latency_s",)
    #: lowered predictor layer-times, or None for the analytic roofline
    lowered: Optional[object] = dataclasses.field(default=None,
                                                  compare=False)


def lower_to_accel(cost: Optional[CostModel],
                   efficiency: float = DEFAULT_EFFICIENCY) -> AccelSpec:
    """``cost`` → :class:`AccelSpec`, or raise ``TypeError`` if the model
    cannot run on-accelerator.

    ``None`` lowers to the analytic latency-only default at
    ``efficiency``.  Cost models opt in by exposing ``accel_spec()``:
    :class:`AnalyticCost` and :class:`CompositeCost` are pure array math
    over ``EnvArrays``; :class:`PredictorCost` lowers by compiling its
    fitted regressor to array form (``repro.oracle.lowered`` — ridge →
    dot, MLP → jitted matmul chain, GBT → flattened node arrays walked
    by the ``tree_predict`` kernels), and raises ``TypeError`` only when
    the wrapped model is outside those families (arbitrary host Python
    — use ``backend='numpy'``).
    """
    if cost is None:
        return AccelSpec(efficiency, (1.0, 0.0, 0.0, 0.0))
    fn = getattr(cost, "accel_spec", None)
    if fn is None:
        raise TypeError(
            f"{type(cost).__name__} does not lower to the accelerator "
            "decision kernels: backend='jax'/'pallas' needs pure array "
            "math over EnvArrays or a lowerable fitted regressor "
            "(AnalyticCost, CompositeCost, PredictorCost over a ridge/"
            "MLP/GBT model) — use backend='numpy'")
    return fn()


# --------------------------------------------------------------------------
# Analytic cost: the roofline time model, latency-only
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AnalyticCost:
    """FLOPs / (peak × efficiency) latency — wraps ``latency_matrix``
    bit-for-bit, as the single objective ``latency_s``."""

    efficiency: float = DEFAULT_EFFICIENCY

    objectives: ClassVar[tuple[str, ...]] = ("latency_s",)

    def __post_init__(self):
        # memo keyed on (layers, envs) identity — components() and the
        # DecisionPlan breakdown inside one decide_all share one compute.
        # Callers must treat layers/envs as immutable (no in-place edits).
        object.__setattr__(self, "_parts_cache", (None, None, None))

    def components(self, layers, envs) -> np.ndarray:
        dev_cum, xfer, edge_cum = self.latency_parts(layers, envs)
        return (dev_cum + xfer + edge_cum)[..., None]

    def scalarize(self, components: np.ndarray) -> np.ndarray:
        return np.asarray(components)[..., 0]

    def latency_parts(self, layers, envs):
        cached = self._parts_cache
        if cached[0] is layers and cached[1] is envs:
            return cached[2]
        parts = latency_components(layers, envs, self.efficiency)
        object.__setattr__(self, "_parts_cache", (layers, envs, parts))
        return parts

    def accel_spec(self) -> AccelSpec:
        return AccelSpec(self.efficiency, (1.0, 0.0, 0.0, 0.0))


# --------------------------------------------------------------------------
# Predictor cost: the trained profiling model in the decision loop
# --------------------------------------------------------------------------
def default_layer_features(layers: Sequence[LayerCost],
                           spec: DeviceSpec) -> np.ndarray:
    """``[L, F]`` feature rows for per-layer execution-time prediction:
    log-scaled layer size plus the hardware features the paper's profiling
    models train on (``DeviceSpec.as_features``, incl. ``hw_tdp_watts``)."""
    n = len(layers)
    hw = spec.as_features()
    flops = np.fromiter((lc.flops for lc in layers), np.float64, count=n)
    act = np.fromiter((lc.act_bytes for lc in layers), np.float64, count=n)
    cols = [
        np.log10(np.maximum(flops, 1.0)),
        np.log10(np.maximum(act, 1.0)),
        np.full(n, np.log10(max(hw["hw_peak_flops"], 1.0))),
        np.full(n, np.log10(max(hw["hw_hbm_bw"], 1.0))),
        np.full(n, hw["hw_clock_ghz"]),
        np.full(n, hw["hw_is_accelerated"]),
        np.full(n, hw["hw_tdp_watts"]),
    ]
    return np.stack(cols, axis=1).astype(np.float32)


@dataclasses.dataclass
class PredictorCost:
    """Latency from a fitted profiling regressor (GBT / MLP / ridge).

    Per-layer times for the device and edge come from ONE batched
    ``model.predict`` over ``[2L, F]`` feature rows — independent of the
    number of environments being swept, so fleet-scale sweeps stay one
    predict call.  Transfer latency keeps the analytic link model (the
    profiler predicts compute, the radio is observed state).

    Predictions and latency parts are memoised on the *identity* of the
    layers/envs arguments: treat them as immutable (build fresh objects
    per scenario rather than mutating in place), and build a fresh
    PredictorCost after refitting the model.
    """

    model: "Regressor"                   # fitted: predict([N, F]) -> [N]
    device: DeviceSpec
    edge: DeviceSpec
    feature_fn: Callable[[Sequence[LayerCost], DeviceSpec], np.ndarray] = \
        default_layer_features
    target_index: int = 0                # column, for multi-target models

    objectives: ClassVar[tuple[str, ...]] = ("latency_s",)

    def __post_init__(self):
        self._times_cache: tuple = (None, None)
        self._parts_cache: tuple = (None, None, None)
        self._accel_cache: tuple = (None, None)

    def layer_times(self, layers) -> tuple[np.ndarray, np.ndarray]:
        """Predicted per-layer times ``(device [L], edge [L])`` — one
        ``predict`` call, clamped to ≥ 0.  Memoised on the layers object,
        so ``components`` + ``latency_parts`` within one decision sweep
        share a single predict call."""
        if self._times_cache[0] is layers:
            return self._times_cache[1]
        feats = np.concatenate([self.feature_fn(layers, self.device),
                                self.feature_fn(layers, self.edge)], axis=0)
        pred = np.asarray(self.model.predict(feats), np.float64)
        if pred.ndim == 2:
            pred = pred[:, self.target_index]
        pred = np.maximum(pred, 0.0)
        times = (pred[:len(layers)], pred[len(layers):])
        self._times_cache = (layers, times)
        return times

    def latency_parts(self, layers, envs):
        cached = self._parts_cache
        if cached[0] is layers and cached[1] is envs:
            return cached[2]
        t_dev, t_edge = self.layer_times(layers)
        dev_cum = np.concatenate(([0.0], np.cumsum(t_dev)))
        edge_cum = np.concatenate((np.cumsum(t_edge[::-1])[::-1], [0.0]))
        shape = (len(envs), len(layers) + 1)
        parts = (np.broadcast_to(dev_cum, shape),
                 transfer_matrix(layers, envs),
                 np.broadcast_to(edge_cum, shape))
        self._parts_cache = (layers, envs, parts)
        return parts

    def components(self, layers, envs) -> np.ndarray:
        dev_cum, xfer, edge_cum = self.latency_parts(layers, envs)
        return (dev_cum + xfer + edge_cum)[..., None]

    def scalarize(self, components: np.ndarray) -> np.ndarray:
        return np.asarray(components)[..., 0]

    def accel_spec(self) -> AccelSpec:
        """Lower to the accelerator decision kernels by compiling the
        fitted regressor to array form (``repro.oracle.lowered``);
        raises ``TypeError`` when the model has no array lowering.
        Memoised on the model identity so repeated sweeps reuse the
        compiled form (and its per-layer-set predict memo)."""
        if self._accel_cache[0] is self.model:
            return self._accel_cache[1]
        from repro.oracle.lowered import lower_layer_times
        spec = AccelSpec(DEFAULT_EFFICIENCY, (1.0, 0.0, 0.0, 0.0),
                         lowered=lower_layer_times(self))
        self._accel_cache = (self.model, spec)
        return spec

    def task_matrix(self, tasks, nodes) -> np.ndarray:
        """Predicted ``[T, N]`` expected-time-to-compute matrix for
        :func:`repro.core.scheduler.etc_matrix` — one ``predict`` over all
        (task, node) pairs, plus the analytic input-transfer term."""
        layers = [LayerCost(t.name, flops=t.flops, act_bytes=0.0)
                  for t in tasks]
        feats = np.concatenate([self.feature_fn(layers, n.spec)
                                for n in nodes], axis=0)     # [N*T, F]
        pred = np.asarray(self.model.predict(feats), np.float64)
        if pred.ndim == 2:
            pred = pred[:, self.target_index]
        comp = np.maximum(pred, 0.0).reshape(len(nodes), len(tasks)).T
        link = np.asarray([n.spec.link_bw for n in nodes], np.float64)
        inp = np.asarray([t.input_bytes for t in tasks], np.float64)
        return comp + inp[:, None] / np.maximum(link, 1.0)[None, :]


# --------------------------------------------------------------------------
# Composite cost: latency + energy + price + deadline slack
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CompositeCost:
    """Multi-objective cost over a latency-producing base model.

    Objectives, in order:

      * ``latency_s``        — end-to-end latency from ``base``
      * ``energy_j``         — device compute at ``dev_tdp_watts``, radio
                               at ``radio_watts`` during transfer, edge
                               compute at ``edge_tdp_watts``
      * ``price``            — billed edge seconds + shipped gigabytes
      * ``deadline_slack_s`` — ``max(0, latency - deadline_s)`` overrun
      * ``tail_latency_s``   — only when ``tail=`` is set: predicted
                               tail completion (latency + the p99/CVaR_α
                               excess of ``rtt`` over its mean for every
                               offloading split), so schedulers can
                               trade tail latency against energy/price

    ``scalarize`` applies ``weights`` (objective name → weight; ``None``
    means equal weights); :meth:`pareto` extracts the non-dominated splits
    per environment when no single scalarisation is trusted.
    """

    base: CostModel = dataclasses.field(default_factory=AnalyticCost)
    weights: Optional[Mapping[str, float]] = None
    radio_watts: float = 2.5             # device NIC/radio power while TX
    price_per_edge_s: float = 0.0
    price_per_gb: float = 0.0
    deadline_s: float = np.inf
    #: ``"p99"`` or ``"cvar"`` turns on the fifth ``tail_latency_s``
    #: objective over the ``rtt`` delay process; ``None`` (default)
    #: keeps the historical 4-objective stack byte-identical.
    tail: Optional[str] = None
    tail_alpha: float = 0.99
    rtt: Optional[object] = None         # a queueing.DelayProcess

    objectives: ClassVar[tuple[str, ...]] = (
        "latency_s", "energy_j", "price", "deadline_slack_s")

    def __post_init__(self):
        if not hasattr(self.base, "latency_parts"):
            raise TypeError(
                f"CompositeCost base {type(self.base).__name__} must "
                "expose latency_parts(layers, envs) — the energy/price/"
                "slack objectives need the (device, transfer, edge) "
                "latency decomposition, not just totals")
        if self.tail is not None:
            if self.tail not in ("p99", "cvar"):
                raise ValueError(f"tail must be 'p99' or 'cvar', "
                                 f"got {self.tail!r}")
            if self.rtt is None:
                raise ValueError(
                    "tail= needs an rtt= delay process (e.g. "
                    "repro.sim.queueing.WeibullRTT) to take the tail "
                    "statistic over")
            # shadow the ClassVar: this instance carries five objectives
            self.objectives = CompositeCost.objectives + (
                "tail_latency_s",)

    def tail_excess_s(self) -> float:
        """Predicted excess of the tail RTT statistic over its mean —
        the per-offload premium the ``tail_latency_s`` column adds."""
        if self.tail is None:
            return 0.0
        return max(self.rtt.tail_stat(self.tail, self.tail_alpha)
                   - self.rtt.mean(), 0.0)

    def components(self, layers, envs) -> np.ndarray:
        dev_t, xfer_t, edge_t = self.base.latency_parts(layers, envs)
        total = dev_t + xfer_t + edge_t
        dev_w = _tdp_or_zero(envs.dev_tdp_watts, len(envs))
        edge_w = _tdp_or_zero(envs.edge_tdp_watts, len(envs))
        energy = dev_t * dev_w[:, None] + xfer_t * self.radio_watts \
            + edge_t * edge_w[:, None]
        price = edge_t * self.price_per_edge_s \
            + transfer_bytes(layers, envs) / 1e9 * self.price_per_gb
        slack = np.maximum(total - self.deadline_s, 0.0)
        if self.tail is None:
            return np.stack([total, energy, price, slack], axis=-1)
        tail_col = total.copy()
        tail_col[..., :-1] += self.tail_excess_s()  # last split: no RTT
        return np.stack([total, energy, price, slack, tail_col],
                        axis=-1)

    def scalarize(self, components: np.ndarray) -> np.ndarray:
        return scalarize_weighted(components, self.objectives, self.weights)

    def latency_parts(self, layers, envs):
        return self.base.latency_parts(layers, envs)

    def pareto(self, layers, envs) -> np.ndarray:
        """``[E, L+1]`` mask of Pareto-optimal splits per environment."""
        return pareto_front(self.components(layers, envs))

    def accel_spec(self) -> AccelSpec:
        base_fn = getattr(self.base, "accel_spec", None)
        if base_fn is None:
            raise TypeError(
                f"CompositeCost over base {type(self.base).__name__} does "
                "not lower to the accelerator decision kernels — the base "
                "must be pure array math (AnalyticCost) or a lowerable "
                "PredictorCost; use backend='numpy'")
        base = base_fn()        # may itself raise for host-only regressors
        if base.objectives != ("latency_s",):
            raise TypeError(
                "CompositeCost needs a latency-only base (AnalyticCost "
                "or PredictorCost) to lower — a base carrying its own "
                "objective stack would be silently overwritten")
        w = weight_vector(self.objectives, self.weights)
        return dataclasses.replace(
            base, weights=tuple(float(x) for x in w[:4]),
            radio_watts=self.radio_watts,
            price_per_edge_s=self.price_per_edge_s,
            price_per_gb=self.price_per_gb,
            deadline_s=float(self.deadline_s),
            tail_excess_s=float(self.tail_excess_s()),
            tail_weight=float(w[4]) if self.tail is not None else 0.0,
            objectives=self.objectives)


def _tdp_or_zero(tdp: Optional[np.ndarray], n: int) -> np.ndarray:
    if tdp is None:
        return np.zeros(n)
    return np.asarray(tdp, np.float64)


# --------------------------------------------------------------------------
# Queue-aware cost: live pool state folded into any cost model
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QueueAwareCost:
    """Sojourn-aware wrapper: predicted completion = wait + service (+
    transfer) over any base :class:`CostModel`.

    Two seams feed the predicted queueing delay:

      * ``edge_pool`` — a live :class:`repro.sim.queueing.ServerPool`
        for the edge server the split decision offloads to; its current
        ``wait(now)`` is added to every offloading split (all columns
        but the run-local last one), and re-read on every call so the
        wrapper tracks pool state with zero bookkeeping;
      * ``pools`` — a :class:`repro.sim.queueing.NodePools` for the
        placement path: :meth:`task_matrix` adds per-node waits to the
        base ETC matrix, so min-min/HEFT see contention directly.

    ``rtt`` (a ``DelayProcess``) optionally adds the *mean* RTT to
    offloading latencies; pair with ``CompositeCost(tail=...)`` when the
    tail, not the mean, should drive the pick.  Advance virtual time
    with :meth:`set_now` (the simulators do this before each decision).
    """

    base: CostModel = dataclasses.field(default_factory=AnalyticCost)
    edge_pool: Optional[object] = None      # queueing.ServerPool
    pools: Optional[object] = None          # queueing.NodePools
    rtt: Optional[object] = None            # queueing.DelayProcess
    wait_s: float = 0.0                     # static extra wait (tests)
    now: float = 0.0

    def set_now(self, now: float) -> None:
        self.now = float(now)

    @property
    def objectives(self) -> tuple[str, ...]:
        return self.base.objectives

    def _edge_wait(self) -> float:
        w = float(self.wait_s)
        if self.edge_pool is not None:
            w += float(self.edge_pool.wait(self.now))
        if self.rtt is not None:
            w += float(self.rtt.mean())
        return w

    def components(self, layers, envs) -> np.ndarray:
        comp = np.array(self.base.components(layers, envs), np.float64)
        w = self._edge_wait()
        if w > 0.0:
            comp[..., :-1, 0] += w          # offloading splits wait
        return comp

    def scalarize(self, components: np.ndarray) -> np.ndarray:
        return self.base.scalarize(components)

    def latency_parts(self, layers, envs):
        dev_t, xfer_t, edge_t = self.base.latency_parts(layers, envs)
        w = self._edge_wait()
        if w > 0.0:
            xfer_t = np.array(xfer_t, np.float64)
            xfer_t[..., :-1] += w           # book the wait with transfer
        return dev_t, xfer_t, edge_t

    def task_matrix(self, tasks, nodes) -> np.ndarray:
        etc = etc_from_cost(self.base, tasks, nodes)
        extra = np.zeros(etc.shape[1], np.float64)
        if self.pools is not None:
            extra = extra + self.pools.waits(self.now)
        if self.rtt is not None:
            extra = extra + float(self.rtt.mean())
        if self.wait_s:
            extra = extra + float(self.wait_s)
        return etc + extra[None, :]

    def accel_spec(self) -> AccelSpec:
        spec = lower_to_accel(self.base)
        return dataclasses.replace(
            spec, queue_wait_s=float(spec.queue_wait_s
                                     + self._edge_wait()))


# --------------------------------------------------------------------------
# Cost-model-driven ETC matrices for the scheduler
# --------------------------------------------------------------------------
def etc_from_cost(cost: CostModel, tasks, nodes) -> np.ndarray:
    """``[T, N]`` scalarised cost of running each task wholly on each node.

    Each task becomes a one-layer chain evaluated at split 0 — the task
    ships its input over the node's link and executes remotely — which for
    :class:`AnalyticCost` reproduces ``Node.exec_time`` exactly.  Cost
    models exposing a ``task_matrix`` fast path (:class:`PredictorCost`)
    are dispatched to it instead.
    """
    fast = getattr(cost, "task_matrix", None)
    if fast is not None:
        return fast(tasks, nodes)
    specs = [n.spec for n in nodes]
    link = np.asarray([s.link_bw for s in specs], np.float64)
    out = np.empty((len(tasks), len(specs)))
    for i, t in enumerate(tasks):
        layers = [LayerCost(t.name, flops=t.flops, act_bytes=0.0)]
        envs = make_envs(specs, specs, link_bw=link, link_latency_s=0.0,
                         input_bytes=t.input_bytes)
        out[i] = cost.scalarize(cost.components(layers, envs))[:, 0]
    return out
