"""Computation offloading policies (paper §II-C).

Split computing: the first ``s`` layers of a network run on the device, the
activation at the split crosses the link, the remaining layers run on the
edge server.  Costs come from a :class:`CostModel` — either analytic
(FLOPs/roofline) or *predicted by the trained profiling model* (the paper's
point: profiling → prediction → offloading decisions).

Policies:
  * ``local_only`` / ``remote_only`` — degenerate baselines
  * ``greedy``   — walk split points until the marginal move stops helping
  * ``optimal``  — exact: evaluate all L+1 split points (O(L), the DP
                   closed form for a chain graph)
  * ``QLearningPolicy`` — tabular DRL over stochastic link states (the
                   paper names DRL as the usual controller)

The decision core is array-native: :func:`split_times_all` evaluates every
split latency in O(L) via forward/backward prefix sums, and ``optimal`` /
``greedy`` are thin argmin/scan wrappers over it.  The ``*_ref`` variants
keep the original scalar loops as oracles for the equivalence tests.
Batched sweeps over many environments live in :mod:`repro.core.decisions`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.hw import DeviceSpec


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Static per-layer profile (per batch)."""
    name: str
    flops: float                 # compute cost of the layer
    act_bytes: float             # activation size flowing OUT of the layer
    param_bytes: float = 0.0


@dataclasses.dataclass(frozen=True)
class OffloadEnv:
    device: DeviceSpec
    edge: DeviceSpec
    link_bw: float               # bytes/s currently available
    link_latency_s: float = 0.005
    input_bytes: float = 0.0     # bytes to ship if split at 0 (raw input)


DEFAULT_EFFICIENCY = 0.35            # effective MFU of the analytic model


def layer_time(flops: float, dev: DeviceSpec,
               efficiency: float = DEFAULT_EFFICIENCY) -> float:
    """Simple effective-throughput model (efficiency ≈ measured MFU)."""
    return flops / (dev.peak_flops_f32 * efficiency)


@dataclasses.dataclass
class SplitDecision:
    split: int                   # layers [0, split) on device, rest on edge
    total_time_s: float
    device_time_s: float
    transfer_time_s: float
    edge_time_s: float


def split_time(layers: Sequence[LayerCost], split: int, env: OffloadEnv,
               time_fn: Optional[Callable[[LayerCost, DeviceSpec], float]]
               = None) -> SplitDecision:
    """Latency of executing with the given split point (0..L)."""
    time_fn = time_fn or (lambda lc, dev: layer_time(lc.flops, dev))
    dev_t = sum(time_fn(lc, env.device) for lc in layers[:split])
    edge_t = sum(time_fn(lc, env.edge) for lc in layers[split:])
    if split == len(layers):
        xfer = 0.0
    else:
        xfer_bytes = (layers[split - 1].act_bytes if split > 0
                      else env.input_bytes)
        xfer = env.link_latency_s + xfer_bytes / max(env.link_bw, 1.0)
    return SplitDecision(split, dev_t + xfer + edge_t, dev_t, xfer, edge_t)


def local_only(layers, env, **kw) -> SplitDecision:
    return split_time(layers, len(layers), env, **kw)


def remote_only(layers, env, **kw) -> SplitDecision:
    return split_time(layers, 0, env, **kw)


# --------------------------------------------------------------------------
# Vectorized all-splits evaluation: O(L) prefix sums instead of O(L²)
# --------------------------------------------------------------------------
def layer_time_vector(layers: Sequence[LayerCost], dev: DeviceSpec,
                      time_fn: Optional[Callable[[LayerCost, DeviceSpec],
                                                 float]] = None
                      ) -> np.ndarray:
    """Per-layer execution times on ``dev`` as a float64 ``[L]`` vector."""
    if time_fn is None:
        flops = np.fromiter((lc.flops for lc in layers), dtype=np.float64,
                            count=len(layers))
        return flops / (dev.peak_flops_f32 * DEFAULT_EFFICIENCY)
    return np.fromiter((time_fn(lc, dev) for lc in layers),
                       dtype=np.float64, count=len(layers))


def split_components(layers: Sequence[LayerCost], env: OffloadEnv,
                     time_fn=None
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``(device, transfer, edge)`` time vectors, each ``[L+1]``, indexed by
    split point: forward prefix sum of device times, backward prefix sum of
    edge times, and the activation-transfer vector."""
    L = len(layers)
    t_dev = layer_time_vector(layers, env.device, time_fn)
    t_edge = layer_time_vector(layers, env.edge, time_fn)
    dev_cum = np.concatenate(([0.0], np.cumsum(t_dev)))
    edge_cum = np.concatenate((np.cumsum(t_edge[::-1])[::-1], [0.0]))
    xfer_bytes = np.concatenate(
        ([env.input_bytes], [lc.act_bytes for lc in layers]))
    xfer = env.link_latency_s + xfer_bytes / max(env.link_bw, 1.0)
    xfer[L] = 0.0                     # split == L ships nothing
    return dev_cum, xfer, edge_cum


def split_times_all(layers: Sequence[LayerCost], env: OffloadEnv,
                    time_fn=None) -> np.ndarray:
    """Total latency of *every* split point as a ``[L+1]`` vector, O(L)."""
    dev_cum, xfer, edge_cum = split_components(layers, env, time_fn)
    return dev_cum + xfer + edge_cum


def _decision_at(split: int, dev_cum, xfer, edge_cum) -> SplitDecision:
    return SplitDecision(int(split),
                         float(dev_cum[split] + xfer[split]
                               + edge_cum[split]),
                         float(dev_cum[split]), float(xfer[split]),
                         float(edge_cum[split]))


def optimal_split(layers, env, *, time_fn=None) -> SplitDecision:
    """Exact best split: argmin over :func:`split_times_all`."""
    comps = split_components(layers, env, time_fn)
    total = comps[0] + comps[1] + comps[2]
    return _decision_at(int(np.argmin(total)), *comps)


def optimal_split_ref(layers, env, **kw) -> SplitDecision:
    """Scalar O(L²) oracle retained for equivalence tests/benchmarks."""
    return min((split_time(layers, s, env, **kw)
                for s in range(len(layers) + 1)),
               key=lambda d: d.total_time_s)


def greedy_split(layers, env, *, time_fn=None) -> SplitDecision:
    """Start local-only; move the split point while it helps — a scan over
    the precomputed all-splits vector (one O(L) pass, no re-summation)."""
    comps = split_components(layers, env, time_fn)
    total = comps[0] + comps[1] + comps[2]
    best = len(layers)
    for s in range(len(layers) - 1, -1, -1):
        if total[s] <= total[best]:
            best = s
        else:
            break
    return _decision_at(best, *comps)


def greedy_split_ref(layers, env, **kw) -> SplitDecision:
    """Scalar oracle for :func:`greedy_split` (original walk)."""
    best = local_only(layers, env, **kw)
    for s in range(len(layers) - 1, -1, -1):
        cand = split_time(layers, s, env, **kw)
        if cand.total_time_s <= best.total_time_s:
            best = cand
        else:
            break
    return best


# --------------------------------------------------------------------------
# Tabular Q-learning over stochastic link states (the DRL controller)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QLearningPolicy:
    """State = discretised link bandwidth bucket; action = split point."""
    layers: Sequence[LayerCost]
    env_base: OffloadEnv
    link_buckets: tuple = (0.125e9 / 16, 0.125e9 / 4, 0.125e9, 1.25e9)
    episodes: int = 3000
    alpha: float = 0.2
    gamma: float = 0.0           # contextual bandit: immediate latency
    eps: float = 0.2
    seed: int = 0

    def __post_init__(self):
        self.n_actions = len(self.layers) + 1
        self.q_ = np.zeros((len(self.link_buckets), self.n_actions))

    def _env_for(self, bucket: int) -> OffloadEnv:
        return dataclasses.replace(self.env_base,
                                   link_bw=self.link_buckets[bucket])

    def latency_table(self) -> np.ndarray:
        """``[n_buckets, n_actions]`` latency of every (link state, split)."""
        return np.stack([split_times_all(self.layers, self._env_for(b))
                         for b in range(len(self.link_buckets))])

    def train(self, batch_size: int = 256) -> "QLearningPolicy":
        """Table-driven training: rewards come from a precomputed
        ``[n_buckets, n_actions]`` latency table and episodes run in
        vectorized batches (greedy actions frozen per batch).  Within a
        batch the k repeated updates of one ``(s, a)`` cell collapse to
        the exact closed form ``q ← r + (1-α)^k (q - r)`` because the
        reward of a cell is deterministic.

        The batch size is capped so the number of greedy refreshes
        (``episodes / batch``) stays ≥ 2× the action count: with
        negative rewards and optimistic-zero init, greedy exploration
        advances one action per refresh, so freezing it for too long
        leaves deep action spaces (large L) under-visited and the argmax
        biased toward under-trained cells."""
        rng = np.random.default_rng(self.seed)
        table = self.latency_table()
        reward = -table
        n_s, n_a = table.shape
        batch_size = int(np.clip(self.episodes // (2 * n_a), 1, batch_size))
        remaining = self.episodes
        while remaining > 0:
            m = min(batch_size, remaining)
            remaining -= m
            s = rng.integers(n_s, size=m)
            explore = rng.random(m) < self.eps
            a = np.where(explore, rng.integers(n_a, size=m),
                         np.argmax(self.q_[s], axis=1))
            counts = np.bincount(s * n_a + a,
                                 minlength=n_s * n_a).reshape(n_s, n_a)
            decay = (1.0 - self.alpha) ** counts
            self.q_ = np.where(counts > 0,
                               reward + decay * (self.q_ - reward),
                               self.q_)
        return self

    def decide(self, link_bw: float) -> SplitDecision:
        bucket = int(np.argmin([abs(link_bw - b) for b in self.link_buckets]))
        a = int(np.argmax(self.q_[bucket]))
        env = dataclasses.replace(self.env_base, link_bw=link_bw)
        return split_time(self.layers, a, env)

    def regret(self) -> float:
        """Mean latency gap to the optimal split across link states."""
        gaps = []
        for b in range(len(self.link_buckets)):
            env = self._env_for(b)
            learned = split_time(self.layers, int(np.argmax(self.q_[b])), env)
            best = optimal_split(self.layers, env)
            gaps.append(learned.total_time_s - best.total_time_s)
        return float(np.mean(gaps))


# --------------------------------------------------------------------------
# Per-layer costs for the Table-I workloads + assigned transformer archs
# --------------------------------------------------------------------------
def workload_layer_costs(wc, batch_size: Optional[int] = None
                         ) -> list[LayerCost]:
    """Analytic per-layer costs of a Table-I CNN/MLP (inference)."""
    from repro.core.workloads import IMG, NCLASS
    bs = batch_size or wc.batch_size
    costs = []
    if wc.kind == "mlp":
        dims = [IMG * IMG] + list(wc.arch) + [NCLASS]
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            costs.append(LayerCost(
                f"fc{i}", flops=2.0 * bs * a * b,
                act_bytes=4.0 * bs * b, param_bytes=4.0 * a * b))
        return costs
    hw, c_in = IMG, 1
    for i, layer in enumerate(wc.arch):
        k, c_out = layer["kernel"], layer["out"]
        flops = 2.0 * bs * hw * hw * k * k * c_in * c_out
        if layer["pool"]:
            hw //= 2
        costs.append(LayerCost(
            f"conv{i}", flops=flops, act_bytes=4.0 * bs * hw * hw * c_out,
            param_bytes=4.0 * k * k * c_in * c_out))
        c_in = c_out
    costs.append(LayerCost(
        "head", flops=2.0 * bs * hw * hw * c_in * NCLASS,
        act_bytes=4.0 * bs * NCLASS,
        param_bytes=4.0 * hw * hw * c_in * NCLASS))
    return costs


def transformer_layer_costs(cfg, seq_len: int, batch_size: int
                            ) -> list[LayerCost]:
    """Analytic per-layer inference costs of an assigned architecture —
    the pod-scale analogue used by the placement simulator."""
    d, l = cfg.d_model, max(cfg.num_layers, 1)
    t = seq_len * batch_size
    attn_proj = 2.0 * t * d * (cfg.num_heads * cfg.head_dim) * 2
    attn_kv = 2.0 * t * d * (cfg.num_kv_heads * cfg.head_dim) * 2
    attn_scores = 2.0 * batch_size * cfg.num_heads * seq_len * seq_len \
        * cfg.head_dim * 2
    if cfg.num_experts:
        ff = 3 * 2.0 * t * d * cfg.moe_d_ff * (cfg.top_k
                                               + cfg.num_shared_experts)
    elif cfg.d_ff:
        n_mat = 2 if cfg.mlp_act in ("gelu_plain", "relu2") else 3
        ff = n_mat * 2.0 * t * d * cfg.d_ff
    else:
        ff = 2.0 * t * d * d * 4     # xlstm-style block projections
    per_layer = attn_proj + attn_kv + attn_scores + ff
    act = 2.0 * t * d
    return [LayerCost(f"layer{i}", flops=per_layer, act_bytes=act)
            for i in range(l)]
