"""Task scheduling across heterogeneous edge nodes (paper §II-D).

Pipeline: *task brokering* (queue of offloaded AI tasks) → *resource & time
prediction* (the global profiling model supplies the expected-time-to-
compute matrix) → *infrastructure monitoring* (node availability) →
scheduling.

Schedulers: round-robin / random baselines, min-min and max-min list
scheduling (classic ETC heuristics), HEFT-style earliest-finish-time, and
an exact MDP value-iteration formulation for small instances (the paper
frames scheduling as an (PO-)MDP).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.offload import DEFAULT_EFFICIENCY
from repro.hw import DeviceSpec


@dataclasses.dataclass(frozen=True)
class Task:
    """One brokered AI task (a profiling-grid workload or an arch config)."""
    name: str
    flops: float
    input_bytes: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Node:
    spec: DeviceSpec
    available_at: float = 0.0    # infrastructure monitoring: busy-until

    def exec_time(self, task: Task,
                  efficiency: float = DEFAULT_EFFICIENCY) -> float:
        comp = task.flops / (self.spec.peak_flops_f32 * efficiency)
        xfer = task.input_bytes / max(self.spec.link_bw, 1.0)
        return comp + xfer


@dataclasses.dataclass
class Assignment:
    task: Task
    node: str
    start: float
    finish: float


@dataclasses.dataclass
class Schedule:
    assignments: list[Assignment]

    @property
    def makespan(self) -> float:
        return max((a.finish for a in self.assignments), default=0.0)

    @property
    def mean_completion(self) -> float:
        return float(np.mean([a.finish for a in self.assignments])) \
            if self.assignments else 0.0

    def deadline_misses(self) -> int:
        return sum(1 for a in self.assignments
                   if a.task.deadline_s is not None
                   and a.finish > a.task.deadline_s)


def etc_matrix(tasks: Sequence[Task], nodes: Sequence[Node],
               predictor: Optional[Callable[[Task, Node], float]] = None,
               *, cost=None) -> np.ndarray:
    """Expected-time-to-compute matrix [T, N].

    ``cost`` plugs in a :class:`repro.core.costs.CostModel`: each task is
    costed as running wholly on each node (``PredictorCost`` batches all
    (task, node) pairs into one ``predict`` call; ``CompositeCost`` yields
    a scalarised multi-objective ETC).  ``predictor`` is the older scalar
    hook — a ``(task, node) -> seconds`` callable (paper §II-D: "resource
    and time prediction using global profiling models").  Default is the
    analytic roofline estimate.
    """
    if cost is not None:
        from repro.core.costs import etc_from_cost
        return etc_from_cost(cost, tasks, nodes)
    fn = predictor or (lambda t, n: n.exec_time(t))
    return np.array([[fn(t, n) for n in nodes] for t in tasks])


def _fresh(nodes: Sequence[Node]) -> list[Node]:
    return [dataclasses.replace(n) for n in nodes]


def masked_argmin(fin: np.ndarray, active: np.ndarray) -> tuple[int, int]:
    """The min-min pick: ``(task, node)`` of the smallest finish time
    among the ``active`` rows of a ``[T, N]`` finish matrix, row-major
    first occurrence on ties.  Shared by the batch :func:`min_min` and
    the incremental streaming scheduler (:mod:`repro.sim.stream`), so
    the two stay tie-break-for-tie-break identical."""
    flat = int(np.argmin(np.where(active[:, None], fin, np.inf)))
    i, j = divmod(flat, fin.shape[1])
    return i, j


def _assign(task, node, etc_tn) -> Assignment:
    start = node.available_at
    finish = start + etc_tn
    node.available_at = finish
    return Assignment(task, node.spec.name, start, finish)


def round_robin(tasks, nodes, etc) -> Schedule:
    nodes = _fresh(nodes)
    out = [_assign(t, nodes[i % len(nodes)], etc[i, i % len(nodes)])
           for i, t in enumerate(tasks)]
    return Schedule(out)


def random_schedule(tasks, nodes, etc, seed: int = 0) -> Schedule:
    rng = np.random.default_rng(seed)
    nodes = _fresh(nodes)
    out = []
    for i, t in enumerate(tasks):
        j = int(rng.integers(len(nodes)))
        out.append(_assign(t, nodes[j], etc[i, j]))
    return Schedule(out)


def min_min(tasks, nodes, etc) -> Schedule:
    """Classic min-min: repeatedly place the task with the smallest
    earliest-completion-time.

    Array-native: one masked argmin over the ``[T, N]`` finish matrix per
    placement; only the placed node's column is refreshed.  Bit-for-bit
    equivalent to :func:`min_min_ref` (same arithmetic, same row-major
    first-occurrence tie-break)."""
    if len(tasks) == 0:
        return Schedule([])
    etc = np.asarray(etc, np.float64).reshape(len(tasks), len(nodes))
    n_t, n_n = etc.shape
    avail = np.asarray([n.available_at for n in nodes], np.float64)
    fin = avail[None, :] + etc
    active = np.ones(n_t, bool)
    out = []
    for _ in range(n_t):
        i, j = masked_argmin(fin, active)
        out.append(Assignment(tasks[i], nodes[j].spec.name,
                              float(avail[j]), float(fin[i, j])))
        avail[j] = fin[i, j]
        active[i] = False
        fin[:, j] = avail[j] + etc[:, j]
    return Schedule(out)


def min_min_ref(tasks, nodes, etc) -> Schedule:
    """Scalar min-min oracle (original nested loops), kept for tests."""
    nodes = _fresh(nodes)
    remaining = list(range(len(tasks)))
    out = []
    while remaining:
        best = None
        for i in remaining:
            for j, n in enumerate(nodes):
                fin = n.available_at + etc[i, j]
                if best is None or fin < best[0]:
                    best = (fin, i, j)
        _, i, j = best
        out.append(_assign(tasks[i], nodes[j], etc[i, j]))
        remaining.remove(i)
    return Schedule(out)


def max_min(tasks, nodes, etc) -> Schedule:
    """max-min: place the *largest* task first (better balance for skew).

    Vectorized like :func:`min_min`: per-row argmin picks each task's best
    node, a masked argmax picks the worst-off task."""
    if len(tasks) == 0:
        return Schedule([])
    etc = np.asarray(etc, np.float64).reshape(len(tasks), len(nodes))
    n_t, n_n = etc.shape
    avail = np.asarray([n.available_at for n in nodes], np.float64)
    fin = avail[None, :] + etc
    active = np.ones(n_t, bool)
    out = []
    for _ in range(n_t):
        masked = np.where(active[:, None], fin, np.inf)
        best_j = np.argmin(masked, axis=1)
        best_fin = masked[np.arange(n_t), best_j]
        i = int(np.argmax(np.where(active, best_fin, -np.inf)))
        j = int(best_j[i])
        out.append(Assignment(tasks[i], nodes[j].spec.name,
                              float(avail[j]), float(fin[i, j])))
        avail[j] = fin[i, j]
        active[i] = False
        fin[:, j] = avail[j] + etc[:, j]
    return Schedule(out)


def max_min_ref(tasks, nodes, etc) -> Schedule:
    """Scalar max-min oracle (original nested loops), kept for tests."""
    nodes = _fresh(nodes)
    remaining = list(range(len(tasks)))
    out = []
    while remaining:
        picks = {}
        for i in remaining:
            fins = [(n.available_at + etc[i, j], j)
                    for j, n in enumerate(nodes)]
            picks[i] = min(fins)
        i = max(picks, key=lambda i_: picks[i_][0])
        fin, j = picks[i]
        out.append(_assign(tasks[i], nodes[j], etc[i, j]))
        remaining.remove(i)
    return Schedule(out)


def heft(tasks, nodes, etc) -> Schedule:
    """HEFT-lite for independent tasks: rank by mean ETC descending, place
    each on the earliest-finish node (argmin over the node-availability
    vector, no per-node Python objects)."""
    if len(tasks) == 0:
        return Schedule([])
    etc = np.asarray(etc, np.float64).reshape(len(tasks), len(nodes))
    avail = np.asarray([n.available_at for n in nodes], np.float64)
    order = np.argsort(-etc.mean(axis=1))
    out = []
    for i in order:
        j = int(np.argmin(avail + etc[i]))
        start = float(avail[j])
        finish = start + float(etc[i, j])
        avail[j] = finish
        out.append(Assignment(tasks[int(i)], nodes[j].spec.name,
                              start, finish))
    return Schedule(out)


def heft_ref(tasks, nodes, etc) -> Schedule:
    """Scalar HEFT-lite oracle (original loops), kept for tests."""
    nodes = _fresh(nodes)
    order = np.argsort(-etc.mean(axis=1))
    out = []
    for i in order:
        j = int(np.argmin([n.available_at + etc[i, j]
                           for j, n in enumerate(nodes)]))
        out.append(_assign(tasks[i], nodes[j], etc[i, j]))
    return Schedule(out)


def optimal_bruteforce(tasks, nodes, etc) -> Schedule:
    """Exact minimum-makespan assignment (tiny instances only)."""
    best = None
    for combo in itertools.product(range(len(nodes)), repeat=len(tasks)):
        loads = np.zeros(len(nodes))
        for i, j in enumerate(combo):
            loads[j] += etc[i, j]
        mk = loads.max()
        if best is None or mk < best[0]:
            best = (mk, combo)
    _, combo = best
    nodes = _fresh(nodes)
    return Schedule([_assign(tasks[i], nodes[j], etc[i, j])
                     for i, j in enumerate(combo)])


SCHEDULERS: dict[str, Callable] = {
    "round_robin": round_robin,
    "random": random_schedule,
    "min_min": min_min,
    "max_min": max_min,
    "heft": heft,
}

# scalar oracles, exercised by the equivalence tests and benchmarks
SCHEDULERS_REF: dict[str, Callable] = {
    "min_min": min_min_ref,
    "max_min": max_min_ref,
    "heft": heft_ref,
}


# --------------------------------------------------------------------------
# MDP formulation (paper: "modelled as an MDP or PO-MDP")
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SchedulingMDP:
    """Exact finite-horizon MDP for sequential task arrival.

    State: (next task index, discretised node-backlog vector).
    Action: node for the current task.  Cost: increase in makespan.
    Solved by backward value iteration — the optimal policy lower-bounds
    the heuristics on small instances (tested).
    """
    tasks: Sequence[Task]
    nodes: Sequence[Node]
    etc: np.ndarray
    backlog_levels: int = 8

    def solve(self) -> float:
        levels = self.backlog_levels
        etc = self.etc
        t_max = etc.sum()
        step = t_max / (levels - 1) if levels > 1 else t_max

        def discretise(b: float) -> int:
            return min(int(round(b / step)), levels - 1)

        n_nodes = len(self.nodes)
        from functools import lru_cache

        @lru_cache(maxsize=None)
        def value(i: int, backlog: tuple) -> float:
            if i == len(self.tasks):
                return max(backlog) * step
            best = np.inf
            for j in range(n_nodes):
                b = list(backlog)
                b[j] = discretise(b[j] * step + etc[i, j])
                best = min(best, value(i + 1, tuple(b)))
            return best

        return float(value(0, tuple([0] * n_nodes)))
