"""Task scheduling across heterogeneous edge nodes (paper §II-D).

Pipeline: *task brokering* (queue of offloaded AI tasks) → *resource & time
prediction* (the global profiling model supplies the expected-time-to-
compute matrix) → *infrastructure monitoring* (node availability) →
scheduling.

Schedulers: round-robin / random baselines, min-min and max-min list
scheduling (classic ETC heuristics), HEFT-style earliest-finish-time, and
an exact MDP value-iteration formulation for small instances (the paper
frames scheduling as an (PO-)MDP).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

import numpy as np

from repro.hw import DeviceSpec


@dataclasses.dataclass(frozen=True)
class Task:
    """One brokered AI task (a profiling-grid workload or an arch config)."""
    name: str
    flops: float
    input_bytes: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Node:
    spec: DeviceSpec
    available_at: float = 0.0    # infrastructure monitoring: busy-until

    def exec_time(self, task: Task, efficiency: float = 0.35) -> float:
        comp = task.flops / (self.spec.peak_flops_f32 * efficiency)
        xfer = task.input_bytes / max(self.spec.link_bw, 1.0)
        return comp + xfer


@dataclasses.dataclass
class Assignment:
    task: Task
    node: str
    start: float
    finish: float


@dataclasses.dataclass
class Schedule:
    assignments: list[Assignment]

    @property
    def makespan(self) -> float:
        return max((a.finish for a in self.assignments), default=0.0)

    @property
    def mean_completion(self) -> float:
        return float(np.mean([a.finish for a in self.assignments])) \
            if self.assignments else 0.0

    def deadline_misses(self) -> int:
        return sum(1 for a in self.assignments
                   if a.task.deadline_s is not None
                   and a.finish > a.task.deadline_s)


def etc_matrix(tasks: Sequence[Task], nodes: Sequence[Node],
               predictor: Optional[Callable[[Task, Node], float]] = None
               ) -> np.ndarray:
    """Expected-time-to-compute matrix [T, N].

    ``predictor`` plugs in the trained profiling model (paper §II-D:
    "resource and time prediction using global profiling models"); default
    is the analytic roofline estimate.
    """
    fn = predictor or (lambda t, n: n.exec_time(t))
    return np.array([[fn(t, n) for n in nodes] for t in tasks])


def _fresh(nodes: Sequence[Node]) -> list[Node]:
    return [dataclasses.replace(n) for n in nodes]


def _assign(task, node, etc_tn) -> Assignment:
    start = node.available_at
    finish = start + etc_tn
    node.available_at = finish
    return Assignment(task, node.spec.name, start, finish)


def round_robin(tasks, nodes, etc) -> Schedule:
    nodes = _fresh(nodes)
    out = [_assign(t, nodes[i % len(nodes)], etc[i, i % len(nodes)])
           for i, t in enumerate(tasks)]
    return Schedule(out)


def random_schedule(tasks, nodes, etc, seed: int = 0) -> Schedule:
    rng = np.random.default_rng(seed)
    nodes = _fresh(nodes)
    out = []
    for i, t in enumerate(tasks):
        j = int(rng.integers(len(nodes)))
        out.append(_assign(t, nodes[j], etc[i, j]))
    return Schedule(out)


def min_min(tasks, nodes, etc) -> Schedule:
    """Classic min-min: repeatedly place the task with the smallest
    earliest-completion-time."""
    nodes = _fresh(nodes)
    remaining = list(range(len(tasks)))
    out = []
    while remaining:
        best = None
        for i in remaining:
            for j, n in enumerate(nodes):
                fin = n.available_at + etc[i, j]
                if best is None or fin < best[0]:
                    best = (fin, i, j)
        _, i, j = best
        out.append(_assign(tasks[i], nodes[j], etc[i, j]))
        remaining.remove(i)
    return Schedule(out)


def max_min(tasks, nodes, etc) -> Schedule:
    """max-min: place the *largest* task first (better balance for skew)."""
    nodes = _fresh(nodes)
    remaining = list(range(len(tasks)))
    out = []
    while remaining:
        picks = {}
        for i in remaining:
            fins = [(n.available_at + etc[i, j], j)
                    for j, n in enumerate(nodes)]
            picks[i] = min(fins)
        i = max(picks, key=lambda i_: picks[i_][0])
        fin, j = picks[i]
        out.append(_assign(tasks[i], nodes[j], etc[i, j]))
        remaining.remove(i)
    return Schedule(out)


def heft(tasks, nodes, etc) -> Schedule:
    """HEFT-lite for independent tasks: rank by mean ETC descending, place
    each on the earliest-finish node."""
    nodes = _fresh(nodes)
    order = np.argsort(-etc.mean(axis=1))
    out = []
    for i in order:
        j = int(np.argmin([n.available_at + etc[i, j]
                           for j, n in enumerate(nodes)]))
        out.append(_assign(tasks[i], nodes[j], etc[i, j]))
    return Schedule(out)


def optimal_bruteforce(tasks, nodes, etc) -> Schedule:
    """Exact minimum-makespan assignment (tiny instances only)."""
    best = None
    for combo in itertools.product(range(len(nodes)), repeat=len(tasks)):
        loads = np.zeros(len(nodes))
        for i, j in enumerate(combo):
            loads[j] += etc[i, j]
        mk = loads.max()
        if best is None or mk < best[0]:
            best = (mk, combo)
    _, combo = best
    nodes = _fresh(nodes)
    return Schedule([_assign(tasks[i], nodes[j], etc[i, j])
                     for i, j in enumerate(combo)])


SCHEDULERS: dict[str, Callable] = {
    "round_robin": round_robin,
    "random": random_schedule,
    "min_min": min_min,
    "max_min": max_min,
    "heft": heft,
}


# --------------------------------------------------------------------------
# MDP formulation (paper: "modelled as an MDP or PO-MDP")
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SchedulingMDP:
    """Exact finite-horizon MDP for sequential task arrival.

    State: (next task index, discretised node-backlog vector).
    Action: node for the current task.  Cost: increase in makespan.
    Solved by backward value iteration — the optimal policy lower-bounds
    the heuristics on small instances (tested).
    """
    tasks: Sequence[Task]
    nodes: Sequence[Node]
    etc: np.ndarray
    backlog_levels: int = 8

    def solve(self) -> float:
        levels = self.backlog_levels
        etc = self.etc
        t_max = etc.sum()
        step = t_max / (levels - 1) if levels > 1 else t_max

        def discretise(b: float) -> int:
            return min(int(round(b / step)), levels - 1)

        n_nodes = len(self.nodes)
        from functools import lru_cache

        @lru_cache(maxsize=None)
        def value(i: int, backlog: tuple) -> float:
            if i == len(self.tasks):
                return max(backlog) * step
            best = np.inf
            for j in range(n_nodes):
                b = list(backlog)
                b[j] = discretise(b[j] * step + etc[i, j])
                best = min(best, value(i + 1, tuple(b)))
            return best

        return float(value(0, tuple([0] * n_nodes)))
