"""Batched, array-native offloading decisions (the vectorized decision core).

:mod:`repro.core.offload` answers "where do I split for *this* device,
edge, and link?".  This module answers the fleet-scale question: given
*vectors* of link bandwidths, device specs, and edge specs — thousands of
concurrent users, each in a different radio condition — compute the full
``[n_envs, L+1]`` latency matrix in one shot of numpy broadcasting and
argmin every row.  One call replaces ``n_envs × (L+1)`` scalar
``split_time`` evaluations, which is what makes scenario sweeps (link
grids × device mixes × models) and high-rate decision serving tractable.

Usage::

    from repro.core import decisions as dec
    from repro.core import offload as off
    from repro.hw import get_device

    layers = off.workload_layer_costs(wc)
    envs = dec.make_envs(get_device("pi5-arm"),
                         get_device("edge-server-a100"),
                         link_bw=np.geomspace(1e5, 1e10, 4096),
                         input_bytes=4 * 32 * 784)
    lat = dec.latency_matrix(layers, envs)      # [4096, L+1]
    plan = dec.decide_all(layers, envs)         # argmin per env
    plan.splits, plan.total_time_s              # [4096] each
    plan[0]                                     # -> offload.SplitDecision

Scalar oracles for every path here live in ``repro.core.offload``
(``split_time`` / ``optimal_split_ref``); the equivalence tests in
``tests/test_decisions.py`` pin this module to them.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import numpy as np

from repro.core.offload import (DEFAULT_EFFICIENCY as EFFICIENCY, LayerCost,
                                OffloadEnv, SplitDecision)
from repro.hw import DeviceSpec


@dataclasses.dataclass(frozen=True)
class EnvArrays:
    """Struct-of-arrays form of ``n_envs`` :class:`OffloadEnv` instances."""
    dev_flops: np.ndarray            # [E] effective f32 peak of the device
    edge_flops: np.ndarray           # [E] effective f32 peak of the edge
    link_bw: np.ndarray              # [E] bytes/s
    link_latency_s: np.ndarray       # [E]
    input_bytes: np.ndarray          # [E]

    def __len__(self) -> int:
        return self.dev_flops.shape[0]


def _spec_flops(spec) -> Union[float, np.ndarray]:
    if isinstance(spec, DeviceSpec):
        return spec.peak_flops_f32
    return np.asarray([s.peak_flops_f32 for s in spec], np.float64)


def make_envs(device, edge, link_bw,
              link_latency_s=0.005, input_bytes=0.0) -> EnvArrays:
    """Broadcast scalars/vectors of specs and link states into an
    :class:`EnvArrays`.  ``device``/``edge`` may be a single
    :class:`DeviceSpec` or a sequence of them."""
    arrs = np.broadcast_arrays(
        np.atleast_1d(np.asarray(_spec_flops(device), np.float64)),
        np.atleast_1d(np.asarray(_spec_flops(edge), np.float64)),
        np.atleast_1d(np.asarray(link_bw, np.float64)),
        np.atleast_1d(np.asarray(link_latency_s, np.float64)),
        np.atleast_1d(np.asarray(input_bytes, np.float64)))
    return EnvArrays(*arrs)


def stack_envs(envs: Sequence[OffloadEnv]) -> EnvArrays:
    """Struct-of-arrays from a list of scalar :class:`OffloadEnv`."""
    return EnvArrays(
        np.asarray([e.device.peak_flops_f32 for e in envs], np.float64),
        np.asarray([e.edge.peak_flops_f32 for e in envs], np.float64),
        np.asarray([e.link_bw for e in envs], np.float64),
        np.asarray([e.link_latency_s for e in envs], np.float64),
        np.asarray([e.input_bytes for e in envs], np.float64))


def latency_components(layers: Sequence[LayerCost], envs: EnvArrays,
                       efficiency: float = EFFICIENCY
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``(device, transfer, edge)`` latency matrices, each ``[E, L+1]``.

    Column ``s`` of each matrix is the corresponding component of running
    layers ``[0, s)`` on-device and the rest on-edge — the batched twin of
    ``offload.split_components``.
    """
    n = len(envs)
    flops = np.fromiter((lc.flops for lc in layers), np.float64,
                        count=len(layers))
    act = np.fromiter((lc.act_bytes for lc in layers), np.float64,
                      count=len(layers))
    t_dev = flops[None, :] / (envs.dev_flops[:, None] * efficiency)
    t_edge = flops[None, :] / (envs.edge_flops[:, None] * efficiency)
    zero = np.zeros((n, 1))
    dev_cum = np.concatenate([zero, np.cumsum(t_dev, axis=1)], axis=1)
    edge_cum = np.concatenate(
        [np.cumsum(t_edge[:, ::-1], axis=1)[:, ::-1], zero], axis=1)
    xfer_bytes = np.concatenate(
        [envs.input_bytes[:, None],
         np.broadcast_to(act[None, :], (n, len(layers)))], axis=1)
    xfer = envs.link_latency_s[:, None] \
        + xfer_bytes / np.maximum(envs.link_bw, 1.0)[:, None]
    xfer[:, -1] = 0.0                # split == L ships nothing
    return dev_cum, xfer, edge_cum


def latency_matrix(layers: Sequence[LayerCost], envs: EnvArrays,
                   efficiency: float = EFFICIENCY) -> np.ndarray:
    """Total latency of every (environment, split) pair: ``[E, L+1]``."""
    dev_cum, xfer, edge_cum = latency_components(layers, envs, efficiency)
    return dev_cum + xfer + edge_cum


@dataclasses.dataclass(frozen=True)
class BatchDecisions:
    """Per-environment optimal decisions, struct-of-arrays (all ``[E]``)."""
    splits: np.ndarray
    total_time_s: np.ndarray
    device_time_s: np.ndarray
    transfer_time_s: np.ndarray
    edge_time_s: np.ndarray

    def __len__(self) -> int:
        return self.splits.shape[0]

    def __getitem__(self, i: int) -> SplitDecision:
        return SplitDecision(int(self.splits[i]),
                             float(self.total_time_s[i]),
                             float(self.device_time_s[i]),
                             float(self.transfer_time_s[i]),
                             float(self.edge_time_s[i]))


def decide_all(layers: Sequence[LayerCost], envs: EnvArrays,
               efficiency: float = EFFICIENCY) -> BatchDecisions:
    """Optimal split per environment: one argmin over the latency matrix."""
    dev_cum, xfer, edge_cum = latency_components(layers, envs, efficiency)
    total = dev_cum + xfer + edge_cum
    s = np.argmin(total, axis=1)
    rows = np.arange(len(envs))
    return BatchDecisions(s, total[rows, s], dev_cum[rows, s],
                          xfer[rows, s], edge_cum[rows, s])


def sweep_links(layers: Sequence[LayerCost], env_base: OffloadEnv,
                link_bws) -> BatchDecisions:
    """Optimal decisions for one device/edge pair across a bandwidth grid —
    the common "radio conditions sweep" shorthand."""
    envs = make_envs(env_base.device, env_base.edge,
                     link_bw=np.asarray(link_bws, np.float64),
                     link_latency_s=env_base.link_latency_s,
                     input_bytes=env_base.input_bytes)
    return decide_all(layers, envs)
