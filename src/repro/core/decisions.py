"""Batched, array-native offloading decisions (the vectorized decision core).

:mod:`repro.core.offload` answers "where do I split for *this* device,
edge, and link?".  This module answers the fleet-scale question: given
*vectors* of link bandwidths, device specs, and edge specs — thousands of
concurrent users, each in a different radio condition — compute the full
``[n_envs, L+1]`` latency matrix in one shot of numpy broadcasting and
argmin every row.  One call replaces ``n_envs × (L+1)`` scalar
``split_time`` evaluations, which is what makes scenario sweeps (link
grids × device mixes × models) and high-rate decision serving tractable.

*What* is being minimised is pluggable: every decision entry point takes a
``cost=`` :class:`repro.core.costs.CostModel` mapping ``(layers, envs)``
to a ``[n_envs, L+1, n_objectives]`` component tensor — analytic roofline
latency (the default), latency predicted by the trained profiling model
(``PredictorCost``), or multi-objective latency/energy/price/deadline
stacks (``CompositeCost``).  Without ``cost=`` the historical analytic
latency-only behaviour is preserved bit-for-bit.

*Where* the sweep runs is also pluggable: ``decide_all``/``sweep_links``
take ``backend="numpy" | "jax" | "pallas"``.  ``"numpy"`` (default) is
this module's host path; ``"jax"`` lowers the same pipeline to jitted XLA
(``repro.kernels.decide_split.ops``), bit-for-bit equal in f64, so
serving engines can re-plan on-accelerator next to the model; ``"pallas"``
is a fused TPU kernel for very large sweeps that never materialises the
``[n_envs, L+1]`` cost tensor in HBM (within f32 tolerance).  Cost
models lower via ``costs.lower_to_accel``: ``AnalyticCost`` and
``CompositeCost`` are pure array math over ``EnvArrays``;
``PredictorCost`` lowers by compiling its fitted regressor to array
form (``repro.oracle.lowered`` — ridge → dot, MLP → jitted matmul
chain, GBT → the ``tree_predict`` kernels), so predictor-driven sweeps
run on-accelerator too.  Only regressors outside those families raise
``TypeError`` on accelerator backends rather than silently copying back.

Usage::

    from repro.core import costs as co
    from repro.core import decisions as dec
    from repro.core import offload as off
    from repro.hw import get_device

    layers = off.workload_layer_costs(wc)
    envs = dec.make_envs(get_device("pi5-arm"),
                         get_device("edge-server-a100"),
                         link_bw=np.geomspace(1e5, 1e10, 4096),
                         input_bytes=4 * 32 * 784)
    plan = dec.decide_all(layers, envs)         # analytic, latency-only
    plan.splits, plan.total_time_s              # [4096] each
    plan[0]                                     # -> offload.SplitDecision

    dec.decide_all(layers, envs, backend="jax")     # jitted, bit-for-bit
    dec.decide_all(layers, envs, backend="pallas")  # fused TPU kernel

    cost = co.CompositeCost(weights={"latency_s": 1, "energy_j": 0.05})
    plan = dec.decide_all(layers, envs, cost=cost)
    plan.objective("energy_j")                  # [4096] joules at the split
    co.pareto_front(cost.components(layers, envs))   # [4096, L+1] mask

    gbt = MultiTargetGBT().fit(x, y)            # trained profiling model
    plan = dec.decide_all(layers, envs,
                          cost=co.PredictorCost(gbt, device, edge))

Scalar oracles for every path here live in ``repro.core.offload``
(``split_time`` / ``optimal_split_ref``); the equivalence tests in
``tests/test_decisions.py`` and ``tests/test_costs.py`` pin this module
and the cost models to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.offload import (DEFAULT_EFFICIENCY as EFFICIENCY, LayerCost,
                                OffloadEnv, SplitDecision)
from repro.hw import DeviceSpec


@dataclasses.dataclass(frozen=True)
class EnvArrays:
    """Struct-of-arrays form of ``n_envs`` :class:`OffloadEnv` instances."""
    dev_flops: np.ndarray            # [E] effective f32 peak of the device
    edge_flops: np.ndarray           # [E] effective f32 peak of the edge
    link_bw: np.ndarray              # [E] bytes/s
    link_latency_s: np.ndarray       # [E]
    input_bytes: np.ndarray          # [E]
    # board power, for the energy objective (None when built by hand from
    # raw arrays; make_envs/stack_envs always fill them from the specs)
    dev_tdp_watts: Optional[np.ndarray] = None      # [E]
    edge_tdp_watts: Optional[np.ndarray] = None     # [E]

    def __len__(self) -> int:
        return self.dev_flops.shape[0]


def _spec_attr(spec, attr: str) -> Union[float, np.ndarray]:
    if isinstance(spec, DeviceSpec):
        return getattr(spec, attr)
    return np.asarray([getattr(s, attr) for s in spec], np.float64)


def make_envs(device, edge, link_bw,
              link_latency_s=0.005, input_bytes=0.0) -> EnvArrays:
    """Broadcast scalars/vectors of specs and link states into an
    :class:`EnvArrays`.  ``device``/``edge`` may be a single
    :class:`DeviceSpec` or a sequence of them."""
    arrs = np.broadcast_arrays(
        np.atleast_1d(np.asarray(_spec_attr(device, "peak_flops_f32"),
                                 np.float64)),
        np.atleast_1d(np.asarray(_spec_attr(edge, "peak_flops_f32"),
                                 np.float64)),
        np.atleast_1d(np.asarray(link_bw, np.float64)),
        np.atleast_1d(np.asarray(link_latency_s, np.float64)),
        np.atleast_1d(np.asarray(input_bytes, np.float64)),
        np.atleast_1d(np.asarray(_spec_attr(device, "tdp_watts"),
                                 np.float64)),
        np.atleast_1d(np.asarray(_spec_attr(edge, "tdp_watts"),
                                 np.float64)))
    return EnvArrays(*arrs)


def stack_envs(envs: Sequence[OffloadEnv]) -> EnvArrays:
    """Struct-of-arrays from a list of scalar :class:`OffloadEnv`."""
    return EnvArrays(
        np.asarray([e.device.peak_flops_f32 for e in envs], np.float64),
        np.asarray([e.edge.peak_flops_f32 for e in envs], np.float64),
        np.asarray([e.link_bw for e in envs], np.float64),
        np.asarray([e.link_latency_s for e in envs], np.float64),
        np.asarray([e.input_bytes for e in envs], np.float64),
        np.asarray([e.device.tdp_watts for e in envs], np.float64),
        np.asarray([e.edge.tdp_watts for e in envs], np.float64))


def transfer_bytes(layers: Sequence[LayerCost], envs: EnvArrays
                   ) -> np.ndarray:
    """Bytes crossing the link per split, ``[E, L+1]`` (0 at split == L):
    the raw input at split 0, the split layer's activation otherwise."""
    n = len(envs)
    act = np.fromiter((lc.act_bytes for lc in layers), np.float64,
                      count=len(layers))
    out = np.concatenate(
        [envs.input_bytes[:, None],
         np.broadcast_to(act[None, :], (n, len(layers)))], axis=1)
    out[:, -1] = 0.0                 # split == L ships nothing
    return out


def transfer_matrix(layers: Sequence[LayerCost], envs: EnvArrays
                    ) -> np.ndarray:
    """Transfer latency per split, ``[E, L+1]``: link latency plus shipped
    bytes over bandwidth (0 at split == L)."""
    xfer = envs.link_latency_s[:, None] + transfer_bytes(layers, envs) \
        / np.maximum(envs.link_bw, 1.0)[:, None]
    xfer[:, -1] = 0.0                # split == L ships nothing
    return xfer


def latency_components(layers: Sequence[LayerCost], envs: EnvArrays,
                       efficiency: float = EFFICIENCY
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``(device, transfer, edge)`` latency matrices, each ``[E, L+1]``.

    Column ``s`` of each matrix is the corresponding component of running
    layers ``[0, s)`` on-device and the rest on-edge — the batched twin of
    ``offload.split_components``.
    """
    n = len(envs)
    flops = np.fromiter((lc.flops for lc in layers), np.float64,
                        count=len(layers))
    t_dev = flops[None, :] / (envs.dev_flops[:, None] * efficiency)
    t_edge = flops[None, :] / (envs.edge_flops[:, None] * efficiency)
    zero = np.zeros((n, 1))
    dev_cum = np.concatenate([zero, np.cumsum(t_dev, axis=1)], axis=1)
    edge_cum = np.concatenate(
        [np.cumsum(t_edge[:, ::-1], axis=1)[:, ::-1], zero], axis=1)
    return dev_cum, transfer_matrix(layers, envs), edge_cum


def latency_matrix(layers: Sequence[LayerCost], envs: EnvArrays,
                   efficiency: float = EFFICIENCY) -> np.ndarray:
    """Total latency of every (environment, split) pair: ``[E, L+1]``."""
    dev_cum, xfer, edge_cum = latency_components(layers, envs, efficiency)
    return dev_cum + xfer + edge_cum


@dataclasses.dataclass(frozen=True)
class DecisionPlan:
    """Per-environment optimal decisions, struct-of-arrays (all ``[E]``).

    With a multi-objective cost model, ``objectives``/``components`` carry
    the named per-objective cost at each chosen split and ``scalar_cost``
    the scalarised value the argmin ranked by; latency-only plans leave
    them at their defaults.
    """
    splits: np.ndarray
    total_time_s: np.ndarray
    device_time_s: np.ndarray
    transfer_time_s: np.ndarray
    edge_time_s: np.ndarray
    objectives: tuple[str, ...] = ("latency_s",)
    components: Optional[np.ndarray] = None       # [E, n_objectives]
    scalar_cost: Optional[np.ndarray] = None      # [E]

    def __len__(self) -> int:
        return self.splits.shape[0]

    def __getitem__(self, i: int) -> SplitDecision:
        return SplitDecision(int(self.splits[i]),
                             float(self.total_time_s[i]),
                             float(self.device_time_s[i]),
                             float(self.transfer_time_s[i]),
                             float(self.edge_time_s[i]))

    def objective(self, name: str) -> np.ndarray:
        """``[E]`` cost of the named objective at each chosen split."""
        if self.components is None:
            if name == "latency_s":
                return self.total_time_s
            raise KeyError(f"plan carries no components for {name!r}")
        return self.components[:, self.objectives.index(name)]


# the pre-CostModel name, kept for existing callers
BatchDecisions = DecisionPlan


def decide_all(layers: Sequence[LayerCost], envs: EnvArrays,
               efficiency: float = EFFICIENCY, *,
               cost=None, backend: str = "numpy") -> DecisionPlan:
    """Optimal split per environment: one argmin over the cost matrix.

    ``cost`` is a :class:`repro.core.costs.CostModel`; ``None`` keeps the
    historical analytic latency-only path (identical to
    ``cost=AnalyticCost(efficiency)`` but without building components).
    The argmin ranks splits by ``cost.scalarize(components)``.
    ``efficiency`` only applies to the analytic default — with ``cost=``
    the model owns its parameters, so combining the two is rejected
    rather than silently ignoring one.

    ``backend`` selects where the sweep runs: ``"numpy"`` on the host
    (default), ``"jax"`` as jitted XLA (bit-for-bit with numpy in f64),
    ``"pallas"`` as the fused TPU kernel for very large sweeps (within
    f32 tolerance) — see :mod:`repro.kernels.decide_split`.
    ``None``/``AnalyticCost``/``CompositeCost`` lower as pure array
    math; ``PredictorCost`` lowers through its compiled regressor
    (``repro.oracle.lowered``) and only raises when the wrapped model
    has no array form.
    """
    if cost is not None and efficiency != EFFICIENCY:
        raise ValueError(
            "efficiency= is ignored when cost= is given; set it on the "
            "cost model instead (e.g. AnalyticCost(efficiency=...))")
    if backend != "numpy":
        from repro.kernels.decide_split import ops
        return ops.decide_accel(layers, envs, efficiency, cost=cost,
                                backend=backend)
    if cost is None:
        dev_cum, xfer, edge_cum = latency_components(layers, envs,
                                                     efficiency)
        total = dev_cum + xfer + edge_cum
        s = np.argmin(total, axis=1)
        rows = np.arange(len(envs))
        return DecisionPlan(s, total[rows, s], dev_cum[rows, s],
                            xfer[rows, s], edge_cum[rows, s])
    comp = np.asarray(cost.components(layers, envs), np.float64)
    scalar = cost.scalarize(comp)
    s = np.argmin(scalar, axis=1)
    rows = np.arange(comp.shape[0])
    objectives = tuple(cost.objectives)
    comp_s = comp[rows, s]
    if "latency_s" in objectives:
        total = comp_s[:, objectives.index("latency_s")]
    else:
        # no latency objective -> the scalarised weighted cost is in
        # arbitrary units, not seconds; total_time_s must not lie
        # (scalar_cost below still carries the value the argmin ranked by)
        total = np.full(len(rows), np.nan)
    parts_fn = getattr(cost, "latency_parts", None)
    if parts_fn is not None:
        dev_cum, xfer, edge_cum = parts_fn(layers, envs)
        dev_t, xfer_t, edge_t = (dev_cum[rows, s], xfer[rows, s],
                                 edge_cum[rows, s])
    else:                            # no latency decomposition available
        dev_t = xfer_t = edge_t = np.full(len(rows), np.nan)
    return DecisionPlan(s, total, dev_t, xfer_t, edge_t,
                        objectives=objectives, components=comp_s,
                        scalar_cost=scalar[rows, s])


def pad_envs(envs: EnvArrays, multiple: int) -> tuple[EnvArrays, int]:
    """Pad the environment axis up to a multiple of ``multiple`` by
    repeating the last row — the shard-friendly layout for splitting the
    env axis across devices (padded rows compute real but discarded
    decisions, so the maths stays row-wise identical).  Returns
    ``(padded, original_length)``; the caller trims results back with
    ``[:original_length]``."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    e = len(envs)
    pad = (-e) % multiple
    if pad == 0:
        return envs, e
    if e == 0:
        raise ValueError("cannot pad an empty EnvArrays (no row to "
                         "repeat)")
    idx = np.concatenate([np.arange(e), np.full(pad, e - 1, np.intp)])
    return take_envs(envs, idx), e


def take_envs(envs: EnvArrays, idx) -> EnvArrays:
    """Row-subset of an :class:`EnvArrays` (``idx`` is an integer index
    array or boolean mask over the environment axis)."""
    idx = np.asarray(idx)

    def take(a):
        return None if a is None else a[idx]

    return EnvArrays(envs.dev_flops[idx], envs.edge_flops[idx],
                     envs.link_bw[idx], envs.link_latency_s[idx],
                     envs.input_bytes[idx], take(envs.dev_tdp_watts),
                     take(envs.edge_tdp_watts))


def replan(layers: Sequence[LayerCost], envs: EnvArrays,
           prev: DecisionPlan, changed, *,
           efficiency: float = EFFICIENCY, cost=None,
           backend: str = "numpy") -> DecisionPlan:
    """Incremental :func:`decide_all`: re-decide only the ``changed``
    environments and splice the fresh rows into ``prev``.

    ``changed`` is an integer index array or boolean mask over the
    environment axis — in a streaming run, the environments whose link
    state or backlog actually drifted since ``prev`` was computed
    (:mod:`repro.sim.state` tracks them).  Rows outside ``changed`` are
    carried over untouched, so the result is bit-for-bit what a full
    ``decide_all`` over the updated ``envs`` would return, at the cost
    of the changed rows only.
    """
    idx = np.asarray(changed)
    if idx.dtype == bool:
        if idx.shape != (len(envs),):
            raise ValueError(
                f"boolean changed mask must be [{len(envs)}], "
                f"got {idx.shape}")
        idx = np.flatnonzero(idx)
    if len(prev) != len(envs):
        raise ValueError(
            f"prev plan covers {len(prev)} envs, got {len(envs)}")
    if idx.size == 0:
        return prev
    sub = decide_all(layers, take_envs(envs, idx), efficiency,
                     cost=cost, backend=backend)
    if sub.objectives != prev.objectives:
        raise ValueError(
            f"cost model changed between plans: prev objectives "
            f"{prev.objectives}, new {sub.objectives} — replan only "
            "splices rows of the same objective stack")

    def scatter(old, new):
        if old is None or new is None:
            if (old is None) != (new is None):
                raise ValueError(
                    "prev and updated plans disagree on carrying "
                    "components/scalar_cost — same cost= required")
            return None
        out = np.asarray(old).copy()
        out[idx] = new
        return out

    return DecisionPlan(scatter(prev.splits, sub.splits),
                        scatter(prev.total_time_s, sub.total_time_s),
                        scatter(prev.device_time_s, sub.device_time_s),
                        scatter(prev.transfer_time_s, sub.transfer_time_s),
                        scatter(prev.edge_time_s, sub.edge_time_s),
                        objectives=prev.objectives,
                        components=scatter(prev.components, sub.components),
                        scalar_cost=scatter(prev.scalar_cost,
                                            sub.scalar_cost))


def sweep_links(layers: Sequence[LayerCost], env_base: OffloadEnv,
                link_bws, efficiency: float = EFFICIENCY, *,
                cost=None, backend: str = "numpy") -> DecisionPlan:
    """Optimal decisions for one device/edge pair across a bandwidth grid —
    the common "radio conditions sweep" shorthand.  ``efficiency``/
    ``cost``/``backend`` pass straight through to :func:`decide_all`
    (including its efficiency-vs-cost conflict guard)."""
    envs = make_envs(env_base.device, env_base.edge,
                     link_bw=np.asarray(link_bws, np.float64),
                     link_latency_s=env_base.link_latency_s,
                     input_bytes=env_base.input_bytes)
    return decide_all(layers, envs, efficiency, cost=cost, backend=backend)
