"""The profiler (paper §II-A): run an AI workload, capture its execution
profile — FLOPs, MACs, memory, wall time, accuracy — on concrete hardware.

Two probe backends:

  * **measured** — actually executes the workload on this host (the paper's
    own method: >3,000 timed runs on a Dell XPS).  Wall-clock is measured,
    FLOPs/MACs/bytes come from XLA ``cost_analysis`` of the jitted step.
  * **analytic** — for TPU-pod-scale workloads that cannot run here:
    lower+compile only (the multi-pod dry-run), with the roofline terms as
    the time estimate.  Same ``ProfileRecord`` schema, so predictors train
    on both.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workloads import (WorkloadConfig, init_workload_params,
                                  synthetic_image_data, workload_loss)
from repro.hw import DeviceSpec, get_device
from repro.optim import apply_updates, get_optimizer


@dataclasses.dataclass
class ProfileRecord:
    """One profiling run (one row of the paper's dataset)."""
    label: str
    kind: str
    # --- profile targets (paper Fig. 3: FLOPS, MACs, total time) ---
    flops_per_step: float
    macs_per_step: float
    total_time_s: float
    # --- extended targets ---
    step_time_s: float
    peak_bytes: float
    param_count: int
    final_loss: float
    final_acc: float
    # --- inputs (features) ---
    config: dict
    hardware: dict

    def targets(self, extended: bool = False) -> dict:
        """Prediction targets.  The default three are the paper's Fig. 3
        stack;
        ``extended=True`` additionally surfaces the resource-utilisation
        targets (per-step time and peak memory) so predictors can learn
        *resource use*, not just completion time (paper abstract:
        "execution time and resource utilization")."""
        out = {
            "flops": self.flops_per_step,
            "macs": self.macs_per_step,
            "total_time": self.total_time_s,
        }
        if extended:
            out["step_time"] = self.step_time_s
            out["peak_bytes"] = self.peak_bytes
        return out


def _cost_of(jitted, *args) -> dict:
    from repro.roofline import normalize_cost_analysis
    compiled = jitted.lower(*args).compile()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    mem = compiled.memory_analysis()
    peak = (getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "peak_bytes": float(peak)}


def profile_workload(wc: WorkloadConfig, *, device: Optional[DeviceSpec] = None,
                     measure: bool = True, max_steps: int = 0,
                     seed: int = 0) -> ProfileRecord:
    """Train the Table-I workload and record its profile.

    ``max_steps`` > 0 truncates the run and extrapolates total time from the
    measured per-step time (the profiling-dataset generator uses this to
    keep >100-run grids tractable; the benchmark validates the
    extrapolation error on full runs).
    """
    device = device or get_device("xps15-i5")
    key = jax.random.key(seed)
    params = init_workload_params(wc, key)
    opt = get_optimizer(wc.optimiser, wc.lr)
    opt_state = opt.init(params)
    x, y = synthetic_image_data(wc.dataset_size, seed=seed)

    def train_step(params, opt_state, batch):
        (loss, acc), grads = jax.value_and_grad(
            workload_loss, has_aux=True)(params, batch, wc)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss, acc

    jitted = jax.jit(train_step)
    bs = wc.batch_size
    batch0 = {"x": jnp.asarray(x[:bs]), "y": jnp.asarray(y[:bs])}
    cost = _cost_of(jitted, params, opt_state, batch0)

    steps_per_epoch = max(wc.dataset_size // bs, 1)
    planned = wc.epochs * steps_per_epoch
    loss_v = acc_v = float("nan")
    if measure:
        # warmup (compile) excluded from timing
        params, opt_state, *_ = jitted(params, opt_state, batch0)
        jax.block_until_ready(params)
        run_steps = min(planned, max_steps) if max_steps else planned
        t0 = time.perf_counter()
        step = 0
        done = False
        for _ in range(wc.epochs):
            for i in range(steps_per_epoch):
                lo = (i * bs) % wc.dataset_size
                batch = {"x": jnp.asarray(x[lo:lo + bs]),
                         "y": jnp.asarray(y[lo:lo + bs])}
                params, opt_state, loss_v, acc_v = jitted(
                    params, opt_state, batch)
                step += 1
                if step >= run_steps:
                    done = True
                    break
            if done:
                break
        jax.block_until_ready(params)
        elapsed = time.perf_counter() - t0
        step_time = elapsed / max(step, 1)
        total_time = step_time * planned
        loss_v, acc_v = float(loss_v), float(acc_v)
    else:
        # analytic estimate from the roofline of this device
        step_time = max(cost["flops"] / device.peak_flops_f32,
                        cost["bytes"] / device.hbm_bw)
        total_time = step_time * planned

    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    return ProfileRecord(
        label=wc.label(),
        kind=wc.kind,
        flops_per_step=cost["flops"],
        macs_per_step=cost["flops"] / 2.0,
        total_time_s=total_time,
        step_time_s=step_time,
        peak_bytes=cost["peak_bytes"],
        param_count=n_params,
        final_loss=loss_v,
        final_acc=acc_v,
        config=dataclasses.asdict(wc),
        hardware=device.as_features(),
    )
