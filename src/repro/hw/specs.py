"""Hardware specification tables.

The paper's thesis is that edge AI systems are *heterogeneous* — devices
differ in ISA, clock, accelerator and link speed, and any offloading decision
must be grounded in per-device capability numbers. This module is the single
source of truth for those numbers, used by:

  * ``repro.roofline``        — TPU v5e roofline constants for the dry-run.
  * ``repro.core.offload``    — edge-device specs for the split-computing sim.
  * ``repro.core.features``   — hardware features fed to the profiling model.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Capability description of one compute device (chip or edge node)."""

    name: str
    kind: str                   # "tpu" | "gpu" | "cpu"
    isa: str                    # "tpu-v5e" | "x86" | "arm" ...
    peak_flops: float           # peak FLOP/s at the preferred dtype
    peak_flops_f32: float       # peak FLOP/s at f32
    hbm_bytes: float            # accelerator memory capacity (bytes)
    hbm_bw: float               # memory bandwidth, bytes/s
    link_bw: float              # per-link interconnect bandwidth, bytes/s
    clock_ghz: float            # nominal clock (paper uses this as a feature)
    vmem_bytes: float = 0.0     # on-chip scratch (VMEM / SMEM / L2)
    tdp_watts: float = 0.0

    def as_features(self) -> dict[str, float]:
        """Hardware features for the profiling predictor (paper §II-A)."""
        return {
            "hw_peak_flops": self.peak_flops,
            "hw_hbm_bw": self.hbm_bw,
            "hw_link_bw": self.link_bw,
            "hw_clock_ghz": self.clock_ghz,
            "hw_mem_bytes": self.hbm_bytes,
            "hw_is_accelerated": 1.0 if self.kind in ("tpu", "gpu") else 0.0,
            "hw_tdp_watts": self.tdp_watts,
        }


# --- TPU v5e: the production target of this framework -----------------------
# Constants mandated by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link.
TPU_V5E = DeviceSpec(
    name="tpu-v5e",
    kind="tpu",
    isa="tpu-v5e",
    peak_flops=197e12,
    peak_flops_f32=98.5e12,
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    link_bw=50e9,
    clock_ghz=1.7,
    vmem_bytes=128 * 2**20,
    tdp_watts=250,
)

# --- Edge devices for the paper-faithful offloading simulation ---------------
# The paper's own testbed: Dell XPS 15, Intel Core i5 + NVIDIA GTX 1650.
XPS15_I5 = DeviceSpec(
    name="xps15-i5",
    kind="cpu",
    isa="x86",
    peak_flops=230e9,           # ~8c AVX2 FMA at boost
    peak_flops_f32=230e9,
    hbm_bytes=16 * 2**30,
    hbm_bw=40e9,
    link_bw=0.125e9,            # 1 Gb/s NIC
    clock_ghz=3.5,
    tdp_watts=45,
)

GTX_1650 = DeviceSpec(
    name="gtx-1650",
    kind="gpu",
    isa="cuda-turing",
    peak_flops=5.9e12,          # fp16
    peak_flops_f32=2.95e12,
    hbm_bytes=4 * 2**30,
    hbm_bw=128e9,
    link_bw=0.125e9,
    clock_ghz=1.49,
    tdp_watts=75,
)

# Heterogeneous extreme-edge devices (paper §I: "1.5GHz vs 3.5GHz, X86 vs ARM")
PI5_ARM = DeviceSpec(
    name="pi5-arm",
    kind="cpu",
    isa="arm",
    peak_flops=30e9,
    peak_flops_f32=30e9,
    hbm_bytes=8 * 2**30,
    hbm_bw=17e9,
    link_bw=0.125e9,
    clock_ghz=2.4,
    tdp_watts=12,
)

JETSON_ORIN_NANO = DeviceSpec(
    name="jetson-orin-nano",
    kind="gpu",
    isa="cuda-ampere",
    peak_flops=20e12,           # sparse int8 marketing → ~10 TF fp16 dense
    peak_flops_f32=2.5e12,
    hbm_bytes=8 * 2**30,
    hbm_bw=68e9,
    link_bw=0.125e9,
    clock_ghz=0.625,
    tdp_watts=15,
)

EDGE_SERVER_A100 = DeviceSpec(
    name="edge-server-a100",
    kind="gpu",
    isa="cuda-ampere",
    peak_flops=312e12,
    peak_flops_f32=19.5e12,
    hbm_bytes=40 * 2**30,
    hbm_bw=1555e9,
    link_bw=1.25e9,             # 10 Gb/s uplink to the edge site
    clock_ghz=1.41,
    tdp_watts=400,
)

EDGE_DEVICES: dict[str, DeviceSpec] = {
    d.name: d
    for d in (XPS15_I5, GTX_1650, PI5_ARM, JETSON_ORIN_NANO, EDGE_SERVER_A100)
}

ALL_DEVICES: dict[str, DeviceSpec] = {**EDGE_DEVICES, TPU_V5E.name: TPU_V5E}


def get_device(name: str) -> DeviceSpec:
    try:
        return ALL_DEVICES[name]
    except KeyError as e:
        raise KeyError(
            f"unknown device {name!r}; known: {sorted(ALL_DEVICES)}"
        ) from e
