from repro.hw.specs import (
    ALL_DEVICES,
    EDGE_DEVICES,
    TPU_V5E,
    DeviceSpec,
    get_device,
)

__all__ = [
    "ALL_DEVICES",
    "EDGE_DEVICES",
    "TPU_V5E",
    "DeviceSpec",
    "get_device",
]
