"""Synthetic data pipeline.

Provides deterministic, seedable batches for every architecture family:
token streams for LMs, embedding sequences for the VLM backbone, frame
embeddings for the audio encoder, and tabular regression sets for the
profiling predictors.  The LM stream is a learnable k-th order Markov
source so tiny training runs show real loss decrease.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def train_batch(cfg, batch_size: int, seq_len: int, seed: int = 0) -> dict:
    """One training batch matching the family's ``train_loss`` signature."""
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        frames = rng.normal(size=(batch_size, cfg.enc_seq, cfg.d_model),
                            scale=0.5).astype(np.float32)
        tokens = _markov_tokens(rng, batch_size, seq_len + 1, cfg.vocab_size)
        return {"frames": jnp.asarray(frames), "tokens": jnp.asarray(tokens)}
    if cfg.family == "vlm":
        embeds = rng.normal(size=(batch_size, seq_len, cfg.d_model),
                            scale=0.5).astype(np.float32)
        labels = _markov_tokens(rng, batch_size, seq_len, cfg.vocab_size)
        return {"embeds": jnp.asarray(embeds), "labels": jnp.asarray(labels)}
    tokens = _markov_tokens(rng, batch_size, seq_len + 1, cfg.vocab_size)
    return {"tokens": jnp.asarray(tokens)}


def prefill_batch(cfg, batch_size: int, seq_len: int, seed: int = 0) -> dict:
    b = train_batch(cfg, batch_size, max(seq_len - 1, 1), seed)
    if cfg.family == "vlm":
        return {"embeds": b["embeds"]}
    if cfg.family == "audio":
        return {"frames": b["frames"], "tokens": b["tokens"][:, :seq_len]}
    return {"tokens": b["tokens"][:, :seq_len]}


def decode_batch(cfg, batch_size: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    tok = rng.integers(0, cfg.vocab_size, size=(batch_size, 1))
    return {"token": jnp.asarray(tok, jnp.int32)}


def _markov_tokens(rng, batch: int, seq: int, vocab: int) -> np.ndarray:
    """Order-1 Markov chain over a small state set mapped into the vocab —
    learnable structure for loss-decrease tests."""
    states = min(vocab, 16)
    trans = rng.dirichlet(np.ones(states) * 0.3, size=states)
    out = np.zeros((batch, seq), np.int64)
    s = rng.integers(0, states, size=batch)
    for t in range(seq):
        out[:, t] = s
        u = rng.random(batch)
        cum = np.cumsum(trans[s], axis=1)
        s = (u[:, None] < cum).argmax(axis=1)
    # map states onto spread-out vocab ids to exercise the full embed table
    ids = np.linspace(0, vocab - 1, states, dtype=np.int64)
    return ids[out].astype(np.int32)


# --------------------------------------------------------------------------
# Tabular regression data (profiling-predictor substrate)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TabularDataset:
    x: np.ndarray                   # [N, F] float32
    y: np.ndarray                   # [N, T] float32 (multi-target)
    feature_names: list[str]
    target_names: list[str]

    def split(self, frac: float = 0.8, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.x))
        k = int(len(idx) * frac)
        tr, te = idx[:k], idx[k:]
        mk = lambda i: TabularDataset(self.x[i], self.y[i],
                                      self.feature_names, self.target_names)
        return mk(tr), mk(te)

    def normalised(self):
        """Min-max normalise x and y (paper reports normalised RMSE)."""
        def norm(a):
            lo, hi = a.min(axis=0), a.max(axis=0)
            span = np.where(hi > lo, hi - lo, 1.0)
            return (a - lo) / span, (lo, span)
        xn, xs = norm(self.x)
        yn, ys = norm(self.y)
        return TabularDataset(xn.astype(np.float32), yn.astype(np.float32),
                              self.feature_names, self.target_names), (xs, ys)


def batches(x: np.ndarray, y: np.ndarray, batch_size: int,
            seed: int = 0) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    for i in range(0, len(idx) - batch_size + 1, batch_size):
        j = idx[i:i + batch_size]
        yield x[j], y[j]
