"""Mixture-of-Experts layer (DeepSeekMoE-style: shared + fine-grained routed).

Dispatch is *sort-based with static capacity* and built entirely from
gathers + batched matmuls (no large scatters), which keeps GSPMD lowering
clean when the expert buffer is sharded over the ``model`` mesh axis
(expert parallelism) while tokens are sharded over ``data``:

  1. route: softmax(router) → top-k experts/weights per token
  2. argsort token-choices by expert id → contiguous per-expert runs
  3. expert buffer [E, C, d] gathered from the sorted tokens (overflow beyond
     capacity C is dropped, matching Switch/GShard semantics)
  4. batched expert matmuls [E,C,d]×[E,d,ff]
  5. inverse-permutation gather back to [T, k, d] → weighted combine

The auxiliary load-balance loss (DeepSeek eq. 12-style) is returned for the
training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _ACTS, gated_mlp, matmul, mlp_param_shapes


def moe_param_shapes(cfg) -> dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    shapes = {
        "router": (d, e),
        "w_gate": (e, d, ff),
        "w_up": (e, d, ff),
        "w_down": (e, ff, d),
    }
    if cfg.num_shared_experts:
        shapes["shared"] = mlp_param_shapes(
            d, ff * cfg.num_shared_experts, cfg.mlp_act)
    return shapes


def capacity(num_tokens: int, cfg) -> int:
    """Static per-expert capacity."""
    c = int(num_tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def route(params, x_flat: jax.Array, cfg):
    """Router: returns (weights [T,k], expert_idx [T,k], aux_loss scalar)."""
    logits = jnp.matmul(x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # [T,E]
    weights, idx = jax.lax.top_k(probs, cfg.top_k)           # [T,k]
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss: E * sum_e f_e * P_e
    e = cfg.num_experts
    f = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (idx.size))                                    # dispatch frac
    p = probs.mean(axis=0)
    aux = e * jnp.sum(f * p) * cfg.router_aux_coef
    return weights, idx, aux


def _expert_compute(x_flat, idx, weights, w_gate, w_up, w_down, cfg,
                    e_base, n_local, cap):
    """Sort-based dispatch → batched matmuls → combine, over the ``n_local``
    experts starting at ``e_base``.  Pure local computation (per shard).

    §Perf C4: with ``cfg.moe_bf16_combine`` the [T, k, d] weighted combine
    stays in compute dtype (k ≤ 6 accumulands — bounded error) instead of
    materialising an f32 copy."""
    t, d = x_flat.shape
    k = cfg.top_k
    tk = t * k
    e_flat = idx.reshape(tk)
    order = jnp.argsort(e_flat)                              # [Tk]
    e_sorted = e_flat[order]
    tok_sorted = order // k                                  # source token
    # counts over the local expert range only
    e_local = e_sorted - e_base
    in_range = (e_local >= 0) & (e_local < n_local)
    e_clip = jnp.clip(e_local, 0, n_local - 1)
    counts = jnp.zeros((n_local,), jnp.int32).at[e_clip].add(
        in_range.astype(jnp.int32))
    first = jnp.argmax(in_range)                             # first local row
    starts = first + jnp.cumsum(counts) - counts             # exclusive
    pos_in_e = jnp.arange(tk) - starts[e_clip]               # rank in expert

    # ---- gather into the expert buffer [E_l, C, d] ---------------------
    buf_src = starts[:, None] + jnp.arange(cap)[None, :]     # [E_l,C]
    buf_valid = jnp.arange(cap)[None, :] < counts[:, None]
    buf_tok = jnp.where(buf_valid, tok_sorted[jnp.clip(buf_src, 0, tk - 1)],
                        0)
    buf = x_flat[buf_tok] * buf_valid[..., None].astype(x_flat.dtype)

    # ---- expert computation --------------------------------------------
    act = _ACTS[cfg.mlp_act]
    dt = x_flat.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
    h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(dt)
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))

    # ---- combine back (all gathers, no scatter) -------------------------
    inv_order = jnp.argsort(order)                           # rank of (t,k)
    kept = in_range & (pos_in_e < cap)
    dest = e_clip * cap + jnp.clip(pos_in_e, 0, cap - 1)
    y_flat = y_buf.reshape(n_local * cap, d)
    y_tk = (y_flat[dest[inv_order]]
            * kept[inv_order][:, None].astype(x_flat.dtype))
    if cfg.moe_bf16_combine:
        y = (y_tk.reshape(t, k, d)
             * weights[..., None].astype(x_flat.dtype)).sum(axis=1)
    else:
        y = (y_tk.reshape(t, k, d).astype(jnp.float32)
             * weights[..., None]).sum(axis=1)
    return y.astype(x_flat.dtype)


def moe_mlp(params, x: jax.Array, cfg):
    """x [B,S,d] (or [T,d]) → (y same shape, aux_loss).

    On the production mesh this runs under ``shard_map``: tokens stay in
    their data shard, each "model" shard computes only its own experts over
    the (model-replicated) local tokens, and partial outputs combine with a
    single psum — Megatron-row-parallel-style expert parallelism with no
    all-to-all and no global sort (DESIGN.md §5).
    """
    from repro.distributed.context import current_mesh, dp_axes, tp_axes
    orig_shape = x.shape
    d = orig_shape[-1]
    x_flat = x.reshape(-1, d)
    t = x_flat.shape[0]
    e = cfg.num_experts

    weights, idx, aux = route(params, x_flat, cfg)

    mesh = current_mesh()
    tp = tp_axes()
    dp = dp_axes()
    axis = dict(mesh.shape) if mesh else {}
    tp_size = axis.get("model", 1) if tp else 1
    dp_size = 1
    for a in dp:
        dp_size *= axis.get(a, 1)

    if (mesh is not None and tp_size > 1 and e % tp_size == 0):
        from jax.sharding import PartitionSpec as P
        try:                             # jax >= 0.5
            shard_map = jax.shard_map
        except AttributeError:           # jax 0.4.x
            from jax.experimental.shard_map import shard_map
        tok_dp = dp if (t % max(dp_size, 1) == 0 and dp_size > 1) else ()
        t_local = t // dp_size if tok_dp else t
        n_local = e // tp_size
        cap = capacity(t_local, cfg)
        tok_spec = P(tok_dp if tok_dp else None)
        w_spec = P("model", None, None)

        def local_fn(xl, il, wl, wg, wu, wd):
            e_base = jax.lax.axis_index("model") * n_local
            y = _expert_compute(xl, il, wl, wg, wu, wd, cfg, e_base,
                                n_local, cap)
            # psum runs in compute dtype (bf16) — _expert_compute already
            # returns x.dtype
            return jax.lax.psum(y, "model")

        import inspect
        check_kw = ("check_vma" if "check_vma"
                    in inspect.signature(shard_map).parameters
                    else "check_rep")    # pre-0.5 jax spelling
        y = shard_map(
            local_fn, mesh=mesh,
            in_specs=(tok_spec, tok_spec, tok_spec, w_spec, w_spec, w_spec),
            out_specs=tok_spec,
            **{check_kw: False},
        )(x_flat, idx, weights, params["w_gate"], params["w_up"],
          params["w_down"])
    else:
        cap = capacity(t, cfg)
        y = _expert_compute(x_flat, idx, weights, params["w_gate"],
                            params["w_up"], params["w_down"], cfg, 0, e, cap)

    out = y.astype(x.dtype)
    if cfg.num_shared_experts:
        out = out + gated_mlp(x_flat, params["shared"], cfg.mlp_act)
    return out.reshape(orig_shape), aux
