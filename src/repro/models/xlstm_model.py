"""xLSTM language model: groups of (slstm_every-1) mLSTM blocks + 1 sLSTM.

With ``slstm_every == 0`` the stack is pure mLSTM (single scan).  No FFN
(d_ff = 0 per the assignment) — the projection capacity lives inside the
blocks (proj factor 2), matching the xLSTM block design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import xlstm as xl
from repro.models.layers import init_tree, rms_norm
from repro.models.transformer import _lm_head, chunked_lm_loss, lm_loss


def _grouping(cfg) -> tuple[int, int]:
    if not cfg.slstm_every:
        return 1, cfg.num_layers
    assert cfg.num_layers % cfg.slstm_every == 0, \
        f"{cfg.name}: num_layers must divide by slstm_every"
    return cfg.num_layers // cfg.slstm_every, cfg.slstm_every - 1


def param_shapes(cfg) -> dict:
    g, m = _grouping(cfg)
    stack = lambda lead, s: jax.tree_util.tree_map(
        lambda t: (*lead, *t), s, is_leaf=lambda t: isinstance(t, tuple))
    shapes = {
        "embed": (cfg.vocab_size, cfg.d_model),
        "final_norm_scale": (cfg.d_model,),
        "mlstm": stack((g, m), xl.mlstm_block_shapes(cfg)),
    }
    if cfg.slstm_every:
        shapes["slstm"] = stack((g,), xl.slstm_block_shapes(cfg))
    return shapes


def init_params(cfg, key):
    return init_tree(key, param_shapes(cfg), jnp.dtype(cfg.dtype))


def _embed(params, tokens, cfg):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    return x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)


def forward(params, batch, cfg, *, remat=False, last_only=False,
            collect_cache=True):
    """Returns (hidden|logits, aux=0.0, states).

    ``collect_cache=False`` (training) emits no per-layer state ys — under
    remat they would all be saved for backward."""
    x = _embed(params, batch["tokens"], cfg)
    x = constrain(x, "activation")
    has_s = bool(cfg.slstm_every)

    def group(h, gp):
        def mlayer(hc, lp):
            hc2, state, conv = xl.mlstm_block(lp, hc, cfg)
            return (constrain(hc2, "activation"),
                    (state, conv) if collect_cache else None)

        mbody = jax.checkpoint(mlayer) if remat else mlayer
        h, mstates = jax.lax.scan(mbody, h, gp["mlstm"])
        sstates = None
        if has_s:
            sfn = (jax.checkpoint(xl.slstm_block, static_argnums=(2,))
                   if remat else xl.slstm_block)
            h, sstate, sconv = sfn(gp["slstm"], h, cfg)
            h = constrain(h, "activation")
            sstates = (sstate, sconv) if collect_cache else None
        if not collect_cache:
            return h, None
        return h, (mstates, sstates)

    body = jax.checkpoint(group) if remat else group
    gp_tree = {"mlstm": params["mlstm"]}
    if has_s:
        gp_tree["slstm"] = params["slstm"]
    x, states = jax.lax.scan(body, x, gp_tree)
    x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    if last_only:
        return _lm_head(params, x[:, -1:], cfg), 0.0, states
    return x, 0.0, states


def train_loss(params, batch, cfg, **_):
    tokens = batch["tokens"]
    x, aux, _ = forward(params, {"tokens": tokens[:, :-1]}, cfg, remat=True,
                        collect_cache=False)
    if cfg.loss_chunk:
        head_w = (params["embed"].T if cfg.tie_embeddings
                  and "lm_head" not in params else params["lm_head"])
        loss = chunked_lm_loss(x, head_w, tokens[:, 1:], cfg)
    else:
        loss = lm_loss(_lm_head(params, x, cfg), tokens[:, 1:],
                       batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# Cache / decode — xLSTM state is O(1) in sequence length.
# --------------------------------------------------------------------------
def cache_shapes(cfg, batch_size: int, max_len: int) -> dict:
    del max_len                      # recurrent: no KV growth
    g, m = _grouping(cfg)
    d, h = cfg.d_model, cfg.num_heads
    di = int(cfg.xlstm_proj_factor * d)
    dh = di // h
    b = batch_size
    dtype = jnp.dtype(cfg.dtype)
    shapes = {
        "m_C": ((g, m, b, h, dh, dh), jnp.float32),
        "m_n": ((g, m, b, h, dh), jnp.float32),
        "m_m": ((g, m, b, h), jnp.float32),
        "m_conv": ((g, m, b, 3, di), dtype),
        "pos": ((), jnp.int32),
    }
    if cfg.slstm_every:
        shapes.update({
            "s_c": ((g, b, d), jnp.float32),
            "s_n": ((g, b, d), jnp.float32),
            "s_h": ((g, b, d), jnp.float32),
            "s_m": ((g, b, d), jnp.float32),
            "s_conv": ((g, b, 3, d), dtype),
        })
    return shapes


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd[0], sd[1]),
        cache_shapes(cfg, batch_size, max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def prefill(params, batch, cfg, max_len: int, **_):
    s = batch["tokens"].shape[1]
    logits, _, states = forward(params, batch, cfg, last_only=True)
    mstates, sstates = states
    (m_C, m_n, m_m), m_conv = mstates
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len)
    cache.update({
        "m_C": m_C, "m_n": m_n, "m_m": m_m,
        "m_conv": m_conv.astype(cache["m_conv"].dtype),
        "pos": jnp.asarray(s, jnp.int32),
    })
    if cfg.slstm_every:
        (s_c, s_n, s_h, s_m), s_conv = sstates
        cache.update({"s_c": s_c, "s_n": s_n, "s_h": s_h, "s_m": s_m,
                      "s_conv": s_conv.astype(cache["s_conv"].dtype)})
    return logits, cache


def decode_step(params, batch, cache, cfg):
    x = _embed(params, batch["token"], cfg)
    has_s = bool(cfg.slstm_every)

    def group(h, inp):
        gp, mC, mn, mm, mconv = inp[:5]
        rest = inp[5:]

        def mlayer(hc, lin):
            lp, C, n, m, conv = lin
            hc2, (C2, n2, m2), conv2 = xl.mlstm_block_step(
                lp, hc, cfg, state=(C, n, m), conv_state=conv)
            return hc2, (C2, n2, m2, conv2)

        h, (mC2, mn2, mm2, mconv2) = jax.lax.scan(
            mlayer, h, (gp["mlstm"], mC, mn, mm, mconv))
        if has_s:
            sc, sn, sh, sm, sconv = rest
            h, (sc2, sn2, sh2, sm2), sconv2 = xl.slstm_block_step(
                gp["slstm"], h, cfg, state=(sc, sn, sh, sm),
                conv_state=sconv)
            return h, (mC2, mn2, mm2, mconv2, sc2, sn2, sh2, sm2, sconv2)
        return h, (mC2, mn2, mm2, mconv2)

    gp_tree = {"mlstm": params["mlstm"]}
    xs = [gp_tree, cache["m_C"], cache["m_n"], cache["m_m"], cache["m_conv"]]
    if has_s:
        gp_tree["slstm"] = params["slstm"]
        xs += [cache["s_c"], cache["s_n"], cache["s_h"], cache["s_m"],
               cache["s_conv"]]
    x, outs = jax.lax.scan(group, x, tuple(xs))
    x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    new_cache = {"m_C": outs[0], "m_n": outs[1], "m_m": outs[2],
                 "m_conv": outs[3], "pos": cache["pos"] + 1}
    if has_s:
        new_cache.update({"s_c": outs[4], "s_n": outs[5], "s_h": outs[6],
                          "s_m": outs[7], "s_conv": outs[8]})
    return logits, new_cache
