"""Shared low-level layers: norms, rotary embeddings, gated MLPs, initialisers.

Numerics policy (TPU-native):
  * weights & activations in ``cfg.dtype`` (bf16 for production, f32 for tests)
  * all reductions (norm statistics, softmax, logsumexp) in f32
  * matmuls accumulate in f32 via ``preferred_element_type``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Native-dtype dot.

    No forced f32 output: the TPU MXU accumulates bf16 dots in f32
    internally, and requesting preferred_element_type=f32 makes XLA:CPU
    materialise (and hoist out of layer scans) full f32 copies of the
    weights — polluting the dry-run memory analysis with copies that would
    not exist on the TPU target.
    """
    return jnp.matmul(x, w.astype(x.dtype))


def einsum(spec: str, *args: jax.Array) -> jax.Array:
    dt = args[0].dtype
    return jnp.einsum(spec, *(a.astype(dt) for a in args))


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    normed = (x32 - mu) * jax.lax.rsqrt(var + eps)
    # (1 + scale) convention: zero-init == identity, matching rms_norm
    return (normed * (1.0 + scale.astype(jnp.float32))
            + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the even half of the head dimension."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` ([..., S, H, D]) by ``positions`` ([..., S]).

    Uses the split-halves convention (first half paired with second half),
    matching the LLaMA/Gemma family.
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv    # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    """Classic sin/cos table (Whisper encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Gated MLPs
# --------------------------------------------------------------------------
_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu_plain": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),   # Nemotron squared-ReLU
}


_NON_GATED = ("gelu_plain", "relu2")


def gated_mlp(x: jax.Array, params: dict, act: str) -> jax.Array:
    """SwiGLU / GeGLU: act(x W_gate) * (x W_up) W_down.

    ``gelu_plain`` (Whisper/StarCoder2) and ``relu2`` (Nemotron) use the
    classic non-gated 2-matrix MLP.
    """
    fn = _ACTS[act]
    if act in _NON_GATED:
        h = fn(matmul(x, params["w_up"]))
        return matmul(h, params["w_down"])
    g = fn(matmul(x, params["w_gate"]))
    u = matmul(x, params["w_up"])
    return matmul(g * u, params["w_down"])


def mlp_param_shapes(d_model: int, d_ff: int, act: str) -> dict:
    if act in _NON_GATED:
        return {"w_up": (d_model, d_ff), "w_down": (d_ff, d_model)}
    return {
        "w_gate": (d_model, d_ff),
        "w_up": (d_model, d_ff),
        "w_down": (d_ff, d_model),
    }


# --------------------------------------------------------------------------
# Initialisation
# --------------------------------------------------------------------------
def init_dense(key: jax.Array, shape: tuple[int, ...], dtype,
               scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def init_tree(key: jax.Array, shapes: dict, dtype) -> dict:
    """Init a (nested) dict of shape-tuples into arrays.

    Name-based rules cover the special leaves of the SSM/xLSTM families
    (decay logs, dt biases, gate biases) so freshly-initialised models are
    NaN-free out of the box.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shape), k in zip(flat, keys):
        name = str(path[-1])
        if "a_log" in name:          # Mamba2: A ∈ [1, 16]
            leaves.append(jax.random.uniform(
                k, shape, jnp.float32, jnp.log(1.0), jnp.log(16.0)))
        elif "dt_bias" in name:      # softplus⁻¹(~0.02)
            leaves.append(jnp.full(shape, -4.0, jnp.float32))
        elif "d_skip" in name:
            leaves.append(jnp.ones(shape, jnp.float32))
        elif name == "b_fg":         # mLSTM forget-gate bias: start open
            leaves.append(jnp.linspace(3.0, 6.0, int(jnp.prod(
                jnp.array(shape)))).reshape(shape).astype(jnp.float32))
        elif name == "b_ig":         # mLSTM input-gate bias: start small
            leaves.append(jnp.full(shape, -5.0, jnp.float32))
        elif "scale" in name or "norm" in name:
            leaves.append(jnp.zeros(shape, dtype=jnp.float32))
        elif "bias" in name or name.startswith("b_"):
            leaves.append(jnp.zeros(shape, dtype=dtype))
        else:
            leaves.append(init_dense(k, shape, dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
