"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
(arXiv:2411.15242).

The shared attention block (single weight set) is applied before every
``cfg.shared_attn_every``-th Mamba2 layer.  Layers are organised as
G groups × K layers (K = shared_attn_every) and executed as a nested scan:

    for g in range(G):            # outer scan (shared attn + group params)
        x += shared_attn(ln(x))   # its own KV cache per application
        for k in range(K):        # inner scan (stacked mamba params)
            x += valid[g,k] * mamba2(ln(x))

When L % K != 0 the trailing group is padded with identity (valid=0) layers;
the padding overhead is reported by ``pad_fraction``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models.layers import init_tree, matmul, rms_norm
from repro.models.transformer import _lm_head, chunked_lm_loss, lm_loss


def _grouping(cfg) -> tuple[int, int]:
    k = cfg.shared_attn_every
    g = -(-cfg.num_layers // k)
    return g, k


def pad_fraction(cfg) -> float:
    g, k = _grouping(cfg)
    return (g * k - cfg.num_layers) / (g * k)


def valid_mask(cfg) -> jnp.ndarray:
    g, k = _grouping(cfg)
    idx = jnp.arange(g * k).reshape(g, k)
    return (idx < cfg.num_layers).astype(jnp.float32)


def param_shapes(cfg) -> dict:
    g, k = _grouping(cfg)
    d = cfg.d_model
    mamba = jax.tree_util.tree_map(
        lambda s: (g, k, *s), m2.mamba2_param_shapes(cfg),
        is_leaf=lambda s: isinstance(s, tuple))
    mamba["pre_norm_scale"] = (g, k, d)
    from repro.models.layers import mlp_param_shapes
    return {
        "embed": (cfg.vocab_size, d),
        "final_norm_scale": (d,),
        "mamba": mamba,
        # shared *transformer* block (attn + MLP), one weight set reused
        "shared_attn": {
            "ln_scale": (d,),
            "attn": attn_mod.attn_param_shapes(cfg),
            "ln2_scale": (d,),
            "mlp": mlp_param_shapes(d, cfg.d_ff, cfg.mlp_act),
        },
    }


def init_params(cfg, key):
    return init_tree(key, param_shapes(cfg), jnp.dtype(cfg.dtype))


def _embed(params, tokens, cfg):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    return x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)


def forward(params, batch, cfg, *, impl="chunked", remat=False,
            collect_cache=False):
    """Full segment. Returns (hidden|logits, aux, cache_parts).

    ``collect_cache=False`` (training) emits NO per-layer ys — under remat
    every scan-body output would otherwise be saved for the backward pass
    (measured: tens of GiB of dead KV/SSM states on zamba2 train_4k).
    """
    x = _embed(params, batch["tokens"], cfg)
    x = constrain(x, "activation")
    positions = jnp.arange(x.shape[1])[None, :]
    vm = valid_mask(cfg)
    shared = params["shared_attn"]

    def group(carry, inp):
        h = carry
        gp, vrow = inp                   # group params, valid row [K]
        a, kv = attn_mod.gqa_self_attention(
            shared["attn"], rms_norm(h, shared["ln_scale"], cfg.norm_eps),
            cfg, positions=positions, impl=impl)
        h = h + a
        from repro.models.layers import gated_mlp
        h = h + gated_mlp(rms_norm(h, shared["ln2_scale"], cfg.norm_eps),
                          shared["mlp"], cfg.mlp_act)
        h = constrain(h, "activation")

        def lp_wo_norm(lp):
            return {kk: vv for kk, vv in lp.items() if kk != "pre_norm_scale"}

        def layer(hc, lin):
            lp, v = lin
            y, states = m2.mamba2_block(
                lp_wo_norm(lp), rms_norm(hc, lp["pre_norm_scale"],
                                         cfg.norm_eps), cfg)
            hc = hc + (v.astype(jnp.float32) * y.astype(jnp.float32)
                       ).astype(hc.dtype)
            return constrain(hc, "activation"), states

        # per-layer remat: one Mamba layer's SSD chunk residuals live at a
        # time during the group's backward pass
        lbody = jax.checkpoint(layer) if remat else layer
        h, states = jax.lax.scan(lbody, h, (gp, vrow))
        if not collect_cache:
            return h, None
        return h, (kv, states)

    body = jax.checkpoint(group) if remat else group
    x, ys = jax.lax.scan(body, x, (params["mamba"], vm))
    x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    if collect_cache:
        kvs, states = ys
        return _lm_head(params, x[:, -1:], cfg), 0.0, (kvs, states)
    return x, 0.0, None


def train_loss(params, batch, cfg, *, impl="chunked"):
    tokens = batch["tokens"]
    x, aux, _ = forward(params, {"tokens": tokens[:, :-1]}, cfg,
                        impl=impl, remat=True)
    if cfg.loss_chunk:
        head_w = (params["embed"].T if cfg.tie_embeddings
                  and "lm_head" not in params else params["lm_head"])
        loss = chunked_lm_loss(x, head_w, tokens[:, 1:], cfg)
    else:
        loss = lm_loss(_lm_head(params, x, cfg), tokens[:, 1:],
                       batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# Cache / decode
# --------------------------------------------------------------------------
def cache_shapes(cfg, batch_size: int, max_len: int) -> dict:
    g, k = _grouping(cfg)
    dtype = jnp.dtype(cfg.dtype)
    h, p, n = cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * n
    kv = (g, batch_size, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "attn_k": (kv, dtype),
        "attn_v": (kv, dtype),
        "ssm": ((g, k, batch_size, h, p, n), jnp.float32),
        "conv": ((g, k, batch_size, cfg.ssm_conv - 1, conv_dim), dtype),
        "pos": ((), jnp.int32),
    }


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd[0], sd[1]), cache_shapes(cfg, batch_size,
                                                         max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def prefill(params, batch, cfg, max_len: int, *, impl="chunked"):
    s = batch["tokens"].shape[1]
    logits, _, (kvs, states) = forward(params, batch, cfg, impl=impl,
                                       collect_cache=True)
    ssm_state, conv_tail = states
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    k, v = kvs
    cache["attn_k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["attn_k"], k.astype(cache["attn_k"].dtype), 0, axis=2)
    cache["attn_v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["attn_v"], v.astype(cache["attn_v"].dtype), 0, axis=2)
    cache["ssm"] = ssm_state.astype(jnp.float32)
    cache["conv"] = conv_tail.astype(cache["conv"].dtype)
    return logits, cache


def decode_step(params, batch, cache, cfg):
    x = _embed(params, batch["token"], cfg)
    pos = cache["pos"]
    vm = valid_mask(cfg)
    shared = params["shared_attn"]

    def group(h, inp):
        gp, vrow, kc, vc, ssm_g, conv_g = inp
        xn = rms_norm(h, shared["ln_scale"], cfg.norm_eps)
        a, (kc2, vc2) = attn_mod.gqa_decode_attention(
            shared["attn"], xn, cfg, k_cache=kc, v_cache=vc, pos=pos)
        h = h + a
        from repro.models.layers import gated_mlp
        h = h + gated_mlp(rms_norm(h, shared["ln2_scale"], cfg.norm_eps),
                          shared["mlp"], cfg.mlp_act)

        def layer(hc, lin):
            lp, v, ssm_l, conv_l = lin
            lpm = {kk: vv for kk, vv in lp.items() if kk != "pre_norm_scale"}
            y, (ssm2, conv2) = m2.mamba2_step(
                lpm, rms_norm(hc, lp["pre_norm_scale"], cfg.norm_eps), cfg,
                ssm_state=ssm_l, conv_state=conv_l)
            # identity for padded layers: keep old state, no residual
            hc = hc + (v.astype(jnp.float32) * y.astype(jnp.float32)
                       ).astype(hc.dtype)
            ssm2 = jnp.where(v > 0, ssm2, ssm_l)
            conv2 = jnp.where(v > 0, conv2, conv_l)
            return hc, (ssm2, conv2)

        h, (ssm_g2, conv_g2) = jax.lax.scan(layer, h,
                                            (gp, vrow, ssm_g, conv_g))
        return h, (kc2, vc2, ssm_g2, conv_g2)

    x, (kc, vc, ssm, conv) = jax.lax.scan(
        group, x, (params["mamba"], vm, cache["attn_k"], cache["attn_v"],
                   cache["ssm"], cache["conv"]))
    x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    return logits, {"attn_k": kc, "attn_v": vc, "ssm": ssm, "conv": conv,
                    "pos": pos + 1}
