"""Generic decoder-only transformer: dense / MoE / MLA / VLM families.

Layers are *stacked* (leading L axis on every layer leaf) and executed with
``jax.lax.scan`` so the HLO contains one layer body regardless of depth —
essential for tractable multi-pod compile times.  Training wraps the layer
body in ``jax.checkpoint`` (remat).

Uniform model API (shared by all families via ``repro.models.registry``):

    init_params(key)                        -> params
    train_loss(params, batch)               -> (loss, metrics)
    forward(params, batch)                  -> logits          (full segment)
    prefill(params, batch, max_len)         -> (logits, cache)
    decode_step(params, batch, cache)       -> (logits, cache)
    init_cache(batch_size, max_len)         -> cache (zeros)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (gated_mlp, init_tree, matmul,
                                 mlp_param_shapes, rms_norm)

PyTree = Any


# --------------------------------------------------------------------------
# Parameter shapes
# --------------------------------------------------------------------------
def layer_shapes(cfg) -> dict:
    d = cfg.d_model
    shapes = {"ln1_scale": (d,), "ln2_scale": (d,)}
    if cfg.attn_kind == "mla":
        shapes["attn"] = mla_mod.mla_param_shapes(cfg)
    else:
        shapes["attn"] = attn_mod.attn_param_shapes(cfg)
    if cfg.num_experts:
        shapes["moe"] = moe_mod.moe_param_shapes(cfg)
    else:
        shapes["mlp"] = mlp_param_shapes(d, cfg.d_ff, cfg.mlp_act)
    return shapes


def param_shapes(cfg) -> dict:
    d, v, l = cfg.d_model, cfg.vocab_size, cfg.num_layers
    stacked = jax.tree_util.tree_map(
        lambda s: (l, *s), layer_shapes(cfg),
        is_leaf=lambda s: isinstance(s, tuple))
    shapes = {
        "embed": (v, d),
        "final_norm_scale": (d,),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (d, v)
    return shapes


def init_params(cfg, key) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    return init_tree(key, param_shapes(cfg), dtype)


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------
def _attn_full(lp, x, cfg, positions, impl):
    if cfg.attn_kind == "mla":
        out, kv = mla_mod.mla_self_attention(lp["attn"], x, cfg,
                                             positions=positions, impl=impl)
    else:
        out, kv = attn_mod.gqa_self_attention(lp["attn"], x, cfg,
                                              positions=positions, impl=impl)
    return out, kv


def _ffn(lp, x, cfg):
    if cfg.num_experts:
        y = constrain(x, "activation")
        out, aux = moe_mod.moe_mlp(lp["moe"], y, cfg)
        return out, aux
    return gated_mlp(x, lp["mlp"], cfg.mlp_act), 0.0


def block_full(lp, x, cfg, positions, impl):
    """One pre-norm layer over a full segment. Returns (x, aux, (k, v))."""
    h, kv = _attn_full(lp, rms_norm(x, lp["ln1_scale"], cfg.norm_eps), cfg,
                       positions, impl)
    x = x + h
    f, aux = _ffn(lp, rms_norm(x, lp["ln2_scale"], cfg.norm_eps), cfg)
    return x + f, aux, kv


def block_decode(lp, x, cfg, cache_l, pos):
    """One layer, one token. cache_l: per-layer cache dict."""
    xn = rms_norm(x, lp["ln1_scale"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        h, (ckv, kr) = mla_mod.mla_decode_attention(
            lp["attn"], xn, cfg, ckv_cache=cache_l["ckv"],
            kr_cache=cache_l["kr"], pos=pos, absorbed=cfg.mla_absorbed)
        new_cache = {"ckv": ckv, "kr": kr}
    else:
        h, (k, v) = attn_mod.gqa_decode_attention(
            lp["attn"], xn, cfg, k_cache=cache_l["k"], v_cache=cache_l["v"],
            pos=pos)
        new_cache = {"k": k, "v": v}
    x = x + h
    f, _ = _ffn(lp, rms_norm(x, lp["ln2_scale"], cfg.norm_eps), cfg)
    return x + f, new_cache


# --------------------------------------------------------------------------
# Full-model passes
# --------------------------------------------------------------------------
def _embed_in(params, batch, cfg):
    if cfg.takes_embeddings and "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    return constrain(x, "activation")


def _lm_head(params, x, cfg):
    if cfg.tie_embeddings and "lm_head" not in params:
        logits = matmul(x, params["embed"].T)
    else:
        logits = matmul(x, params["lm_head"])
    return constrain(logits, "logits")


def backbone(params, batch, cfg, *, impl="chunked", remat=False):
    """All layers + final norm; returns (hidden [B,S,d], aux_loss)."""
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    act_name = "activation_sp" if cfg.seq_parallel else "activation"
    x = constrain(x, act_name)

    def body(carry, lp):
        h, aux = carry
        h2, aux2, _ = block_full(lp, h, cfg, positions, impl)
        return (constrain(h2, act_name), aux + aux2), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    return rms_norm(x, params["final_norm_scale"], cfg.norm_eps), aux


def forward(params, batch, cfg, *, impl="chunked", remat=False):
    """Full-segment forward. Returns (logits [B,S,V], aux_loss)."""
    x, aux = backbone(params, batch, cfg, impl=impl, remat=remat)
    return _lm_head(params, x, cfg), aux


def lm_loss(logits, labels, mask=None):
    """Mean token cross-entropy. logits [B,S,V]; labels [B,S] int32."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    ll = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def chunked_lm_loss(x, head_w, labels, cfg):
    """Sequence-chunked vocab-parallel cross-entropy.

    Scans over sequence chunks so the f32 [B,S,V] logits never materialise —
    each chunk's logits are rematerialised in the backward pass.  Essential
    for 256k-vocab models at 1M-token global batches (DESIGN.md §5).
    """
    b, s, d = x.shape
    chunk = min(cfg.loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(acc, inp):
        xi, li = inp
        logits = constrain(matmul(xi, head_w), "logits")
        logits32 = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits32, axis=-1)
        ll = jnp.take_along_axis(
            logits32, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        return acc + ((lse - ll) * valid).sum(), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (xc, lc))
    return total / (b * s)


def train_loss(params, batch, cfg, *, impl="chunked"):
    """batch: tokens [B,S+1] (or embeds [B,S,d] + labels [B,S])."""
    if cfg.takes_embeddings and "embeds" in batch:
        inputs = {"embeds": batch["embeds"]}
        labels = batch["labels"]
    else:
        tokens = batch["tokens"]
        inputs = {"tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
    if cfg.loss_chunk:
        x, aux = backbone(params, inputs, cfg, impl=impl, remat=True)
        head_w = (params["embed"].T if cfg.tie_embeddings
                  and "lm_head" not in params else params["lm_head"])
        loss = chunked_lm_loss(x, head_w, labels, cfg)
    else:
        logits, aux = forward(params, inputs, cfg, impl=impl, remat=True)
        loss = lm_loss(logits, labels, batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# KV-cache: prefill & decode
# --------------------------------------------------------------------------
def cache_shapes(cfg, batch_size: int, max_len: int) -> dict:
    """Shape/dtype tree of the decode cache (stacked over layers)."""
    l, dtype = cfg.num_layers, jnp.dtype(cfg.dtype)
    s = min(max_len, cfg.window) if cfg.window else max_len
    if cfg.attn_kind == "mla":
        layers = {
            "ckv": ((l, batch_size, s, cfg.kv_lora_rank), dtype),
            "kr": ((l, batch_size, s, cfg.qk_rope_dim), dtype),
        }
    else:
        kv = (l, batch_size, s, cfg.num_kv_heads, cfg.head_dim)
        layers = {"k": (kv, dtype), "v": (kv, dtype)}
    return {"layers": layers, "pos": ((), jnp.int32)}


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd[0], sd[1]), cache_shapes(cfg, batch_size,
                                                         max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def prefill(params, batch, cfg, max_len: int, *, impl="chunked"):
    """Run the prompt; build the cache. Returns (last-token logits, cache)."""
    x = _embed_in(params, batch, cfg)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(carry, lp):
        h, aux = carry
        h2, aux2, kv = block_full(lp, h, cfg, positions, impl)
        return (h2, aux + aux2), kv

    (x, _aux), kvs = jax.lax.scan(body, (x, 0.0), params["layers"])
    x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = _lm_head(params, x[:, -1:], cfg)

    cache = init_cache(cfg, b, max_len)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    cache_len = cache["layers"][next(iter(cache["layers"]))].shape[2]
    if cfg.attn_kind == "mla":
        ckv, kr = kvs
        new = {"ckv": ckv, "kr": kr}
    else:
        k, v = kvs
        new = {"k": k, "v": v}
    for name, val in new.items():
        if cfg.window and s >= cache_len:
            # ring-buffer invariant: token p lives at slot p % window
            seg = val[:, :, -cache_len:]
            seg = jnp.roll(seg, shift=(s - cache_len) % cache_len, axis=2)
        else:
            seg = val
        cache["layers"][name] = jax.lax.dynamic_update_slice_in_dim(
            cache["layers"][name], seg.astype(cache["layers"][name].dtype),
            0, axis=2)
    return logits, cache


def decode_step(params, batch, cache, cfg):
    """One decode step. batch: {"token": [B,1]}. Returns (logits, cache)."""
    x = _embed_in(params, {"tokens": batch["token"]}, cfg)
    pos = cache["pos"]

    def body(h, lp_cache):
        lp, cache_l = lp_cache
        h2, new_cache = block_decode(lp, h, cfg, cache_l, pos)
        return h2, new_cache

    x, new_layer_caches = jax.lax.scan(body, x,
                                       (params["layers"], cache["layers"]))
    x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    return logits, {"layers": new_layer_caches, "pos": pos + 1}
