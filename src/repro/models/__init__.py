from repro.models.registry import ModelAPI, build_model, param_count

__all__ = ["ModelAPI", "build_model", "param_count"]
