"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM is implemented in a *chunkwise-parallel* form (the TPU adaptation —
dense [Q,Q] tiles on the MXU + a short inter-chunk scan), with the exact
sequential recurrence kept as the test oracle (``mlstm_sequential``).
Stabilisation follows the paper: running per-head max ``m`` with the
denominator ``max(|q·n|, exp(-m))``.

sLSTM is an inherently sequential per-unit recurrence (block-diagonal
recurrent weights per head) — implemented with ``lax.scan`` over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import matmul, rms_norm

NEG = -1e30


# --------------------------------------------------------------------------
# mLSTM cell
# --------------------------------------------------------------------------
def mlstm_sequential(q, k, v, i_raw, f_raw):
    """Oracle: step-by-step recurrence. q/k/v [B,S,H,D]; gates [B,S,H].

    Returns (h [B,S,H,D], (C [B,H,D,D], n [B,H,D], m [B,H])).
    """
    bsz, s, h, d = q.shape
    k = k.astype(jnp.float32) / jnp.sqrt(d)
    q, v = q.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    i_raw = i_raw.astype(jnp.float32)

    def step(state, inp):
        c, n, m = state
        qt, kt, vt, it, lft = inp
        m_new = jnp.maximum(lft + m, it)                     # [B,H]
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(lft + m - m_new)
        c = c * fp[..., None, None] + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])             # [B,H,D,D]
        n = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, c)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c, n, m_new), num / den

    init = (jnp.zeros((bsz, h, d, d), jnp.float32),
            jnp.zeros((bsz, h, d), jnp.float32),
            jnp.zeros((bsz, h), jnp.float32))
    xs = tuple(t.swapaxes(0, 1) for t in (q, k, v, i_raw, logf))
    state, hs = jax.lax.scan(step, init, xs)
    return hs.swapaxes(0, 1), state


def mlstm_chunked(q, k, v, i_raw, f_raw, chunk: int = 128):
    """Chunkwise-parallel mLSTM, numerically equal to ``mlstm_sequential``."""
    bsz, s, h, d = q.shape
    qc = min(chunk, s)
    pad = (-s) % qc
    if pad:
        zp4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        zp3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(t, zp4) for t in (q, k, v))
        i_raw = jnp.pad(i_raw, zp3)
        f_raw = jnp.pad(f_raw, zp3, constant_values=30.0)  # f≈1, i: pad i_raw
        i_raw = jnp.where(
            jnp.arange(s + pad)[None, :, None] < s, i_raw, NEG)
    nc = (s + pad) // qc
    k = k.astype(jnp.float32) / jnp.sqrt(d)
    q, v = q.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    i_raw = i_raw.astype(jnp.float32)

    def cshape(t):
        return t.reshape(bsz, nc, qc, *t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, is_, lfs = map(cshape, (q, k, v, i_raw, logf))

    causal = jnp.tril(jnp.ones((qc, qc), bool))

    def chunk_step(state, inp):
        c_st, n_st, m_st = inp_state = state
        qq, kk, vv, ii, lf = inp                 # [B,Q,H,*]
        b = jnp.cumsum(lf, axis=1)               # [B,Q,H] inclusive
        btot = b[:, -1]                          # [B,H]
        # log-weights
        dmat = (b[:, :, None, :] - b[:, None, :, :]
                + ii[:, None, :, :])             # [B,t,s,H]
        dmat = jnp.where(causal[None, :, :, None], dmat, NEG)
        m_intra = dmat.max(axis=2)               # [B,Q,H]
        m_t = jnp.maximum(b + m_st[:, None, :], m_intra)
        # intra scores
        sc = jnp.einsum("bqhd,bshd->bqsh", qq, kk)
        w = jnp.exp(dmat - m_t[:, :, None, :])
        num = jnp.einsum("bqsh,bqsh,bshe->bqhe", sc, w, vv)
        den = jnp.einsum("bqsh,bqsh->bqh", sc, w)
        # inter (carried state)
        scale_in = jnp.exp(b + m_st[:, None, :] - m_t)       # [B,Q,H]
        num = num + scale_in[..., None] * jnp.einsum(
            "bqhd,bhde->bqhe", qq, c_st)
        den = den + scale_in * jnp.einsum("bqhd,bhd->bqh", qq, n_st)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        out = num / den
        # state update
        m_new = jnp.maximum(btot + m_st,
                            (btot[:, None] - b + ii).max(axis=1))
        sc_out = jnp.exp(btot[:, None] - b + ii - m_new[:, None])  # [B,Q,H]
        c_new = (c_st * jnp.exp(btot + m_st - m_new)[..., None, None]
                 + jnp.einsum("bqh,bqhd,bqhe->bhde", sc_out, kk, vv))
        n_new = (n_st * jnp.exp(btot + m_st - m_new)[..., None]
                 + jnp.einsum("bqh,bqhd->bhd", sc_out, kk))
        return (c_new, n_new, m_new), out

    init = (jnp.zeros((bsz, h, d, d), jnp.float32),
            jnp.zeros((bsz, h, d), jnp.float32),
            jnp.zeros((bsz, h), jnp.float32))
    state, hs = jax.lax.scan(chunk_step, init, (qs, ks, vs, is_, lfs))
    out = hs.swapaxes(0, 1).reshape(bsz, nc * qc, h, d)[:, :s]
    return out, state


def mlstm_step(state, q_t, k_t, v_t, i_t, f_t):
    """Single-token mLSTM. state = (C,n,m); q/k/v [B,H,D]; gates [B,H]."""
    c, n, m = state
    d = q_t.shape[-1]
    kt = k_t.astype(jnp.float32) / jnp.sqrt(d)
    qt, vt = q_t.astype(jnp.float32), v_t.astype(jnp.float32)
    lft = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
    it = i_t.astype(jnp.float32)
    m_new = jnp.maximum(lft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(lft + m - m_new)
    c = c * fp[..., None, None] + ip[..., None, None] * (
        kt[..., :, None] * vt[..., None, :])
    n = n * fp[..., None] + ip[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                      jnp.exp(-m_new))[..., None]
    return (c, n, m_new), num / den


# --------------------------------------------------------------------------
# sLSTM cell (scalar memory, block-diagonal recurrence)
# --------------------------------------------------------------------------
def _block_diag_matmul(h, r):
    """h [B,d] × blockdiag r [H,D',D'] -> [B,d]."""
    bsz, d = h.shape
    nh, du, _ = r.shape
    return jnp.einsum("bhu,huv->bhv", h.reshape(bsz, nh, du),
                      r).reshape(bsz, d)


def slstm_scan(x, params, n_heads: int):
    """x [B,S,d] (pre-activations input); returns (h [B,S,d], final state).

    state = (c, n, hprev, m) each [B,d].
    """
    bsz, s, d = x.shape

    wz, wi, wf, wo = (params[k] for k in ("w_z", "w_i", "w_f", "w_o"))
    rz, ri, rf, ro = (params[k] for k in ("r_z", "r_i", "r_f", "r_o"))
    bz, bi, bf, bo = (params[k] for k in ("b_z", "b_i", "b_f", "b_o"))

    x32 = x.astype(jnp.float32)
    # input contributions precomputed for the whole sequence
    pre = {
        "z": jnp.einsum("bsd,de->bse", x32, wz.astype(jnp.float32)) + bz,
        "i": jnp.einsum("bsd,de->bse", x32, wi.astype(jnp.float32)) + bi,
        "f": jnp.einsum("bsd,de->bse", x32, wf.astype(jnp.float32)) + bf,
        "o": jnp.einsum("bsd,de->bse", x32, wo.astype(jnp.float32)) + bo,
    }

    def step(state, inp):
        c, n, hp, m = state
        pz, pi, pf, po = inp
        z = jnp.tanh(pz + _block_diag_matmul(hp, rz))
        it = pi + _block_diag_matmul(hp, ri)
        ft = pf + _block_diag_matmul(hp, rf)
        o = jax.nn.sigmoid(po + _block_diag_matmul(hp, ro))
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(lf + m - m_new)
        c = fp * c + ip * z
        n = fp * n + ip
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    zeros = jnp.zeros((bsz, d), jnp.float32)
    init = (zeros, zeros, zeros, zeros)
    xs = tuple(t.swapaxes(0, 1) for t in (pre["z"], pre["i"], pre["f"],
                                          pre["o"]))
    state, hs = jax.lax.scan(step, init, xs)
    return hs.swapaxes(0, 1).astype(x.dtype), state


def slstm_step(state, x_t, params):
    """Single-token sLSTM. x_t [B,d]."""
    c, n, hp, m = state
    x32 = x_t.astype(jnp.float32)

    def gate(w, r, b):
        return (x32 @ w.astype(jnp.float32) + b
                + _block_diag_matmul(hp, r))

    z = jnp.tanh(gate(params["w_z"], params["r_z"], params["b_z"]))
    it = gate(params["w_i"], params["r_i"], params["b_i"])
    ft = gate(params["w_f"], params["r_f"], params["b_f"])
    o = jax.nn.sigmoid(gate(params["w_o"], params["r_o"], params["b_o"]))
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(lf + m - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new), h.astype(x_t.dtype)


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------
def mlstm_block_shapes(cfg) -> dict:
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    h = cfg.num_heads
    return {
        "norm_scale": (d,),
        "w_up": (d, 2 * di),
        "conv_w": (4, di),
        "conv_b": (di,),
        "w_q": (di, di),
        "w_k": (di, di),
        "w_v": (di, di),
        "w_ig": (di, h),
        "b_ig": (h,),
        "w_fg": (di, h),
        "b_fg": (h,),
        "out_norm_scale": (di,),
        "w_down": (di, d),
    }


def slstm_block_shapes(cfg) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    du = d // h
    shapes = {"norm_scale": (d,), "conv_w": (4, d), "conv_b": (d,),
              "out_norm_scale": (d,), "w_down": (d, d)}
    for g in ("z", "i", "f", "o"):
        shapes[f"w_{g}"] = (d, d)
        shapes[f"r_{g}"] = (h, du, du)
        shapes[f"b_{g}"] = (d,)
    return shapes


def _conv_causal(x, w, b):
    """Depthwise causal conv, x [B,S,C], w [W,C]."""
    w32 = w.astype(jnp.float32)
    width = w32.shape[0]
    x32 = x.astype(jnp.float32)
    padded = jnp.pad(x32, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(padded[:, i:i + x32.shape[1]] * w32[i] for i in range(width))
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def mlstm_block(params, x, cfg, chunk: int = 128):
    """Full-segment mLSTM block. x [B,S,d] → (y, (C,n,m), conv_tail)."""
    from repro.distributed.context import constrain
    bsz, s, d = x.shape
    h = cfg.num_heads
    xn = rms_norm(x, params["norm_scale"], cfg.norm_eps)
    u = matmul(xn, params["w_up"])
    if cfg.xlstm_pin_inner:
        # §Perf B3: without this GSPMD splits the up-projection over the
        # model axis and must all-gather [B,S,di] before the head reshape
        # (4 heads cannot hold a 16-way shard) — pin it replicated instead
        u = constrain(u, "activation")
    di = u.shape[-1] // 2
    x_in, gate = u[..., :di], u[..., di:]
    conv_tail = x_in[:, -3:]
    xc = _conv_causal(x_in, params["conv_w"], params["conv_b"])
    if cfg.xlstm_pin_inner:
        xc = constrain(xc, "activation")
    q = matmul(xc, params["w_q"]).reshape(bsz, s, h, di // h)
    k = matmul(xc, params["w_k"]).reshape(bsz, s, h, di // h)
    v = matmul(x_in, params["w_v"]).reshape(bsz, s, h, di // h)
    i_raw = (xc.astype(jnp.float32) @ params["w_ig"].astype(jnp.float32)
             + params["b_ig"])
    f_raw = (xc.astype(jnp.float32) @ params["w_fg"].astype(jnp.float32)
             + params["b_fg"])
    out, state = mlstm_chunked(q, k, v, i_raw, f_raw, chunk)
    out = rms_norm(out.astype(x.dtype),
                   params["out_norm_scale"].reshape(h, di // h),
                   cfg.norm_eps).reshape(bsz, s, di)
    out = out * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return x + matmul(out, params["w_down"]), state, conv_tail


def mlstm_block_step(params, x, cfg, *, state, conv_state):
    """Single-token mLSTM block. x [B,1,d]; conv_state [B,3,di]."""
    bsz, _, d = x.shape
    h = cfg.num_heads
    xn = rms_norm(x, params["norm_scale"], cfg.norm_eps)
    u = matmul(xn, params["w_up"])
    di = u.shape[-1] // 2
    x_in, gate = u[..., :di], u[..., di:]
    window = jnp.concatenate([conv_state, x_in], axis=1)     # [B,4,di]
    new_conv = window[:, 1:]
    w32 = params["conv_w"].astype(jnp.float32)
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w32)
        + params["conv_b"].astype(jnp.float32)).astype(x.dtype)
    q = matmul(xc, params["w_q"]).reshape(bsz, h, di // h)
    k = matmul(xc, params["w_k"]).reshape(bsz, h, di // h)
    v = matmul(x_in[:, 0], params["w_v"]).reshape(bsz, h, di // h)
    i_raw = (xc.astype(jnp.float32) @ params["w_ig"].astype(jnp.float32)
             + params["b_ig"])
    f_raw = (xc.astype(jnp.float32) @ params["w_fg"].astype(jnp.float32)
             + params["b_fg"])
    new_state, out = mlstm_step(state, q, k, v, i_raw, f_raw)
    out = rms_norm(out.astype(x.dtype)[:, None],
                   params["out_norm_scale"].reshape(h, di // h),
                   cfg.norm_eps).reshape(bsz, 1, di)
    out = out * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return x + matmul(out, params["w_down"]), new_state, new_conv


def slstm_block(params, x, cfg):
    """Full-segment sLSTM block. Returns (y, state, conv_tail)."""
    xn = rms_norm(x, params["norm_scale"], cfg.norm_eps)
    conv_tail = xn[:, -3:]
    xc = _conv_causal(xn, params["conv_w"], params["conv_b"])
    out, state = slstm_scan(xc, params, cfg.num_heads)
    out = rms_norm(out, params["out_norm_scale"], cfg.norm_eps)
    return x + matmul(out, params["w_down"]), state, conv_tail


def slstm_block_step(params, x, cfg, *, state, conv_state):
    xn = rms_norm(x, params["norm_scale"], cfg.norm_eps)     # [B,1,d]
    window = jnp.concatenate([conv_state, xn], axis=1)
    new_conv = window[:, 1:]
    w32 = params["conv_w"].astype(jnp.float32)
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w32)
        + params["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_state, out = slstm_step(state, xc, params)
    out = rms_norm(out[:, None], params["out_norm_scale"], cfg.norm_eps)
    return x + matmul(out, params["w_down"]), new_state, new_conv
