"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed to a per-token latent ``c_kv`` of size
``kv_lora_rank`` plus a shared rope key of size ``qk_rope_dim``; per-head
keys/values are reconstructed with up-projections.  The decode cache stores
only (c_kv, k_rope) — 576 B/token/layer at the assigned dims — which is what
makes 512k-token decode contexts feasible (DESIGN.md §4).

Two decode paths:
  * ``absorbed=False`` (baseline): reconstruct full K/V each step — faithful
    to the naive formulation, heavy on HBM traffic.
  * ``absorbed=True`` (optimised): fold W_uk into the query and W_uv past the
    attention, so scores are taken directly against the latent cache.
    This is the §Perf hillclimb lever for decode shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, matmul, rms_norm

NEG_INF = -1e30


def mla_param_shapes(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq": (d, h * (dn + dr)),          # queries (nope + rope parts)
        "w_dkv": (d, r),                   # KV down-projection (latent)
        "w_kr": (d, dr),                   # shared rope key
        "kv_norm_scale": (r,),
        "w_uk": (r, h * dn),               # latent -> per-head key (nope)
        "w_uv": (r, h * dv),               # latent -> per-head value
        "wo": (h * dv, d),
    }


def _queries(params, x, cfg, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = matmul(x, params["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(params, x, cfg, positions):
    c_kv = rms_norm(matmul(x, params["w_dkv"]), params["kv_norm_scale"],
                    cfg.norm_eps)                       # [B,S,r]
    k_rope = matmul(x, params["w_kr"])[:, :, None, :]   # [B,S,1,dr]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_self_attention(params, x, cfg, *, positions, impl="chunked"):
    """Full-segment MLA (train / prefill). Returns (out, (c_kv, k_rope))."""
    from repro.models.attention import chunked_attention, naive_attention
    b, s, _ = x.shape
    h, dn, dv = cfg.num_heads, cfg.qk_nope_dim, cfg.v_head_dim
    dr = cfg.qk_rope_dim
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_kv, k_rope = _latent(params, x, cfg, positions)
    k_nope = matmul(c_kv, params["w_uk"]).reshape(b, s, h, dn)
    v = matmul(c_kv, params["w_uv"]).reshape(b, s, h, dv)
    # pack rope part into the head dim so one attention call handles both
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
        axis=-1)
    scale = (dn + dr) ** -0.5
    fn = naive_attention if impl == "naive" else chunked_attention
    out = fn(q, k, v, causal=True, scale=scale)
    out = matmul(out.reshape(b, s, h * dv), params["wo"])
    return out, (c_kv, k_rope)


def mla_decode_attention(params, x, cfg, *, ckv_cache, kr_cache, pos,
                         absorbed=True):
    """One-token MLA against the latent cache.

    ckv_cache [B,Smax,r]; kr_cache [B,Smax,dr]; returns (out, new caches).
    """
    b, _, _ = x.shape
    h, dn, dv, dr, r = (cfg.num_heads, cfg.qk_nope_dim, cfg.v_head_dim,
                        cfg.qk_rope_dim, cfg.kv_lora_rank)
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, x, cfg, positions)    # [B,1,h,dn/dr]
    c_new, kr_new = _latent(params, x, cfg, positions)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, c_new, pos, 1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr_new, pos, 1)
    smax = ckv_cache.shape[1]
    valid = jnp.arange(smax) <= pos
    scale = (dn + dr) ** -0.5

    dt = x.dtype
    if absorbed:
        # fold W_uk into q: per-head latent-space query [B,h,r]
        w_uk = params["w_uk"].reshape(r, h, dn)
        q_lat = jnp.einsum("bohd,rhd->bhr", q_nope, w_uk.astype(dt))
        s_nope = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache.astype(dt))
        s_rope = jnp.einsum("bohd,bsd->bhs", q_rope, kr_cache.astype(dt))
        s = (s_nope + s_rope).astype(jnp.float32) * scale
        s = s + jnp.where(valid, 0.0, NEG_INF)[None, None, :]
        p = jax.nn.softmax(s, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhs,bsr->bhr", p, ckv_cache.astype(dt))
        w_uv = params["w_uv"].reshape(r, h, dv)
        out = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(dt))
        out = out.reshape(b, 1, h * dv).astype(x.dtype)
    else:
        # naive: reconstruct K/V for the whole context every step
        k_nope = matmul(ckv_cache, params["w_uk"]).reshape(b, smax, h, dn)
        v = matmul(ckv_cache, params["w_uv"]).reshape(b, smax, h, dv)
        s_nope = jnp.einsum("bohd,bshd->bhs", q_nope, k_nope.astype(dt))
        s_rope = jnp.einsum("bohd,bsd->bhs", q_rope, kr_cache.astype(dt))
        sc = (s_nope + s_rope).astype(jnp.float32) * scale
        sc = sc + jnp.where(valid, 0.0, NEG_INF)[None, None, :]
        p = jax.nn.softmax(sc, axis=-1).astype(dt)
        out = jnp.einsum("bhs,bshd->bhd", p, v)
        out = out.reshape(b, 1, h * dv).astype(x.dtype)

    return matmul(out, params["wo"]), (ckv_cache, kr_cache)
