"""Attention: GQA/MQA, sliding-window, qk-norm, chunked (flash-style) prefill.

Three execution paths, all numerically equivalent (tested against each other):

  * ``naive``   — materialises the full score matrix; oracle + tiny models.
  * ``chunked`` — pure-JAX online-softmax over KV blocks (lax.scan), bounding
                  HLO temporaries to O(block²) — the dry-run/compile path that
                  keeps 32k-prefill memory honest. This is the jnp twin of the
                  Pallas flash kernel in ``repro.kernels.flash_attention``.
  * ``decode``  — one-token query against a (possibly ring-buffered) KV cache.

Shapes: q [B,Sq,Hq,D], k/v [B,Skv,Hkv,D]; grouping G = Hq // Hkv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm

NEG_INF = -1e30


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,Hq,D] -> [B,S,Hkv,G,D]."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _mask_bias(pos_q: jax.Array, pos_k: jax.Array, causal: bool,
               window: int, valid_k=None) -> jax.Array:
    """Additive bias [..., Sq, Sk] from absolute positions."""
    dq = pos_q[..., :, None]
    dk = pos_k[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), dtype=bool)
    if causal:
        ok &= dk <= dq
    if window:
        ok &= (dq - dk) < window
    if valid_k is not None:
        ok &= valid_k[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def naive_attention(q, k, v, *, causal=True, window=0, pos_q=None,
                    pos_k=None, valid_k=None, scale=None):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    if pos_q is None:
        pos_q = jnp.arange(sq)
    if pos_k is None:
        pos_k = jnp.arange(sk)
    scale = scale if scale is not None else d ** -0.5
    qg = _group(q, hkv)                                       # [B,Sq,Hkv,G,D]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    bias = _mask_bias(pos_q, pos_k, causal, window, valid_k)  # [...,Sq,Sk]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=0, q_chunk=512,
                      kv_chunk=512, scale=None):
    """Flash-style attention; pads to chunk multiples and delegates to
    :func:`flash_attention` (which carries the flash custom VJP)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    pq = (-sq) % q_chunk
    pk = (-sk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    out = flash_attention(qp, kp, vp, causal, window, scale, q_chunk,
                          kv_chunk, sk)
    return out[:, :sq]


# --------------------------------------------------------------------------
# Flash attention with a flash *backward* (custom_vjp)
#
# Differentiating the chunked scan directly would store every exp-score
# block (O(S²) residuals — measured 8 GiB/block-row on qwen3 train_4k).
# The custom VJP recomputes scores blockwise from the saved logsumexp, which
# is exactly what the Pallas TPU kernel does on-chip.
# --------------------------------------------------------------------------
def _flash_fwd_inner(q, k, v, causal, window, scale, q_chunk, kv_chunk,
                     valid_len):
    """Returns (out [B,Sq,Hq,Dv], lse [B,Hkv,G,Sq])."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    nq, nk = sq // q_chunk, sk // kv_chunk
    qg = _group(q, hkv).reshape(b, nq, q_chunk, hkv, g, d) \
        .transpose(1, 0, 3, 4, 2, 5)                    # [nq,B,Hkv,G,qc,D]
    kc = k.reshape(b, nk, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, hkv, dv).transpose(1, 0, 3, 2, 4)

    def q_block(_, qi_qb):
        qi, qb = qi_qb
        pos_q = qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(state, ki_kv):
            ki, kb, vb = ki_kv
            m, l, acc = state
            pos_k = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            ok = pos_k[None, :] <= pos_q[:, None] if causal else \
                jnp.ones((q_chunk, kv_chunk), bool)
            if window:
                ok &= (pos_q[:, None] - pos_k[None, :]) < window
            ok &= (pos_k < valid_len)[None, :]
            s = s + jnp.where(ok, 0.0, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qg))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, dv)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, sq)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=0, scale=None,
                    q_chunk=512, kv_chunk=512, valid_len=0):
    """Memory-bounded attention, O(block²) temporaries in fwd AND bwd.

    Sq/Skv must be multiples of the chunk sizes (callers pad; chunk sizes
    are clamped in ``chunked_attention``).
    """
    d = q.shape[-1]
    scale_v = scale if scale is not None else d ** -0.5
    out, _ = _flash_fwd_inner(q, k, v, causal, window, scale_v,
                              q_chunk, kv_chunk, valid_len or k.shape[1])
    return out


def _flash_fwd(q, k, v, causal, window, scale, q_chunk, kv_chunk, valid_len):
    d = q.shape[-1]
    scale_v = scale if scale is not None else d ** -0.5
    out, lse = _flash_fwd_inner(q, k, v, causal, window, scale_v,
                                q_chunk, kv_chunk, valid_len or k.shape[1])
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, scale, q_chunk, kv_chunk, valid_len, res, do):
    q, k, v, out, lse = res
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale_v = scale if scale is not None else d ** -0.5
    nk = sk // kv_chunk

    qg = _group(q, hkv).astype(jnp.float32)                 # [B,Sq,Hkv,G,D]
    og = _group(out, hkv).astype(jnp.float32)               # [B,Sq,Hkv,G,Dv]
    dog = _group(do, hkv).astype(jnp.float32)
    delta = (og * dog).sum(-1)                              # [B,Sq,Hkv,G]
    pos_q = jnp.arange(sq)
    kc_all = k.reshape(b, nk, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    vc_all = v.reshape(b, nk, kv_chunk, hkv, dv).transpose(1, 0, 3, 2, 4)

    def kv_block(dq_acc, inp):
        ki, kb, vb = inp                                    # [B,Hkv,kc,*]
        pos_k = ki * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhgd,bhkd->bhgqk", qg,
                       kb.astype(jnp.float32)) * scale_v
        ok = pos_k[None, :] <= pos_q[:, None] if causal else \
            jnp.ones((sq, kv_chunk), bool)
        if window:
            ok &= (pos_q[:, None] - pos_k[None, :]) < window
        ok &= (pos_k < (valid_len or sk))[None, :]
        s = s + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
        p = jnp.exp(s - lse.transpose(0, 1, 2, 3)[..., None])  # [B,h,g,Sq,kc]
        dvb = jnp.einsum("bhgqk,bqhgd->bhkd", p, dog)
        dp = jnp.einsum("bqhgd,bhkd->bhgqk", dog, vb.astype(jnp.float32))
        ds = p * (dp - delta.transpose(0, 2, 3, 1)[..., None]) * scale_v
        dkb = jnp.einsum("bhgqk,bqhgd->bhkd", ds, qg)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bqhgd", ds,
                                     kb.astype(jnp.float32))
        return dq_acc, (dkb, dvb)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_block, dq0,
                                  (jnp.arange(nk), kc_all, vc_all))
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(b, sk, hkv, d)
    dv_ = dvs.transpose(1, 0, 3, 2, 4).reshape(b, sk, hkv, dv)
    return (dq.reshape(b, sq, hq, d).astype(q.dtype),
            dk.astype(k.dtype), dv_.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, scale=None):
    """One-token attention against a cache.

    q [B,1,Hq,D]; caches [B,Smax,Hkv,D]; ``pos`` — the absolute position of
    the query token: scalar int32, or [B] int32 for ragged per-slot
    positions (continuous batching). With ``window > 0`` the cache is a
    ring buffer of size Smax == window (slot = abs_pos % window); otherwise
    it is linear and slots ≤ pos are valid.
    """
    b, _, hq, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    scale = scale if scale is not None else d ** -0.5
    slots = jnp.arange(smax)
    pos_v = jnp.broadcast_to(jnp.asarray(pos), (b,))           # [B]
    if window:
        # absolute position held by each ring slot (after this step's write)
        abs_pos = pos_v[:, None] - jnp.mod(pos_v[:, None] - slots[None, :],
                                           window)
        valid = abs_pos >= 0                                    # [B,Smax]
    else:
        valid = slots[None, :] <= pos_v[:, None]                # [B,Smax]
    qg = _group(q, hkv)[:, 0]                                  # [B,Hkv,G,D]
    # native-dtype dot against the cache (no materialised f32 cache copy);
    # softmax statistics still run in f32
    s = jnp.einsum("bhgd,bkhd->bhgk", qg,
                   k_cache.astype(q.dtype)).astype(jnp.float32) * scale
    s = s + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, v_cache.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# Full GQA attention layer (projections + rope + qk-norm + attention)
# --------------------------------------------------------------------------
def attn_param_shapes(cfg) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    shapes = {
        "wq": (d, hq * hd),
        "wk": (d, hkv * hd),
        "wv": (d, hkv * hd),
        "wo": (hq * hd, d),
    }
    if cfg.use_qk_norm:
        shapes["q_norm_scale"] = (hd,)
        shapes["k_norm_scale"] = (hd,)
    return shapes


def _project_qkv(params, x, cfg, positions):
    from repro.models.layers import matmul
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = matmul(x, params["wq"]).reshape(b, s, hq, hd)
    k = matmul(x, params["wk"]).reshape(b, s, hkv, hd)
    v = matmul(x, params["wv"]).reshape(b, s, hkv, hd)
    if cfg.use_qk_norm:
        q = rms_norm(q, params["q_norm_scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm_scale"], cfg.norm_eps)
    if cfg.use_rope:
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_self_attention(params, x, cfg, *, positions, impl="chunked"):
    """Self-attention over a full segment (train / prefill). Returns (out, (k, v))."""
    from repro.models.layers import matmul
    q, k, v = _project_qkv(params, x, cfg, positions)
    if impl == "naive":
        out = naive_attention(q, k, v, causal=True, window=cfg.window)
    else:
        out = chunked_attention(q, k, v, causal=True, window=cfg.window)
    b, s, hq, hd = q.shape
    return matmul(out.reshape(b, s, hq * hd), params["wo"]), (k, v)


def gqa_decode_attention(params, x, cfg, *, k_cache, v_cache, pos):
    """One-token self-attention; returns (out, (new_k_cache, new_v_cache)).

    ``pos`` is the absolute position of the incoming token — scalar, or [B]
    for ragged continuous-batching slots.  The new K/V are written at slot
    ``pos % window`` (ring) or ``pos`` (linear) and attention runs over the
    updated cache.
    """
    from repro.models.layers import matmul
    b = x.shape[0]
    pos_arr = jnp.asarray(pos)
    positions = jnp.broadcast_to(
        pos_arr.astype(jnp.int32), (b,))[:, None] if pos_arr.ndim \
        else jnp.full((b, 1), pos_arr, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    slot = jnp.mod(pos_arr, cfg.window) if cfg.window else pos_arr
    if pos_arr.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, 1)
    else:
        # per-row write positions (ragged continuous-batching slots)
        upd = jax.vmap(
            lambda c, kk, s_: jax.lax.dynamic_update_slice_in_dim(
                c, kk, s_, axis=0))
        k_cache = upd(k_cache, k, slot)
        v_cache = upd(v_cache, v, slot)
    out = decode_attention(q, k_cache, v_cache, pos_arr, window=cfg.window)
    _, _, hq, hd = q.shape
    return matmul(out.reshape(b, 1, hq * hd), params["wo"]), (k_cache, v_cache)
