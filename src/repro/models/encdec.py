"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the mel-spectrogram + conv frontend is a STUB: the
encoder consumes precomputed frame embeddings [B, enc_seq, d] from
``input_specs``.  Deviations (recorded in DESIGN.md): sinusoidal decoder
positions instead of learned (keeps parameter shapes independent of the
requested stand-in sequence lengths), bias-free projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.attention import decode_attention
from repro.models.layers import (gated_mlp, init_tree, layer_norm, matmul,
                                 mlp_param_shapes, sinusoidal_positions)
from repro.models.transformer import chunked_lm_loss, lm_loss


def _ln_shapes(d):
    return {"scale": (d,), "bias": (d,)}


def enc_layer_shapes(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": _ln_shapes(d),
        "attn": attn_mod.attn_param_shapes(cfg),
        "ln2": _ln_shapes(d),
        "mlp": mlp_param_shapes(d, cfg.d_ff, "gelu_plain"),
    }


def dec_layer_shapes(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": _ln_shapes(d),
        "self_attn": attn_mod.attn_param_shapes(cfg),
        "ln2": _ln_shapes(d),
        "cross_attn": attn_mod.attn_param_shapes(cfg),
        "ln3": _ln_shapes(d),
        "mlp": mlp_param_shapes(d, cfg.d_ff, "gelu_plain"),
    }


def param_shapes(cfg) -> dict:
    stack = lambda n, s: jax.tree_util.tree_map(
        lambda t: (n, *t), s, is_leaf=lambda t: isinstance(t, tuple))
    d = cfg.d_model
    return {
        "embed": (cfg.vocab_size, d),
        "enc_layers": stack(cfg.enc_layers, enc_layer_shapes(cfg)),
        "enc_final_ln": _ln_shapes(d),
        "dec_layers": stack(cfg.num_layers, dec_layer_shapes(cfg)),
        "dec_final_ln": _ln_shapes(d),
    }


def init_params(cfg, key):
    return init_tree(key, param_shapes(cfg), jnp.dtype(cfg.dtype))


def _ln(x, p, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def _mha(params, x, cfg, *, kv=None, causal, impl):
    """Self (kv=None) or cross attention, no rope (whisper)."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    src = x if kv is None else kv
    q = matmul(x, params["wq"]).reshape(b, s, h, hd)
    k = matmul(src, params["wk"]).reshape(b, src.shape[1], h, hd)
    v = matmul(src, params["wv"]).reshape(b, src.shape[1], h, hd)
    if impl == "naive":
        out = attn_mod.naive_attention(q, k, v, causal=causal)
    else:
        out = attn_mod.chunked_attention(q, k, v, causal=causal)
    return matmul(out.reshape(b, s, h * hd), params["wo"]), (k, v)


def encode(params, frames, cfg, *, impl="chunked", remat=False):
    """frames [B,enc_seq,d] (stubbed conv-frontend output) -> [B,enc_seq,d]."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = x + pos[None]

    def layer(h, lp):
        a, _ = _mha(lp["attn"], _ln(h, lp["ln1"], cfg.norm_eps), cfg,
                    causal=False, impl=impl)
        h = h + a
        h = h + gated_mlp(_ln(h, lp["ln2"], cfg.norm_eps), lp["mlp"],
                          "gelu_plain")
        return h, None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["enc_final_ln"], cfg.norm_eps)


def _dec_embed(params, tokens, cfg, start_pos=0):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = sinusoidal_positions(start_pos + tokens.shape[1],
                               cfg.d_model).astype(x.dtype)
    return x + pos[None, start_pos:]


def decode_full(params, tokens, enc_out, cfg, *, impl="chunked",
                remat=False, return_hidden=False, collect_cache=True):
    """Teacher-forced decoder pass. Returns (logits|hidden, kvs)."""
    x = _dec_embed(params, tokens, cfg)

    def layer(h, lp):
        a, skv = _mha(lp["self_attn"], _ln(h, lp["ln1"], cfg.norm_eps), cfg,
                      causal=True, impl=impl)
        h = h + a
        c, ckv = _mha(lp["cross_attn"], _ln(h, lp["ln2"], cfg.norm_eps), cfg,
                      kv=enc_out, causal=False, impl=impl)
        h = h + c
        h = h + gated_mlp(_ln(h, lp["ln3"], cfg.norm_eps), lp["mlp"],
                          "gelu_plain")
        return h, ((skv, ckv) if collect_cache else None)

    body = jax.checkpoint(layer) if remat else layer
    x, kvs = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(x, params["dec_final_ln"], cfg.norm_eps)
    if return_hidden:
        return x, kvs
    return matmul(x, params["embed"].T), kvs


def train_loss(params, batch, cfg, *, impl="chunked"):
    """batch: frames [B,enc_seq,d], tokens [B,S+1]."""
    enc_out = encode(params, batch["frames"], cfg, impl=impl, remat=True)
    tokens = batch["tokens"]
    if cfg.loss_chunk:
        x, _ = decode_full(params, tokens[:, :-1], enc_out, cfg, impl=impl,
                           remat=True, return_hidden=True,
                           collect_cache=False)
        loss = chunked_lm_loss(x, params["embed"].T, tokens[:, 1:], cfg)
    else:
        logits, _ = decode_full(params, tokens[:, :-1], enc_out, cfg,
                                impl=impl, remat=True, collect_cache=False)
        loss = lm_loss(logits, tokens[:, 1:], batch.get("mask"))
    return loss, {"xent": loss, "aux": jnp.zeros(())}


# --------------------------------------------------------------------------
# Cache / decode
# --------------------------------------------------------------------------
def cache_shapes(cfg, batch_size: int, max_len: int) -> dict:
    l, dtype = cfg.num_layers, jnp.dtype(cfg.dtype)
    h, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "self_k": ((l, batch_size, max_len, h, hd), dtype),
        "self_v": ((l, batch_size, max_len, h, hd), dtype),
        "cross_k": ((l, batch_size, cfg.enc_seq, h, hd), dtype),
        "cross_v": ((l, batch_size, cfg.enc_seq, h, hd), dtype),
        "pos": ((), jnp.int32),
    }


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd[0], sd[1]),
        cache_shapes(cfg, batch_size, max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def prefill(params, batch, cfg, max_len: int, *, impl="chunked"):
    """batch: frames + tokens (prompt). Builds self+cross caches."""
    enc_out = encode(params, batch["frames"], cfg, impl=impl)
    tokens = batch["tokens"]
    logits, (skv, ckv) = decode_full(params, tokens, enc_out, cfg, impl=impl)
    cache = init_cache(cfg, tokens.shape[0], max_len)
    sk, sv = skv
    cache["self_k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["self_k"], sk.astype(cache["self_k"].dtype), 0, axis=2)
    cache["self_v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["self_v"], sv.astype(cache["self_v"].dtype), 0, axis=2)
    cache["cross_k"], cache["cross_v"] = (
        ckv[0].astype(cache["cross_k"].dtype),
        ckv[1].astype(cache["cross_v"].dtype))
    cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits[:, -1:], cache


def decode_step(params, batch, cache, cfg):
    """One token. batch: {"token": [B,1]}."""
    pos = cache["pos"]
    x = _dec_embed_at(params, batch["token"], cfg, pos)
    h_heads, hd = cfg.num_heads, cfg.head_dim
    enc_valid = jnp.asarray(cfg.enc_seq - 1, jnp.int32)

    def layer(h, inp):
        lp, sk, sv, ck, cv = inp
        b = h.shape[0]
        xn = _ln(h, lp["ln1"], cfg.norm_eps)
        q = matmul(xn, lp["self_attn"]["wq"]).reshape(b, 1, h_heads, hd)
        k = matmul(xn, lp["self_attn"]["wk"]).reshape(b, 1, h_heads, hd)
        v = matmul(xn, lp["self_attn"]["wv"]).reshape(b, 1, h_heads, hd)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k, pos, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v, pos, axis=1)
        a = decode_attention(q, sk, sv, pos)
        h = h + matmul(a.reshape(b, 1, h_heads * hd), lp["self_attn"]["wo"])
        xn = _ln(h, lp["ln2"], cfg.norm_eps)
        q = matmul(xn, lp["cross_attn"]["wq"]).reshape(b, 1, h_heads, hd)
        c = decode_attention(q, ck, cv, enc_valid)
        h = h + matmul(c.reshape(b, 1, h_heads * hd), lp["cross_attn"]["wo"])
        h = h + gated_mlp(_ln(h, lp["ln3"], cfg.norm_eps), lp["mlp"],
                          "gelu_plain")
        return h, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        layer, x, (params["dec_layers"], cache["self_k"], cache["self_v"],
                   cache["cross_k"], cache["cross_v"]))
    x = _ln(x, params["dec_final_ln"], cfg.norm_eps)
    logits = matmul(x, params["embed"].T)
    new_cache = dict(cache)
    new_cache.update({"self_k": sk, "self_v": sv, "pos": pos + 1})
    return logits, new_cache


def _dec_embed_at(params, token, cfg, pos):
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    d = cfg.d_model
    half = d // 2
    dim = jnp.arange(half, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000.0 ** (2 * dim / d))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(x.dtype)
    return x + pe[None, None, :]
