"""Uniform model API over all architecture families.

``build_model(cfg)`` returns a :class:`ModelAPI` whose five callables are
pure functions of (params, batch[, cache]) — directly jit/pjit-able by the
launchers, the profiler, and the tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models import encdec, hybrid, transformer, xlstm_model

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: Any
    init_params: Callable[[jax.Array], PyTree]
    train_loss: Callable[[PyTree, dict], tuple[jax.Array, dict]]
    prefill: Callable[[PyTree, dict, int], tuple[jax.Array, PyTree]]
    decode_step: Callable[[PyTree, dict, PyTree], tuple[jax.Array, PyTree]]
    init_cache: Callable[[int, int], PyTree]
    cache_shapes: Callable[[int, int], PyTree]
    param_shapes: Callable[[], PyTree]


def build_model(cfg, *, impl: str = "chunked") -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: mod.init_params(cfg, key),
            train_loss=lambda p, b: mod.train_loss(p, b, cfg, impl=impl),
            prefill=lambda p, b, m: mod.prefill(p, b, cfg, m, impl=impl),
            decode_step=lambda p, b, c: mod.decode_step(p, b, c, cfg),
            init_cache=lambda bs, m: mod.init_cache(cfg, bs, m),
            cache_shapes=lambda bs, m: mod.cache_shapes(cfg, bs, m),
            param_shapes=lambda: mod.param_shapes(cfg),
        )
    if fam == "ssm":
        mod = xlstm_model
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "audio":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")
    kwargs = {} if fam == "ssm" else {"impl": impl}
    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: mod.init_params(cfg, key),
        train_loss=lambda p, b: mod.train_loss(p, b, cfg, **kwargs),
        prefill=lambda p, b, m: mod.prefill(p, b, cfg, m, **kwargs),
        decode_step=lambda p, b, c: mod.decode_step(p, b, c, cfg),
        init_cache=lambda bs, m: mod.init_cache(cfg, bs, m),
        cache_shapes=lambda bs, m: mod.cache_shapes(cfg, bs, m),
        param_shapes=lambda: mod.param_shapes(cfg),
    )


def param_count(shapes: PyTree) -> int:
    import numpy as np
    leaves = jax.tree_util.tree_leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    return int(sum(int(np.prod(s)) for s in leaves if isinstance(s, tuple)))
