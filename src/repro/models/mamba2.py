"""Mamba2 / SSD block (arXiv:2405.21060 form), used by zamba2.

State-space recurrence per head h with scalar decay:

    H_t = a_t * H_{t-1} + dt_t * x_t ⊗ B_t          H ∈ [P, N]
    y_t = H_t · C_t + D * x_t

computed in the TPU-friendly *chunked* (block-decomposition) form: intra-chunk
work is dense matmuls over [Q, Q] tiles, inter-chunk work is a short scan over
chunk states — matching how the SSD kernel tiles onto the MXU (the Pallas twin
lives in ``repro.kernels.ssm_scan``).

TPU-sharding adaptation (DESIGN.md §2): the reference CUDA implementation
fuses z/x/B/C/dt into one in-projection and one grouped conv.  Because the
conv is depthwise (per-channel), splitting it into separate x/B/C streams is
*exactly* equivalent — and it makes the x-stream head-aligned so the SSD
heads shard cleanly over the ``model`` mesh axis.

Shapes: x [B,S,H,P]; dt [B,S,H]; B,C [B,S,N] (single group, shared across
heads); A_log [H]; D [H].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import matmul, rms_norm


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q

    # per-step log decay: log a_t = -exp(A_log) * dt_t   [B,S,H]
    log_a = (-jnp.exp(a_log.astype(jnp.float32))[None, None, :]
             * dt.astype(jnp.float32))
    xb = (x.astype(jnp.float32)
          * dt.astype(jnp.float32)[..., None])              # dt-weighted input

    # reshape to chunks: [B,nc,Q,...] -> scan over nc
    def cshape(t):
        return t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xc, lc, bc, cc = map(cshape, (xb, log_a, b.astype(jnp.float32),
                                  c.astype(jnp.float32)))

    def chunk_step(state, inp):
        xq, lq, bq, cq = inp          # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        csum = jnp.cumsum(lq, axis=1)                       # [B,Q,H] inclusive
        total = csum[:, -1]                                 # [B,H]
        # --- inter-chunk: contribution of the carried state -------------
        #   y_inter[t] = exp(csum[t]) * C_t · H_prev
        decay_in = jnp.exp(csum)                            # [B,Q,H]
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, state) * decay_in[..., None]
        # --- intra-chunk: dense causal tile ------------------------------
        #   L[t,s] = exp(csum[t] - csum[s]) for s <= t  (decay s→t, excl. s)
        rel = csum[:, :, None, :] - csum[:, None, :, :]     # [B,Q,Q,H]
        causal = jnp.tril(jnp.ones((q, q), bool))
        gate = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bqn,bsn->bqs", cq, bq)         # [B,Q,Q]
        y_intra = jnp.einsum("bqs,bqsh,bshp->bqhp",
                             scores, gate, xq)
        # --- state update -------------------------------------------------
        #   H_new = exp(total) * H_prev + sum_s exp(total - csum[s]) B_s x_s^T
        decay_out = jnp.exp(total[:, None] - csum)          # [B,Q,H]
        new_state = (state * jnp.exp(total)[..., None, None]
                     + jnp.einsum("bsh,bsn,bshp->bhpn", decay_out, bq, xq))
        return new_state, y_inter + y_intra

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, yc = jax.lax.scan(chunk_step, init, (xc, lc, bc, cc))
    y = yc.swapaxes(0, 1).reshape(bsz, nc * q, h, p)[:, :s]
    # D skip connection uses the *raw* (un-dt-weighted) input
    y = y + (d_skip.astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)[:, :s])
    return y, final_state


def ssd_step(state, x_t, dt_t, a_log, b_t, c_t, d_skip):
    """Single-token SSD recurrence.

    state [B,H,P,N]; x_t [B,H,P]; dt_t [B,H]; b_t/c_t [B,N].
    Returns (y_t [B,H,P], new_state).
    """
    log_a = -jnp.exp(a_log.astype(jnp.float32))[None, :] * dt_t.astype(jnp.float32)
    a = jnp.exp(log_a)                                       # [B,H]
    xb = x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None]
    outer = jnp.einsum("bhp,bn->bhpn", xb, b_t.astype(jnp.float32))
    new_state = state * a[..., None, None] + outer
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_t.astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32)[None, :, None] * x_t.astype(jnp.float32)
    return y, new_state


# --------------------------------------------------------------------------
# Full Mamba2 block: projections + causal depthwise convs + SSD + gated norm
# --------------------------------------------------------------------------
def mamba2_param_shapes(cfg) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    return {
        "w_z": (d, di),
        "w_x": (d, di),
        "w_b": (d, n),
        "w_c": (d, n),
        "w_dt": (d, h),
        "conv_x_w": (cfg.ssm_conv, di),
        "conv_x_b": (di,),
        "conv_b_w": (cfg.ssm_conv, n),
        "conv_b_b": (n,),
        "conv_c_w": (cfg.ssm_conv, n),
        "conv_c_b": (n,),
        "a_log": (h,),
        "d_skip": (h,),
        "dt_bias": (h,),
        "norm_scale": (di,),
        "w_out": (di, d),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv + silu over the sequence axis. x [B,S,C]."""
    w32 = w.astype(jnp.float32)
    width = w32.shape[0]
    x32 = x.astype(jnp.float32)
    padded = jnp.pad(x32, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(padded[:, i:i + x32.shape[1]] * w32[i] for i in range(width))
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _conv_step(window, w, b):
    """window [B,W,C] (already includes the new token last)."""
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out + b.astype(jnp.float32))


def mamba2_block(params, x, cfg):
    """Full-segment Mamba2. x [B,S,d] → (y [B,S,d], (ssm_state, conv_tail))."""
    bsz, s, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    z = matmul(x, params["w_z"])
    x_pre = matmul(x, params["w_x"])
    b_pre = matmul(x, params["w_b"])
    c_pre = matmul(x, params["w_c"])
    dt_raw = matmul(x, params["w_dt"])
    conv_tail = jnp.concatenate(
        [x_pre[:, -(cfg.ssm_conv - 1):], b_pre[:, -(cfg.ssm_conv - 1):],
         c_pre[:, -(cfg.ssm_conv - 1):]], axis=-1)
    xs = _causal_conv(x_pre, params["conv_x_w"], params["conv_x_b"])
    b = _causal_conv(b_pre, params["conv_b_w"], params["conv_b_b"])
    c = _causal_conv(c_pre, params["conv_c_w"], params["conv_c_b"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    y, ssm_state = ssd_chunked(xs.reshape(bsz, s, h, p), dt,
                               params["a_log"], b, c, params["d_skip"],
                               cfg.ssm_chunk)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm_scale"], cfg.norm_eps)
    return matmul(y, params["w_out"]), (ssm_state.astype(jnp.float32),
                                        conv_tail)


def mamba2_step(params, x, cfg, *, ssm_state, conv_state):
    """Single-token Mamba2. x [B,1,d]; conv_state [B,W-1,di+2n]."""
    bsz = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    z = matmul(x, params["w_z"])
    x_pre = matmul(x, params["w_x"])
    b_pre = matmul(x, params["w_b"])
    c_pre = matmul(x, params["w_c"])
    dt_raw = matmul(x, params["w_dt"])
    new_col = jnp.concatenate([x_pre, b_pre, c_pre], axis=-1)  # [B,1,di+2n]
    window = jnp.concatenate([conv_state, new_col], axis=1)    # [B,W,*]
    new_conv_state = window[:, 1:]
    xw, bw, cw = window[..., :di], window[..., di:di + n], window[..., di + n:]
    xs = _conv_step(xw, params["conv_x_w"], params["conv_x_b"]).astype(x.dtype)
    b = _conv_step(bw, params["conv_b_w"], params["conv_b_b"]).astype(x.dtype)
    c = _conv_step(cw, params["conv_c_w"], params["conv_c_b"]).astype(x.dtype)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    y, new_ssm = ssd_step(ssm_state, xs.reshape(bsz, h, p), dt,
                          params["a_log"], b, c, params["d_skip"])
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm_scale"], cfg.norm_eps)
    return matmul(y, params["w_out"]), (new_ssm, new_conv_state)
