"""W8A16 matmul Pallas TPU kernel: int8 weights × bf16/f32 activations.

§Perf pair A ended weight-read-bound (B=1 long-context decode reads every
parameter per token).  Int8 weights halve that HBM traffic; the dequant
(per-output-channel scale) happens in VMEM right before the MXU dot, so
HBM sees only int8.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost; the f32 accumulator
lives in VMEM scratch across the K steps.  bk×bn int8 weight tiles +
bm×bk activation tiles are MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, scale_ref, o_ref, acc_scr, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                 # [bm, bk]
    w = w_ref[...].astype(jnp.float32)                 # [bk, bn] (dequant ↓)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        scale = scale_ref[...].astype(jnp.float32)     # [1, bn]
        o_ref[...] = (acc_scr[...] * scale).astype(o_ref.dtype)


def int8_matmul_kernel(x, w_q, scale, *, bm=128, bn=128, bk=128,
                       interpret=True):
    """x [M,K] (bf16/f32) × w_q [K,N] int8 (+ scale [N]) → [M,N] x.dtype.

    Per-output-channel symmetric quantisation: w ≈ w_q * scale[None, :].
    M/K/N must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    _, n = w_q.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    kernel = functools.partial(_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scale.reshape(1, n))
