"""Oracle + quantiser for the W8A16 matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def quantize(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-output-channel symmetric int8. w [K,N] → (w_q int8, scale [N])."""
    w = np.asarray(w, np.float32)
    amax = np.abs(w).max(axis=0)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    w_q = np.clip(np.round(w / scale[None, :]), -127, 127).astype(np.int8)
    return w_q, scale


def int8_matmul_ref(x, w_q, scale):
    """x [M,K] × dequant(w_q, scale) — pure jnp."""
    w = w_q.astype(jnp.float32) * scale[None, :].astype(jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), w).astype(x.dtype)


def quant_error_bound(w: np.ndarray) -> float:
    """Max relative dequant error (≤ 1/254 per channel by construction)."""
    w_q, scale = quantize(w)
    deq = w_q.astype(np.float32) * scale[None, :]
    denom = np.maximum(np.abs(w).max(axis=0), 1e-9)
    return float((np.abs(deq - w) / denom[None, :]).max())
