"""Jit'd wrapper for the W8A16 matmul kernel (pads to MXU tiles)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_kernel


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(x, w_q, scale, *, bm=128, bn=128, bk=128, interpret=None):
    """x [..., K] × w_q [K, N] int8 → [..., N]."""
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w_q.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x2 = jnp.pad(x2, ((0, pm), (0, pk)))
    wq = jnp.pad(w_q, ((0, pk), (0, pn))) if (pk or pn) else w_q
    sc = jnp.pad(scale, ((0, pn),)) if pn else scale
    out = int8_matmul_kernel(x2, wq, sc, bm=bm_, bn=bn_, bk=bk_,
                             interpret=interp)
    return out[:m, :n].reshape(*lead, n)
