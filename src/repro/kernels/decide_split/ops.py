"""jit'd decision core: the ``[n_envs, L+1]`` offloading sweep on-accelerator.

:func:`decide_accel` is the accelerator twin of
:func:`repro.core.decisions.decide_all`.  ``backend="jax"`` runs the
latency prefix sums → transfer matrix → scalarise → argmin pipeline as
jitted XLA next to the model, bit-for-bit equal (f64) to the numpy
reference; ``backend="pallas"`` calls the fused TPU kernel
(:mod:`repro.kernels.decide_split.kernel`), which never materialises the
cost tensor in HBM and matches within f32 tolerance.

Cost models lower through :func:`repro.core.costs.lower_to_accel`:
``AnalyticCost`` and ``CompositeCost`` are pure array math over
``EnvArrays``; ``PredictorCost`` lowers by compiling its fitted
regressor to array form (``repro.oracle.lowered`` — the ``AccelSpec``
carries a ``lowered`` layer-times program whose per-layer device/edge
time vectors replace the analytic roofline reconstruction).  Only
regressors outside the lowerable families (ridge / MLP / GBT) are
rejected with a ``TypeError``.

Bit-for-bit notes (why this file looks the way it does):

  * XLA lowers ``cumsum`` to a parallel prefix whose rounding differs
    from numpy's sequential accumulate, so the prefix sums here run as a
    sequential ``lax.scan`` — the exact float ordering of ``np.cumsum``.
  * Inside one jit XLA contracts multiply-add chains into FMAs, which
    perturbs the last ulp of the energy/price objectives and the weighted
    scalarisation.  The multi-objective assembly therefore runs as
    *eager* jnp ops — still device-resident, but one primitive per
    dispatch, which XLA cannot contract.  The latency-only pipelines
    (analytic and predictor-driven) have no mul→add chain and stay
    fully jitted; lowered tree-model inference is add-only (leaf values
    pre-scaled on the host), so it too stays bit-for-bit under jit.
  * Everything executes in f64 under ``jax.experimental.enable_x64`` so
    host and accelerator decisions are interchangeable; the Pallas path
    runs the kernel in f32 (the TPU-native width) and re-evaluates the
    chosen splits in f64 on the host — O(E) gathers, no matrices.
"""
# repro: module-tags=fma-sensitive
# (DET001: a @ / dot / matmul here would let XLA FMA-contract and break
#  the f64 bitwise equality with the numpy host path described above)
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.costs import (ACCEL_OBJECTIVES, AccelSpec, lower_to_accel,
                              scalarize_weighted)
from repro.core.decisions import DecisionPlan, EnvArrays
from repro.core.offload import DEFAULT_EFFICIENCY, LayerCost

def _layer_arrays(layers: Sequence[LayerCost]):
    n = len(layers)
    flops = np.fromiter((lc.flops for lc in layers), np.float64, count=n)
    act = np.fromiter((lc.act_bytes for lc in layers), np.float64, count=n)
    return flops, act


def _env_arrays(envs: EnvArrays):
    e = len(envs)

    def tdp(x):
        return np.zeros(e) if x is None else np.asarray(x, np.float64)

    return tuple(np.asarray(x, np.float64) for x in
                 (envs.dev_flops, envs.edge_flops, envs.link_bw,
                  envs.link_latency_s, envs.input_bytes)) \
        + (tdp(envs.dev_tdp_watts), tdp(envs.edge_tdp_watts))


def _seq_cumsum(x):
    """Row-wise cumsum via sequential scan: numpy's exact float ordering
    (XLA's native cumsum is a parallel prefix with different rounding)."""
    def step(carry, col):
        carry = carry + col
        return carry, carry

    _, out = jax.lax.scan(step, jnp.zeros(x.shape[:1], x.dtype), x.T)
    return out.T


@jax.jit
def _latency_parts(flops, act, dev, edge, bw, lat, inp, eff):
    """jnp twin of ``decisions.latency_components`` + ``transfer_bytes``:
    ``(dev_cum, xfer, edge_cum, shipped_bytes)``, each ``[E, L+1]``."""
    e, n = dev.shape[0], flops.shape[0]
    t_dev = flops[None, :] / (dev[:, None] * eff)
    t_edge = flops[None, :] / (edge[:, None] * eff)
    zero = jnp.zeros((e, 1), t_dev.dtype)
    dev_cum = jnp.concatenate([zero, _seq_cumsum(t_dev)], axis=1)
    edge_cum = jnp.concatenate(
        [_seq_cumsum(t_edge[:, ::-1])[:, ::-1], zero], axis=1)
    tb = jnp.concatenate(
        [inp[:, None], jnp.broadcast_to(act[None, :], (e, n))], axis=1)
    tb = tb.at[:, -1].set(0.0)                  # split == L ships nothing
    xfer = lat[:, None] + tb / jnp.maximum(bw, 1.0)[:, None]
    xfer = xfer.at[:, -1].set(0.0)
    return dev_cum, xfer, edge_cum, tb


@jax.jit
def _predictor_parts(t_dev, t_edge, act, bw, lat, inp):
    """Predictor twin of :func:`_latency_parts`: the per-layer time
    vectors are environment-invariant (one device/edge pair per
    ``PredictorCost``), so both cumulative rows are computed once and
    broadcast — the exact float ordering of the host
    ``PredictorCost.latency_parts``."""
    e, n = bw.shape[0], t_dev.shape[0]
    zero1 = jnp.zeros((1, 1), t_dev.dtype)
    dcum = jnp.concatenate([zero1, _seq_cumsum(t_dev[None, :])], axis=1)[0]
    ecum = jnp.concatenate(
        [_seq_cumsum(t_edge[None, ::-1])[:, ::-1], zero1], axis=1)[0]
    tb = jnp.concatenate(
        [inp[:, None], jnp.broadcast_to(act[None, :], (e, n))], axis=1)
    tb = tb.at[:, -1].set(0.0)
    xfer = lat[:, None] + tb / jnp.maximum(bw, 1.0)[:, None]
    xfer = xfer.at[:, -1].set(0.0)
    shape = (e, n + 1)
    return (jnp.broadcast_to(dcum, shape), xfer,
            jnp.broadcast_to(ecum, shape), tb)


@jax.jit
def _decide_latency(flops, act, dev, edge, bw, lat, inp, eff):
    """Latency-only decide: fully fused, bit-for-bit vs the numpy path."""
    dev_cum, xfer, edge_cum, _ = _latency_parts(flops, act, dev, edge, bw,
                                                lat, inp, eff)
    total = dev_cum + xfer + edge_cum
    s = jnp.argmin(total, axis=1)
    rows = jnp.arange(dev.shape[0])
    return s, total[rows, s], dev_cum[rows, s], xfer[rows, s], \
        edge_cum[rows, s]


@jax.jit
def _decide_predictor(t_dev, t_edge, act, bw, lat, inp):
    """Latency-only predictor decide: fully fused (the broadcast +
    transfer + argmin pipeline is add/divide only — no FMA chain)."""
    dev_cum, xfer, edge_cum, _ = _predictor_parts(t_dev, t_edge, act, bw,
                                                  lat, inp)
    total = dev_cum + xfer + edge_cum
    s = jnp.argmin(total, axis=1)
    rows = jnp.arange(bw.shape[0])
    return s, total[rows, s], dev_cum[rows, s], xfer[rows, s], \
        edge_cum[rows, s]


def _composite_decide(parts, tb, dev_w, edge_w, spec: AccelSpec):
    """Multi-objective decide over jitted parts.  Eager on purpose — see
    the module docstring's FMA note; mirrors ``CompositeCost.components``
    + ``scalarize_weighted`` op-for-op."""
    dev_cum, xfer, edge_cum = parts
    total = dev_cum + xfer + edge_cum
    energy = dev_cum * dev_w[:, None] + xfer * spec.radio_watts \
        + edge_cum * edge_w[:, None]
    price = edge_cum * spec.price_per_edge_s + tb / 1e9 * spec.price_per_gb
    slack = jnp.maximum(total - spec.deadline_s, 0.0)
    comp = jnp.stack([total, energy, price, slack], axis=-1)
    w = spec.weights
    scal = comp[..., 0] * w[0]
    for k in range(1, 4):
        scal = scal + comp[..., k] * w[k]
    s = jnp.argmin(scal, axis=1)
    rows = jnp.arange(dev_cum.shape[0])
    return s, comp[rows, s], scal[rows, s], dev_cum[rows, s], \
        xfer[rows, s], edge_cum[rows, s]


def _queue_decide(parts, tb, dev_w, edge_w, spec: AccelSpec):
    """Queue-/tail-aware decide over jitted parts.  Eager like
    :func:`_composite_decide`; mirrors the host ``QueueAwareCost``
    (edge-pool wait bumps the latency objective on offloading splits)
    and ``CompositeCost(tail=...)`` (fifth ``tail_latency_s`` column =
    total + tail-RTT excess on offloading splits) op-for-op."""
    dev_cum, xfer, edge_cum = parts
    total = dev_cum + xfer + edge_cum
    n_obj = len(spec.objectives)
    wait = spec.queue_wait_s
    rows = jnp.arange(dev_cum.shape[0])
    if n_obj == 1:                       # latency-only base + queue wait
        lat_col = jnp.concatenate(
            [total[:, :-1] + wait, total[:, -1:]], axis=1)
        s = jnp.argmin(lat_col, axis=1)
        xfer_q = jnp.concatenate(
            [xfer[:, :-1] + wait, xfer[:, -1:]], axis=1)
        scal_s = lat_col[rows, s]
        return s, scal_s[:, None], scal_s, dev_cum[rows, s], \
            xfer_q[rows, s], edge_cum[rows, s]
    energy = dev_cum * dev_w[:, None] + xfer * spec.radio_watts \
        + edge_cum * edge_w[:, None]
    price = edge_cum * spec.price_per_edge_s + tb / 1e9 * spec.price_per_gb
    slack = jnp.maximum(total - spec.deadline_s, 0.0)
    cols = [total, energy, price, slack]
    weights = list(spec.weights)
    if n_obj == 5:                       # tail_latency_s objective
        cols.append(jnp.concatenate(
            [total[:, :-1] + spec.tail_excess_s, total[:, -1:]], axis=1))
        weights.append(spec.tail_weight)
    if wait != 0.0:
        cols[0] = jnp.concatenate(
            [total[:, :-1] + wait, total[:, -1:]], axis=1)
        xfer = jnp.concatenate(
            [xfer[:, :-1] + wait, xfer[:, -1:]], axis=1)
    comp = jnp.stack(cols, axis=-1)
    scal = comp[..., 0] * weights[0]
    for k in range(1, n_obj):
        scal = scal + comp[..., k] * weights[k]
    s = jnp.argmin(scal, axis=1)
    return s, comp[rows, s], scal[rows, s], dev_cum[rows, s], \
        xfer[rows, s], edge_cum[rows, s]


def _plan(cost, spec: AccelSpec, s, dev_s, xfer_s, edge_s, total_s,
          comp_s=None, scal_s=None) -> DecisionPlan:
    """Assemble the DecisionPlan mirroring the numpy ``decide_all``
    surface for the same ``cost`` argument."""
    s = np.asarray(s)
    dev_s, xfer_s, edge_s, total_s = (np.asarray(x, np.float64)
                                      for x in (dev_s, xfer_s, edge_s,
                                                total_s))
    if cost is None:
        return DecisionPlan(s, total_s, dev_s, xfer_s, edge_s)
    if comp_s is None:                          # latency-only cost model
        comp_s, scal_s = total_s[:, None], total_s
    else:
        comp_s, scal_s = np.asarray(comp_s, np.float64), \
            np.asarray(scal_s, np.float64)
    if "latency_s" in spec.objectives:
        total = comp_s[:, spec.objectives.index("latency_s")]
    else:                                       # scalar cost is not seconds
        total = np.full(len(s), np.nan)
    return DecisionPlan(s, total, dev_s, xfer_s, edge_s,
                        objectives=spec.objectives, components=comp_s,
                        scalar_cost=scal_s)


def _decide_jax(layers, flops, act, env_arrs, spec: AccelSpec, cost):
    dev, edge, bw, lat, inp, dev_w, edge_w = env_arrs
    # queue-wait / tail objectives take the eager extended path; when
    # both are off the historical branches run untouched (bit-for-bit)
    queued = (spec.queue_wait_s != 0.0 or len(spec.objectives) > 4)
    with enable_x64():
        if spec.lowered is not None:
            t_dev, t_edge = spec.lowered.times(layers)
            pargs = tuple(jnp.asarray(x) for x in
                          (t_dev, t_edge, act, bw, lat, inp))
            if spec.objectives == ("latency_s",) and not queued:
                s, total_s, dev_s, xfer_s, edge_s = _decide_predictor(
                    *pargs)
                return _plan(cost, spec, s, dev_s, xfer_s, edge_s, total_s)
            dev_cum, xfer, edge_cum, tb = _predictor_parts(*pargs)
        else:
            args = tuple(jnp.asarray(x) for x in
                         (flops, act, dev, edge, bw, lat, inp))
            if spec.objectives == ("latency_s",) and not queued:
                s, total_s, dev_s, xfer_s, edge_s = _decide_latency(
                    *args, spec.efficiency)
                return _plan(cost, spec, s, dev_s, xfer_s, edge_s, total_s)
            dev_cum, xfer, edge_cum, tb = _latency_parts(*args,
                                                         spec.efficiency)
        decide = _queue_decide if queued else _composite_decide
        s, comp_s, scal_s, dev_s, xfer_s, edge_s = decide(
            (dev_cum, xfer, edge_cum), tb, jnp.asarray(dev_w),
            jnp.asarray(edge_w), spec)
        total_s = np.asarray(comp_s)[:, 0]
        return _plan(cost, spec, s, dev_s, xfer_s, edge_s, total_s,
                     comp_s, scal_s)


def _decide_pallas(layers, flops, act, env_arrs, spec: AccelSpec, cost,
                   interpret: Optional[bool], block_e: int, block_s: int):
    from repro.kernels.decide_split.kernel import (decide_split_kernel,
                                                   pack_spec)
    dev, edge, bw, lat, inp, dev_w, edge_w = env_arrs
    n = flops.shape[0]
    bvec = np.concatenate(([0.0], act))
    bvec[-1] = 0.0                                       # split == L
    if spec.lowered is not None:
        # predictor mode: prefix sums of the lowered per-layer times,
        # unit divisors (the rows already are seconds)
        t_dev, t_edge = spec.lowered.times(layers)
        dcum = np.concatenate(([0.0], np.cumsum(t_dev)))
        ecum = np.concatenate(([0.0], np.cumsum(t_edge)))
        dev_div = np.ones_like(dev)
        edge_div = np.ones_like(edge)
    else:
        fcum = np.concatenate(([0.0], np.cumsum(flops)))  # [L+1] f64
        dcum = ecum = fcum
        dev_div = dev * spec.efficiency
        edge_div = edge * spec.efficiency
    etot = float(ecum[-1])
    spec_vec = pack_spec(spec.weights,
                         radio_watts=spec.radio_watts,
                         price_per_edge_s=spec.price_per_edge_s,
                         price_per_gb=spec.price_per_gb,
                         deadline_s=spec.deadline_s, edge_total=etot,
                         queue_wait_s=spec.queue_wait_s,
                         tail_excess_s=spec.tail_excess_s,
                         tail_weight=spec.tail_weight)
    f32 = [jnp.asarray(x, jnp.float32)
           for x in (dcum, ecum, bvec, dev_div, edge_div, bw, lat, inp,
                     dev_w, edge_w)]
    s, _ = decide_split_kernel(*f32, jnp.asarray(spec_vec),
                               block_e=block_e, block_s=block_s,
                               interpret=interpret)
    s = np.asarray(s, np.int64)
    # exact f64 costs at the kernel-chosen splits: O(E) gathers, no [E, S]
    dev_s = dcum[s] / dev_div
    edge_s = (etot - ecum[s]) / edge_div
    ship = np.where(s == n, 0.0, np.where(s == 0, inp, bvec[s]))
    xfer_s = np.where(s == n, 0.0, lat + ship / np.maximum(bw, 1.0))
    total_s = dev_s + xfer_s + edge_s
    # queue wait bumps the latency objective (and the booked transfer)
    # on offloading splits — zero when no pool is attached
    bump = np.where(s == n, 0.0, spec.queue_wait_s) \
        if spec.queue_wait_s != 0.0 else None
    if cost is None or spec.objectives == ("latency_s",):
        if bump is not None:
            total_s = total_s + bump
            xfer_s = xfer_s + bump
            return _plan(cost, spec, s, dev_s, xfer_s, edge_s, total_s,
                         total_s[:, None], total_s)
        return _plan(cost, spec, s, dev_s, xfer_s, edge_s, total_s)
    energy = dev_s * dev_w + xfer_s * spec.radio_watts + edge_s * edge_w
    price = edge_s * spec.price_per_edge_s + ship / 1e9 * spec.price_per_gb
    slack = np.maximum(total_s - spec.deadline_s, 0.0)
    cols = [total_s, energy, price, slack]
    weights = list(spec.weights)
    if len(spec.objectives) > 4:         # tail_latency_s objective
        cols.append(total_s + np.where(s == n, 0.0, spec.tail_excess_s))
        weights.append(spec.tail_weight)
    if bump is not None:
        cols[0] = total_s + bump
        xfer_s = xfer_s + bump
        total_s = cols[0]
    comp_s = np.stack(cols, axis=-1)
    scal_s = scalarize_weighted(comp_s, spec.objectives,
                                dict(zip(spec.objectives, weights)))
    return _plan(cost, spec, s, dev_s, xfer_s, edge_s, total_s,
                 comp_s, scal_s)


def decide_accel(layers: Sequence[LayerCost], envs: EnvArrays,
                 efficiency: float = DEFAULT_EFFICIENCY, *,
                 cost=None, backend: str = "jax",
                 interpret: Optional[bool] = None,
                 block_e: int = 256, block_s: int = 128) -> DecisionPlan:
    """Accelerator ``decide_all``: one fused cost+argmin over ``[E, L+1]``.

    ``backend="jax"`` is jitted XLA, bit-for-bit (f64) with the numpy
    path; ``backend="pallas"`` is the fused TPU kernel, within f32
    tolerance (``interpret``/``block_e``/``block_s`` tune it; interpret
    defaults to True off-TPU).  Predictor-driven costs run their lowered
    regressor on-device (``AccelSpec.lowered``); raises ``TypeError``
    only for cost models with no array lowering — see
    :func:`repro.core.costs.lower_to_accel`.
    """
    if backend not in ("jax", "pallas"):
        raise ValueError(
            f"unknown accelerator backend {backend!r}; expected 'jax' or "
            "'pallas' (the host path is decisions.decide_all with "
            "backend='numpy')")
    spec = lower_to_accel(cost, efficiency)
    flops, act = _layer_arrays(layers)
    env_arrs = _env_arrays(envs)
    if backend == "pallas":
        if len(envs) == 0:                      # nothing to grid over
            empty = np.zeros(0)
            return _plan(cost, spec, np.zeros(0, np.int64), empty, empty,
                         empty, empty,
                         None if spec.objectives == ("latency_s",)
                         else np.zeros((0, len(spec.objectives))),
                         empty)
        return _decide_pallas(layers, flops, act, env_arrs, spec, cost,
                              interpret, block_e, block_s)
    return _decide_jax(layers, flops, act, env_arrs, spec, cost)


def latency_matrix_jax(layers: Sequence[LayerCost], envs: EnvArrays,
                       efficiency: float = DEFAULT_EFFICIENCY) -> np.ndarray:
    """jit-computed ``[E, L+1]`` latency matrix, bit-for-bit (f64) with
    ``decisions.latency_matrix`` — the equivalence-test surface."""
    flops, act = _layer_arrays(layers)
    dev, edge, bw, lat, inp, _, _ = _env_arrays(envs)
    with enable_x64():
        dev_cum, xfer, edge_cum, _ = _latency_parts(
            *(jnp.asarray(x) for x in (flops, act, dev, edge, bw, lat,
                                       inp)), efficiency)
        return np.asarray(dev_cum + xfer + edge_cum)
