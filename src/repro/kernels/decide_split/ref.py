"""Numpy reference for the decision kernels: the host decision core.

The oracle the jit/Pallas backends are pinned against is simply the
existing vectorized host path — ``latency_matrix`` + row argmin for the
analytic default, and the ``CostModel`` component/scalarise pipeline for
multi-objective decisions.  Kept as a thin delegation (not a copy) so the
equivalence tests in ``tests/test_decide_split.py`` always compare the
accelerated paths against the *live* host implementation.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import decisions as dec
from repro.core.offload import DEFAULT_EFFICIENCY, LayerCost


def latency_matrix_ref(layers: Sequence[LayerCost], envs: dec.EnvArrays,
                       efficiency: float = DEFAULT_EFFICIENCY) -> np.ndarray:
    """``[E, L+1]`` total-latency matrix, host numpy."""
    return dec.latency_matrix(layers, envs, efficiency)


def decide_ref(layers: Sequence[LayerCost], envs: dec.EnvArrays,
               efficiency: float = DEFAULT_EFFICIENCY, *,
               cost=None) -> dec.DecisionPlan:
    """Host ``decide_all`` — the semantics the accelerated backends must
    reproduce (bit-for-bit for jax/f64, within tolerance for Pallas)."""
    return dec.decide_all(layers, envs, efficiency, cost=cost,
                          backend="numpy")
