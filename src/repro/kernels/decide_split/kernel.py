"""Fused offloading-decision Pallas TPU kernel: cost + row-argmin.

One ``[block_e, block_s]`` tile of the ``[n_envs, L+1]`` decision sweep
per grid step — the cost of every (environment, split) pair is computed
on the fly from the factored form and immediately reduced into a running
per-environment ``(min, argmin)`` carried in VMEM scratch, so the full
cost tensor is never materialised in HBM (the numpy/jit paths build all
``[E, L+1]`` matrices; at fleet scale that is the HBM bottleneck).

The factorisation that makes the fusion cheap: the forward/backward
prefix sums over per-layer times are environment-invariant up to one
divide.  The caller ships two ``[L+1]`` prefix rows and two ``[E]``
divisor columns and the kernel reconstructs both cumulative sums per
tile::

    dev_cum[e, s]  = dcum[s] / dev_div[e]
    edge_cum[e, s] = (etot − ecum[s]) / edge_div[e]
    xfer[e, s]     = 0 at s == L, else lat[e] + ship[e, s] / max(bw[e], 1)
    ship[e, s]     = input_bytes[e] at s == 0, else act_bytes[s − 1]

For the analytic roofline model ``dcum = ecum = F`` (the FLOPs prefix)
and ``dev_div[e] = dev_flops[e] · eff``; for a lowered profiling
predictor (``repro.oracle.lowered``) ``dcum``/``ecum`` are the prefix
sums of the *predicted* per-layer times and the divisors are 1 — one
kernel serves both families.

On top of latency the tile evaluates the full CompositeCost objective
stack (energy from TDP, price, deadline slack) and the weighted
scalarisation — latency-only decisions are the ``weights = (1, 0, 0, 0)``
special case, so one kernel serves every cost model that lowers.

VMEM per step: three [1, block_s] layer rows + nine [block_e, 1] env
columns + the [block_e, block_s] tile intermediates + [block_e, 1]
scratch ≈ 0.6 MB at (256, 128) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# spec vector layout (SMEM): scalar parameters of the lowered cost model
SPEC_RADIO, SPEC_PPS, SPEC_PPG, SPEC_DEADLINE = range(4)
SPEC_W0, SPEC_W1, SPEC_W2, SPEC_W3, SPEC_ETOT = range(4, 9)
# queue-aware extension: predicted edge-pool wait added to every
# offloading split's latency, and the tail_latency_s objective
# (latency + tail-RTT excess on offloading splits) weighted by W4.
# All three default to 0.0, which reproduces the 9-slot kernel math.
SPEC_WAIT, SPEC_TEXC, SPEC_W4 = range(9, 12)
SPEC_LEN = 12


def _kernel(spec_ref, dcum_ref, ecum_ref, bvec_ref, dev_div_ref,
            edge_div_ref, bw_ref, lat_ref, inp_ref, dev_w_ref, edge_w_ref,
            split_ref, cost_ref, best_scr, idx_scr,
            *, block_s: int, n_split_blocks: int, n_splits: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        best_scr[...] = jnp.full_like(best_scr, jnp.inf)
        idx_scr[...] = jnp.zeros_like(idx_scr)

    etot = spec_ref[SPEC_ETOT]
    be = best_scr.shape[0]
    cols = ib * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (be, block_s), 1)                     # [BE, BS]

    dc = dcum_ref[...]                                   # [1, BS]
    ec = ecum_ref[...]
    b = bvec_ref[...]

    dev_t = dc / dev_div_ref[...]                        # [BE, BS]
    edge_t = (etot - ec) / edge_div_ref[...]
    is_last = cols == n_splits - 1                       # split == L
    ship = jnp.where(is_last, 0.0,
                     jnp.where(cols == 0, inp_ref[...], b))
    xfer = jnp.where(is_last, 0.0,
                     lat_ref[...] + ship / jnp.maximum(bw_ref[...], 1.0))

    total = dev_t + xfer + edge_t
    energy = dev_t * dev_w_ref[...] + xfer * spec_ref[SPEC_RADIO] \
        + edge_t * edge_w_ref[...]
    price = edge_t * spec_ref[SPEC_PPS] \
        + ship / 1e9 * spec_ref[SPEC_PPG]
    slack = jnp.maximum(total - spec_ref[SPEC_DEADLINE], 0.0)
    # queue-aware latency: offloading splits pay the edge-pool wait in
    # the latency objective only (energy/price/slack come from the base
    # model, exactly as QueueAwareCost bumps column 0 on the host);
    # SPEC_WAIT == 0.0 adds literal zero — bit-identical historical math
    lat_col = total + jnp.where(is_last, 0.0, spec_ref[SPEC_WAIT])
    scal = spec_ref[SPEC_W0] * lat_col + spec_ref[SPEC_W1] * energy \
        + spec_ref[SPEC_W2] * price + spec_ref[SPEC_W3] * slack
    # tail_latency_s objective: total + tail-RTT excess where offloading
    scal = scal + spec_ref[SPEC_W4] * (
        total + jnp.where(is_last, 0.0, spec_ref[SPEC_TEXC]))
    scal = jnp.where(cols < n_splits, scal, jnp.inf)     # mask split padding

    local_min = jnp.min(scal, axis=1)[:, None]           # [BE, 1]
    local_idx = jnp.argmin(scal, axis=1)[:, None].astype(jnp.int32)
    # strict < keeps the earlier block on ties — np.argmin semantics
    better = local_min < best_scr[...]
    best_scr[...] = jnp.where(better, local_min, best_scr[...])
    idx_scr[...] = jnp.where(better, ib * block_s + local_idx, idx_scr[...])

    @pl.when(ib == n_split_blocks - 1)
    def _emit():
        split_ref[...] = idx_scr[...]
        cost_ref[...] = best_scr[...]


def decide_split_kernel(dcum, ecum, bvec, dev_div, edge_div, bw, lat, inp,
                        dev_w, edge_w, spec, *, block_e: int = 8,
                        block_s: int = 128,
                        interpret: bool | None = None):
    """``dcum``/``ecum``/``bvec`` [L+1] f32 split rows; env arrays [E]
    f32; ``spec`` [SPEC_LEN] f32.  Returns ``(split [E] int32, scalar
    cost [E] f32)``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_envs, n_splits = dev_div.shape[0], dcum.shape[0]
    block_e = min(block_e, max(n_envs, 1))
    block_s = min(block_s, n_splits)
    pad_e = (-n_envs) % block_e
    pad_s = (-n_splits) % block_s
    # padded env rows divide by 1.0 and are sliced off below
    dev_div, edge_div, bw = (jnp.pad(x, (0, pad_e),
                                     constant_values=1.0)[:, None]
                             for x in (dev_div, edge_div, bw))
    lat, inp, dev_w, edge_w = (jnp.pad(x, (0, pad_e))[:, None]
                               for x in (lat, inp, dev_w, edge_w))
    dcum, ecum, bvec = (jnp.pad(x, (0, pad_s))[None, :]
                        for x in (dcum, ecum, bvec))
    ep, sp = n_envs + pad_e, n_splits + pad_s
    n_split_blocks = sp // block_s

    env_spec = pl.BlockSpec((block_e, 1), lambda ie, ib: (ie, 0))
    row_spec = pl.BlockSpec((1, block_s), lambda ie, ib: (0, ib))
    kernel = functools.partial(_kernel, block_s=block_s,
                               n_split_blocks=n_split_blocks,
                               n_splits=n_splits)
    split, cost = pl.pallas_call(
        kernel,
        grid=(ep // block_e, n_split_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # spec scalars
            row_spec, row_spec, row_spec,                # dcum, ecum, bvec
            env_spec, env_spec, env_spec, env_spec,      # divs, bw, lat
            env_spec, env_spec, env_spec,                # inp, dev_w, edge_w
        ],
        out_specs=[env_spec, env_spec],
        out_shape=[
            jax.ShapeDtypeStruct((ep, 1), jnp.int32),
            jax.ShapeDtypeStruct((ep, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_e, 1), jnp.float32),
                        pltpu.VMEM((block_e, 1), jnp.int32)],
        interpret=interpret,
    )(spec, dcum, ecum, bvec, dev_div, edge_div, bw, lat, inp, dev_w,
      edge_w)
    return split[:n_envs, 0], cost[:n_envs, 0]


def pack_spec(weights, radio_watts=0.0, price_per_edge_s=0.0,
              price_per_gb=0.0, deadline_s=np.inf, edge_total=0.0,
              queue_wait_s=0.0, tail_excess_s=0.0, tail_weight=0.0):
    """Build the [SPEC_LEN] f32 scalar vector the kernel reads from SMEM
    (``edge_total`` is ``ecum[-1]``, the full edge-side prefix)."""
    out = np.zeros(SPEC_LEN, np.float32)
    out[SPEC_RADIO] = radio_watts
    out[SPEC_PPS] = price_per_edge_s
    out[SPEC_PPG] = price_per_gb
    out[SPEC_DEADLINE] = deadline_s
    out[SPEC_W0:SPEC_W0 + 4] = weights
    out[SPEC_ETOT] = edge_total
    out[SPEC_WAIT] = queue_wait_s
    out[SPEC_TEXC] = tail_excess_s
    out[SPEC_W4] = tail_weight
    return out
