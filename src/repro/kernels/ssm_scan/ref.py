"""Sequential-recurrence oracle for the SSD scan kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ssd_scan_ref(xdt, loga, b, c, *, n_heads_per_batch: int):
    """Step-by-step recurrence, numpy. Shapes as in ssd_scan_kernel."""
    xdt = np.asarray(xdt, np.float64)
    loga = np.asarray(loga, np.float64)
    b = np.asarray(b, np.float64)
    c = np.asarray(c, np.float64)
    bh, nc, q, p = xdt.shape
    n = b.shape[-1]
    h = n_heads_per_batch
    y = np.zeros((bh, nc, q, p))
    state = np.zeros((bh, p, n))
    for i in range(bh):
        bi = i // h
        st = np.zeros((p, n))
        for ic in range(nc):
            for t in range(q):
                a = np.exp(loga[i, ic, t, 0])
                st = st * a + np.outer(xdt[i, ic, t], b[bi, ic, t])
                y[i, ic, t] = st @ c[bi, ic, t]
        state[i] = st
    return y.astype(np.float32), state.astype(np.float32)
