"""Chunked SSD (Mamba2) scan Pallas TPU kernel.

One (batch·head) row of the SSD recurrence per grid row; the chunk
dimension is innermost and sequential, carrying the [P, N] state in VMEM
scratch.  Within a chunk everything is dense matmul on the MXU:

    y_intra = (C Bᵀ ∘ decay-mask) (dt·x)        [Q,Q] @ [Q,P]
    y_inter = exp(csum) · (C Hᵀ)                [Q,N] @ [N,P]
    H'      = exp(total)·H + (dt·x)ᵀ (B ∘ decay-out)

This is the TPU-native form of the paper-adjacent SSD kernel: block-dense
tiles instead of the CUDA selective-scan (DESIGN.md §2).

VMEM per step: x (Q×P) + B,C (Q×N) + tiles (Q×Q) + state (P×N)
≈ 0.4 MB at Q=128, P=64, N=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, loga_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_scr, *, q: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, 0].astype(jnp.float32)          # [Q, P]
    loga = loga_ref[0, 0].astype(jnp.float32)        # [Q, 1]
    bb = b_ref[0, 0].astype(jnp.float32)             # [Q, N]
    cc = c_ref[0, 0].astype(jnp.float32)             # [Q, N]

    csum = jnp.cumsum(loga, axis=0)                  # [Q,1] inclusive
    total = csum[q - 1]                              # [1]
    state = state_scr[...]                           # [P, N]

    # inter-chunk: carried state contribution
    y_inter = jax.lax.dot_general(cc, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(csum)                # [Q, P]

    # intra-chunk dense causal tile
    rel = csum - csum.reshape(1, q)                  # [Q, Q]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    gate = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(scores * gate, xdt,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update
    decay_out = jnp.exp(total[None, :] - csum)       # [Q,1]
    bw = bb * decay_out                              # [Q,N]
    upd = jax.lax.dot_general(xdt, bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P,N]
    state_scr[...] = state * jnp.exp(total[0]) + upd

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_out_ref[0] = state_scr[...].astype(state_out_ref.dtype)


def ssd_scan_kernel(xdt, loga, b, c, *, n_heads_per_batch: int,
                    interpret: bool = True):
    """xdt [BH, nc, Q, P]; loga [BH, nc, Q, 1]; b/c [B, nc, Q, N]
    (heads share B/C — the index map fans them out).

    Returns (y [BH, nc, Q, P] f32, state [BH, P, N] f32).
    """
    bh, nc, q, p = xdt.shape
    n = b.shape[-1]
    h = n_heads_per_batch
    kernel = functools.partial(_kernel, q=q, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, ic: (i, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda i, ic: (i, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, ic: (i // h, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, ic: (i // h, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, ic: (i, ic, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i, ic: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, loga, b, c)
