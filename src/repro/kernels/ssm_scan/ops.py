"""Jit'd wrapper: model-layout SSD scan via the Pallas kernel.

Mirrors :func:`repro.models.mamba2.ssd_chunked` (same inputs/outputs) so
the model can swap implementations on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(x, dt, a_log, b, c, d_skip, chunk: int = 128,
                       interpret: bool | None = None):
    """x [B,S,H,P]; dt [B,S,H]; b/c [B,S,N]; returns (y, state [B,H,P,N])."""
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q

    log_a = (-jnp.exp(a_log.astype(jnp.float32))[None, None, :]
             * dt.astype(jnp.float32))                       # [B,S',H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # [B,S',H,P] -> [B,H,nc,Q,P] -> [BH,nc,Q,P]
    xdt_k = xdt.transpose(0, 2, 1, 3).reshape(bsz * h, nc, q, p)
    loga_k = log_a.transpose(0, 2, 1).reshape(bsz * h, nc, q, 1)
    b_k = b.astype(jnp.float32).reshape(bsz, nc, q, n)
    c_k = c.astype(jnp.float32).reshape(bsz, nc, q, n)

    y_k, state_k = ssd_scan_kernel(xdt_k, loga_k, b_k, c_k,
                                   n_heads_per_batch=h, interpret=interp)
    y = y_k.reshape(bsz, h, nc * q, p).transpose(0, 2, 1, 3)[:, :s]
    y = y + (d_skip.astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)[:, :s])
    return y, state_k.reshape(bsz, h, p, n)
