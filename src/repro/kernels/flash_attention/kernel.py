"""Flash-attention Pallas TPU kernel.

Tiling: grid = (B, Hq, nq, nk) with the KV dimension innermost — TPU grids
execute sequentially minor-to-major, so the online-softmax state
(m, l, acc) lives in VMEM scratch and persists across the nk steps of one
(b, h, iq) row block.  Block shapes are MXU-aligned (q/kv blocks default
128×head_dim); GQA is handled in the index map (kv head = h // group).

VMEM working set per step:
  q block (qblk×D) + k,v blocks (kblk×D each) + scores (qblk×kblk f32)
  + acc (qblk×D f32)  ≈ 0.5 MB at 128×128 — far under the 128 MB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, valid_len: int,
            qblk: int, kblk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    row = iq * qblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 0)
    col = ik * kblk + jax.lax.broadcasted_iota(jnp.int32, (qblk, kblk), 1)
    ok = col < valid_len
    if causal:
        ok &= col <= row
    if window:
        ok &= (row - col) < window

    # block is entirely masked when its first column exceeds the last row
    live = jnp.logical_not(causal) | (ik * kblk <= iq * qblk + qblk - 1)
    if window:
        live &= (iq * qblk >= ik * kblk) | (
            (iq + 1) * qblk - 1 - ik * kblk < window + qblk)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [qblk, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [kblk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        o = acc_scr[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=0, scale=None,
                           qblk=128, kblk=128, valid_len=0,
                           interpret=True):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Sk,D] (seq already block-padded).

    Returns [B,Hq,Sq,D] in q.dtype.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    qblk = min(qblk, sq)
    kblk = min(kblk, sk)
    assert sq % qblk == 0 and sk % kblk == 0
    nq, nk = sq // qblk, sk // kblk
    scale = scale if scale is not None else d ** -0.5
    valid_len = valid_len or sk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        valid_len=valid_len, qblk=qblk, kblk=kblk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qblk, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, kblk, d),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, kblk, d),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qblk, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qblk, 1), jnp.float32),
            pltpu.VMEM((qblk, 1), jnp.float32),
            pltpu.VMEM((qblk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
