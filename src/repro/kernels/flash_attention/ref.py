"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, scale=None,
                  valid_len=0):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Sk,D] → [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    valid_len = valid_len or sk
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    row = jnp.arange(sq)[:, None]
    col = jnp.arange(sk)[None, :]
    ok = col < valid_len
    if causal:
        ok &= col <= row
    if window:
        ok &= (row - col) < window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (all NEG_INF) should produce 0, not NaN
    any_ok = ok.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    out = jnp.where(any_ok, out, 0.0)
    return out.astype(q.dtype)
