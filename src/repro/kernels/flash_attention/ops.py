"""Jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (q [B,S,Hq,D]), pads sequences to block
multiples, transposes to the kernel layout, and dispatches to the Pallas
kernel (``interpret=True`` on non-TPU backends so the same code validates
on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "qblk", "kblk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    qblk=128, kblk=128, interpret=None):
    """q [B,S,Hq,D], k/v [B,S,Hkv,D] → [B,S,Hq,D]."""
    interp = (not _on_tpu()) if interpret is None else interpret
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    qblk = min(qblk, sq)
    kblk = min(kblk, sk)
    pq, pk = (-sq) % qblk, (-sk) % kblk
    qt = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, window=window, scale=scale, qblk=qblk,
        kblk=kblk, valid_len=sk, interpret=interp)
    return out.transpose(0, 2, 1, 3)[:, :sq]
