"""Gradient-histogram Pallas TPU kernel — the hot loop of the paper's
winning profiler model (histogram GBT).

For each boosting node split search we need, per (feature, bin):
    gsum[f, b] = Σ_{rows r: code[r,f]==b} grad[r]
    cnt [f, b] = Σ_{rows r: code[r,f]==b} 1

TPU adaptation (DESIGN.md §2): a scatter-add histogram (the GPU approach —
atomics into shared memory) has no TPU analogue; instead each row block
builds a one-hot (rows × bins) comparison mask on the VPU and reduces it —
turning the histogram into dense masked reductions, which is exactly the
layout the VPU wants.  Grid is sequential over row blocks; the [F, bins]
accumulators stay resident in VMEM.

VMEM: codes block (blk×F s32) + mask (blk×F×bins f32 transient)
      + out (F×bins ×2) ≈ a few MB at blk=512, F≤64, bins≤256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, grad_ref, gsum_ref, cnt_ref, *, blk: int,
            n_bins: int, n_rows: int):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        gsum_ref[...] = jnp.zeros_like(gsum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    codes = codes_ref[...]                      # [blk, F] int32
    grad = grad_ref[...]                        # [blk, 1] f32
    rows = ib * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0)
    valid = (rows < n_rows).astype(jnp.float32)             # [blk, 1]
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_bins), 2)
    onehot = (codes[:, :, None] == bins).astype(jnp.float32)  # [blk,F,bins]
    g = grad * valid                                          # [blk,1]
    gsum_ref[...] += jnp.einsum("rfb,ro->fb", onehot, g,
                                preferred_element_type=jnp.float32)
    cnt_ref[...] += jnp.einsum("rfb,ro->fb", onehot, valid,
                               preferred_element_type=jnp.float32)


def grad_histogram_kernel(codes, grad, n_bins: int, *, blk: int = 512,
                          interpret: bool = True):
    """codes [N, F] int32, grad [N] f32 → (gsum [F,bins], cnt [F,bins])."""
    n, f = codes.shape
    pad = (-n) % blk
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        grad = jnp.pad(grad, ((0, pad),))
    nb = (n + pad) // blk
    kernel = functools.partial(_kernel, blk=blk, n_bins=n_bins, n_rows=n)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((blk, f), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((f, n_bins), lambda i: (0, 0)),
            pl.BlockSpec((f, n_bins), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((f, n_bins), jnp.float32),
            jax.ShapeDtypeStruct((f, n_bins), jnp.float32),
        ],
        interpret=interpret,
    )(codes, grad.reshape(-1, 1))
