"""Public wrapper: numpy in/out for the GBT training loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gbt_hist.kernel import grad_histogram_kernel

_jitted_cache: dict = {}


def grad_histogram(codes: np.ndarray, grad: np.ndarray, n_bins: int
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Drop-in for the numpy histogram in ``repro.core.predictors.gbt``."""
    interpret = jax.default_backend() != "tpu"
    key = ("h", n_bins, interpret)
    if key not in _jitted_cache:
        _jitted_cache[key] = jax.jit(
            lambda c, g: grad_histogram_kernel(c, g, n_bins,
                                               interpret=interpret))
    gsum, cnt = _jitted_cache[key](
        jnp.asarray(codes, jnp.int32), jnp.asarray(grad, jnp.float32))
    return np.asarray(gsum, np.float64), np.asarray(cnt, np.float64)
