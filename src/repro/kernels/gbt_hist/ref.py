"""Pure oracle for the gradient-histogram kernel (numpy bincount)."""
from __future__ import annotations

import numpy as np


def grad_histogram_ref(codes: np.ndarray, grad: np.ndarray, n_bins: int):
    """codes [N,F] int, grad [N] → (gsum [F,bins] f64, cnt [F,bins] f64)."""
    n, f = codes.shape
    flat = codes.astype(np.int64) + np.arange(f)[None, :] * n_bins
    gsum = np.bincount(flat.ravel(), weights=np.repeat(grad, f),
                       minlength=f * n_bins).reshape(f, n_bins)
    cnt = np.bincount(flat.ravel(), minlength=f * n_bins
                      ).reshape(f, n_bins).astype(np.float64)
    return gsum, cnt
