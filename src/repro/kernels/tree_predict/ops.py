"""Accelerated batched tree inference over :class:`TreeArrays`.

``backend="jax"`` runs the level-synchronous descent as jitted XLA:
binning is the same f32 edge-comparison count as the host, the per-tree
descent is gather-driven, and trees accumulate through a *sequential*
``lax.scan`` in training order — additions of identical f64 values in
the identical order, so the result is bit-for-bit equal to
``GBTRegressor.predict`` / :func:`repro.kernels.tree_predict.ref.
predict_ref` (leaf values are pre-scaled by ``learning_rate`` on the
host, leaving the scan multiply-free — nothing for XLA to contract).

``backend="pallas"`` calls the fused TPU kernel
(:mod:`repro.kernels.tree_predict.kernel`): f32, within tolerance, node
arrays resident in VMEM (interpret mode off-TPU).
"""
# repro: module-tags=fma-sensitive
# (DET001: the scan must stay multiply-free/add-only — a dot/matmul
#  would reintroduce FMA contraction and break the f64 bitwise pin)
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels.tree_predict.ref import TreeArrays


def _bin_codes(x, edges):
    """``code[n, f] = #{edges[f] < x[n, f]}`` — exact ``searchsorted``
    (side='left') semantics on f32, as comparison counts."""
    return jnp.sum(edges[None, :, :] < x[:, :, None], axis=-1,
                   dtype=jnp.int32)


def _descend(codes, feat, thr, left, right, max_depth: int):
    """``[N]`` leaf index per row for one tree (arrays are that tree's
    ``[M]`` rows)."""
    n = codes.shape[0]
    rows = jnp.arange(n)

    def level(_, node):
        f = feat[node]
        split = f >= 0
        goes_left = jnp.where(split, codes[rows, jnp.maximum(f, 0)]
                              <= thr[node], False)
        nxt = jnp.where(goes_left, left[node], right[node])
        return jnp.where(split, nxt, node)

    node0 = jnp.zeros(n, jnp.int32)
    if max_depth == 0:
        return node0
    return jax.lax.fori_loop(0, max_depth, level, node0)


def _predict_jax(x, edges, feat, thr, left, right, scaled_value, base,
                 max_depth: int):
    codes = _bin_codes(x, edges)

    def one_tree(carry, tree):
        tf, tt, tl, tr, tv = tree
        leaf = _descend(codes, tf, tt, tl, tr, max_depth)
        return carry + tv[leaf], None

    init = jnp.full((x.shape[0],), base, scaled_value.dtype)
    pred, _ = jax.lax.scan(one_tree, init,
                           (feat, thr, left, right, scaled_value))
    return pred


def predict_trees(x: np.ndarray, arrays: TreeArrays, *,
                  backend: str = "jax", blk: int = 512,
                  interpret: bool | None = None) -> np.ndarray:
    """``[N]`` f64 predictions for ``x [N, F]`` — the accelerated twin of
    ``GBTRegressor.predict`` (bit-for-bit on ``backend="jax"``, within
    f32 tolerance on ``backend="pallas"``)."""
    x32 = np.asarray(x, np.float32)
    if backend == "pallas":
        from repro.kernels.tree_predict.kernel import tree_predict_kernel
        codes = _bin_codes(jnp.asarray(x32), jnp.asarray(arrays.edges))
        out = tree_predict_kernel(
            jnp.asarray(codes, jnp.int32),
            jnp.asarray(arrays.feature), jnp.asarray(arrays.threshold_bin),
            jnp.asarray(arrays.left), jnp.asarray(arrays.right),
            jnp.asarray(arrays.learning_rate * arrays.value, jnp.float32),
            max_depth=arrays.max_depth, blk=blk, interpret=interpret)
        return np.asarray(out, np.float64) + arrays.base
    if backend != "jax":
        raise ValueError(f"unknown tree-predict backend {backend!r}; "
                         "expected 'jax' or 'pallas'")
    with enable_x64():
        fn = getattr(arrays, "_jitted", None)
        if fn is None:
            # learning_rate folded into the leaf values host-side, in
            # f64 — the exact per-leaf products the host accumulation
            # produces (the scan is multiply-free)
            scaled = arrays.learning_rate * arrays.value
            consts = tuple(jnp.asarray(a) for a in
                           (arrays.edges, arrays.feature,
                            arrays.threshold_bin, arrays.left,
                            arrays.right, scaled))
            depth = arrays.max_depth

            def fn(xv):
                return _predict_jax(xv, *consts, arrays.base, depth)

            fn = jax.jit(fn)
            # memoised on the (frozen) arrays instance: one compile per
            # fitted model, dropped with it
            object.__setattr__(arrays, "_jitted", fn)
        return np.asarray(fn(jnp.asarray(x32)), np.float64)
