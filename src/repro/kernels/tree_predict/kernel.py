"""Fused batched tree-inference Pallas TPU kernel — the serving twin of
the ``gbt_hist`` *training* kernel.

Per grid step one ``[blk]`` row block descends one tree.  Per-row
pointer chasing has no TPU analogue (the VPU has no per-lane gather from
VMEM), so — exactly like the one-hot histogram trick in
:mod:`repro.kernels.gbt_hist` — every gather becomes a dense masked
reduction: a ``[blk, max_nodes]`` one-hot of the current node index
against a ``broadcasted_iota`` selects that node's ``(feature,
threshold, left, right)`` row-wise, and a second ``[blk, F]`` one-hot
selects each row's split-feature bin code.  The five node arrays of the
active tree live in VMEM for the whole descent (they are ``[1,
max_nodes]`` rows — a depth-6 ensemble is a few KB), predictions
accumulate in the output block across the sequential tree axis of the
grid, and the ``[N, n_trees]`` per-tree prediction matrix is never
materialised.

VMEM per step: codes block (blk × F int32) + 5 node rows + the
``[blk, max_nodes]`` one-hot transient + out (blk × 1) ≈ 1–2 MB at
blk=512, F ≤ 32, max_nodes ≤ 256.

Leaf values arrive pre-scaled by ``learning_rate``; the ``base``
intercept is added by the caller (f64, host side).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _select(onehot, row):
    """Row-wise one-hot gather: ``[blk, M] bool, [1, M] int -> [blk]``."""
    return jnp.sum(jnp.where(onehot, row, 0), axis=1, dtype=jnp.int32)


def _kernel(codes_ref, feat_ref, thr_ref, left_ref, right_ref, value_ref,
            out_ref, *, max_depth: int, max_nodes: int, n_feat: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                       # [blk, F] int32
    blk = codes.shape[0]
    node_iota = jax.lax.broadcasted_iota(jnp.int32, (blk, max_nodes), 1)
    feat_iota = jax.lax.broadcasted_iota(jnp.int32, (blk, n_feat), 1)
    feat_row = feat_ref[...]                     # [1, M] int32
    thr_row = thr_ref[...]
    left_row = left_ref[...]
    right_row = right_ref[...]

    def level(_, node):
        onehot = node[:, None] == node_iota      # [blk, M]
        f = _select(onehot, feat_row)
        split = f >= 0
        thr = _select(onehot, thr_row)
        code = jnp.sum(jnp.where(feat_iota == jnp.maximum(f, 0)[:, None],
                                 codes, 0), axis=1, dtype=jnp.int32)
        goes_left = split & (code <= thr)
        nxt = jnp.where(goes_left, _select(onehot, left_row),
                        _select(onehot, right_row))
        return jnp.where(split, nxt, node)

    node = jnp.zeros((blk,), jnp.int32)
    if max_depth > 0:
        node = jax.lax.fori_loop(0, max_depth, level, node)
    leaf_hot = node[:, None] == node_iota
    val = jnp.sum(jnp.where(leaf_hot, value_ref[...], 0.0), axis=1)
    out_ref[...] += val[:, None]


def tree_predict_kernel(codes, feature, threshold_bin, left, right,
                        scaled_value, *, max_depth: int, blk: int = 512,
                        interpret: bool | None = None):
    """``codes [N, F]`` int32 bin codes; node arrays ``[T, M]`` (value
    f32, pre-scaled by the learning rate).  Returns ``[N]`` f32 summed
    tree outputs (add the ensemble ``base`` on the host)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, f = codes.shape
    n_trees, max_nodes = feature.shape
    if n == 0:                           # nothing to grid over
        return jnp.zeros((0,), jnp.float32)
    blk = min(blk, max(n, 1))
    pad = (-n) % blk
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    nb = (n + pad) // blk
    kernel = functools.partial(_kernel, max_depth=max_depth,
                               max_nodes=max_nodes, n_feat=f)
    tree_spec = pl.BlockSpec((1, max_nodes), lambda ir, it: (it, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nb, n_trees),
        in_specs=[
            pl.BlockSpec((blk, f), lambda ir, it: (ir, 0)),    # codes
            tree_spec, tree_spec, tree_spec, tree_spec,        # f, t, l, r
            tree_spec,                                         # values
        ],
        out_specs=pl.BlockSpec((blk, 1), lambda ir, it: (ir, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 1), jnp.float32),
        interpret=interpret,
    )(codes, feature, threshold_bin, left, right, scaled_value)
    return out[:n, 0]
