"""Flattened tree-ensemble form + the host reference for batched inference.

The GBT training code (:mod:`repro.core.predictors.gbt`) keeps each tree
as a Python list of ``_Node`` objects — fine for growing, hostile to
accelerators.  :func:`flatten_gbt` compiles a *fitted* ensemble into five
padded ``[n_trees, max_nodes]`` arrays — ``(feature, threshold_bin,
left, right, value)`` — plus the quantile bin edges, which is the form
every inference backend consumes:

  * :func:`predict_ref` (here)   — vectorised numpy level-synchronous
    descent, bit-for-bit with ``GBTRegressor.predict`` (the pin the
    accelerated paths are tested against);
  * ``ops.predict_trees``        — the same descent as jitted XLA
    (sequential tree accumulation, so f64 results stay bit-for-bit);
  * ``kernel.tree_predict_kernel`` — the fused Pallas TPU kernel (node
    arrays resident in VMEM, one-hot gathers on the VPU).

The same arrays are what predictor persistence
(:mod:`repro.core.predictors.persist`) writes to ``.npz``, so a saved
model *is* its lowered form.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TreeArrays:
    """One fitted GBT ensemble as padded node arrays (struct-of-arrays).

    Node 0 is each tree's root.  ``feature < 0`` marks a leaf; padding
    slots beyond a tree's ``n_nodes`` are leaves with value 0, so a
    descent that never reaches them stays well-defined.  ``value`` holds
    *raw* leaf values — scale by ``learning_rate`` (already folded into
    f64 by the lowering) to accumulate predictions.
    """
    feature: np.ndarray          # [T, M] int32, -1 == leaf
    threshold_bin: np.ndarray    # [T, M] int32 (bin code, go left if <=)
    left: np.ndarray             # [T, M] int32
    right: np.ndarray            # [T, M] int32
    value: np.ndarray            # [T, M] f64 raw leaf values
    n_nodes: np.ndarray          # [T] int32 real node count per tree
    edges: np.ndarray            # [F, n_bins-1] f32 quantile bin edges
    base: float                  # ensemble intercept (mean target)
    learning_rate: float
    max_depth: int               # deepest split depth over all trees

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.feature.shape[1]


def _tree_depth(feature: np.ndarray, left: np.ndarray, right: np.ndarray
                ) -> int:
    """Deepest split chain of one flattened tree (0 for a stump leaf)."""
    depth = 0
    stack = [(0, 0)]
    while stack:
        node, d = stack.pop()
        if feature[node] < 0:
            depth = max(depth, d)
        else:
            stack.append((int(left[node]), d + 1))
            stack.append((int(right[node]), d + 1))
    return depth


def flatten_gbt(model) -> TreeArrays:
    """Compile a *fitted* :class:`repro.core.predictors.gbt.GBTRegressor`
    into :class:`TreeArrays` (raises ``AttributeError`` if unfitted)."""
    trees = model.trees_
    n_trees = len(trees)
    max_nodes = max((len(t) for t in trees), default=1)
    feat = np.full((n_trees, max_nodes), -1, np.int32)
    thr = np.zeros((n_trees, max_nodes), np.int32)
    left = np.zeros((n_trees, max_nodes), np.int32)
    right = np.zeros((n_trees, max_nodes), np.int32)
    value = np.zeros((n_trees, max_nodes), np.float64)
    n_nodes = np.zeros(n_trees, np.int32)
    depth = 0
    for t, tree in enumerate(trees):
        n_nodes[t] = len(tree)
        for i, node in enumerate(tree):
            feat[t, i] = node.feature
            thr[t, i] = node.threshold_bin
            left[t, i] = node.left
            right[t, i] = node.right
            value[t, i] = node.value
        depth = max(depth, _tree_depth(feat[t], left[t], right[t]))
    return TreeArrays(feat, thr, left, right, value, n_nodes,
                      np.asarray(model.edges_, np.float32),
                      float(model.base_), float(model.learning_rate),
                      depth)


def unflatten_gbt(arrays: TreeArrays) -> list:
    """Rebuild the ``list[list[_Node]]`` tree representation — the
    persistence load path (round-trips :func:`flatten_gbt` exactly)."""
    from repro.core.predictors.gbt import _Node
    trees = []
    for t in range(arrays.n_trees):
        trees.append([
            _Node(feature=int(arrays.feature[t, i]),
                  threshold_bin=int(arrays.threshold_bin[t, i]),
                  left=int(arrays.left[t, i]),
                  right=int(arrays.right[t, i]),
                  value=float(arrays.value[t, i]))
            for i in range(int(arrays.n_nodes[t]))])
    return trees


def bin_codes_ref(x: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """``[N, F]`` int32 bin codes — ``searchsorted`` semantics expressed
    as comparison counts (``code = #{edges < x}``), the exact form the
    accelerated paths replay."""
    x = np.asarray(x, np.float32)
    return np.sum(edges[None, :, :] < x[:, :, None], axis=-1,
                  dtype=np.int32)


def predict_ref(x: np.ndarray, arrays: TreeArrays) -> np.ndarray:
    """Host reference: ``[N]`` predictions via level-synchronous descent.

    Bit-for-bit with ``GBTRegressor.predict``: codes from the same f32
    edge comparisons, per-tree leaf values scaled by ``learning_rate``
    as one elementwise f64 multiply, trees accumulated sequentially in
    training order onto the ``base`` intercept.
    """
    codes = bin_codes_ref(x, arrays.edges)
    n = len(codes)
    pred = np.full(n, arrays.base, np.float64)
    rows = np.arange(n)
    for t in range(arrays.n_trees):
        node = np.zeros(n, np.int32)
        for _ in range(arrays.max_depth):
            feat = arrays.feature[t, node]
            split = feat >= 0
            thr = arrays.threshold_bin[t, node]
            goes_left = np.where(split,
                                 codes[rows, np.maximum(feat, 0)] <= thr,
                                 False)
            nxt = np.where(goes_left, arrays.left[t, node],
                           arrays.right[t, node])
            node = np.where(split, nxt, node).astype(np.int32)
        pred += arrays.learning_rate * arrays.value[t, node]
    return pred
