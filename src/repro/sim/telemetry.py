"""Per-run streaming telemetry: completion percentiles, deadline misses,
energy, node utilisation, and re-plan counters.

Every simulator run fills one :class:`Telemetry`: schedulers count their
incremental work (``replans``, ``column_refreshes``, ``split_repicks``,
``split_switches``, ...), and each finished task contributes a
:class:`TaskRecord`.  ``summary()`` reduces that to the run-level
numbers the paper's evaluation reports (p50/p99 completion, misses,
joules, utilisation), and ``to_rows()`` / ``save()`` export the same
flat ``[{"name": ..., metric: ...}]`` record schema the ``results/``
benchmark JSONs already use, so one plotting path covers batch
benchmarks and streaming runs alike.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import Counter
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TaskRecord:
    """One completed task's life cycle in virtual time (seconds)."""
    name: str
    arrived_s: float
    started_s: float
    finished_s: float
    node: str = ""
    # distinguishes same-spec nodes (clusters routinely repeat device
    # specs, so the spec name alone is not a node identity)
    node_id: Optional[int] = None
    deadline_s: Optional[float] = None
    energy_j: float = 0.0
    split: Optional[int] = None      # final offload split, if planned
    switches: int = 0                # Pareto re-picks that changed it
    transfer_s: float = 0.0          # network delay (sampled RTT)

    @property
    def sojourn_s(self) -> float:
        """Arrival → completion (queueing + service + transfer)."""
        return self.finished_s - self.arrived_s

    @property
    def wait_s(self) -> float:
        """Queueing delay: arrival → start of service."""
        return self.started_s - self.arrived_s

    @property
    def service_s(self) -> float:
        """Time in service (start → finish, net of network delay)."""
        return self.finished_s - self.started_s - self.transfer_s

    @property
    def missed(self) -> bool:
        return (self.deadline_s is not None
                and self.finished_s > self.deadline_s)


class Telemetry:
    """Accumulates task records and scheduler counters for one run.

    Records arrive one at a time (:meth:`complete`, the event loop's
    path) or as column batches (:meth:`complete_arrays`, the fleet
    engine's path).  Batches are held as arrays and only materialised
    into :class:`TaskRecord` objects when ``records`` is first read, so
    a 10⁵-task slabbed run never builds per-task Python objects inside
    its hot loop; insertion order is preserved across both paths.
    """

    def __init__(self):
        self._records: list[TaskRecord] = []
        self._pending: list[tuple] = []      # deferred column batches
        self.counters: Counter = Counter()
        self.gauges: dict[str, float] = {}

    # -- ingestion --------------------------------------------------------
    def count(self, key: str, n: int = 1) -> None:
        self.counters[key] += n

    def gauge(self, key: str, value: float) -> None:
        """Record the latest value of a float metric (e.g. the oracle's
        rolling nRMSE) — last write wins, exported with the summary."""
        self.gauges[key] = float(value)

    def complete(self, record: TaskRecord) -> None:
        if self._pending:
            self._materialise()
        self._records.append(record)

    def complete_arrays(self, names, arrived_s, started_s, finished_s, *,
                        node, node_id, deadline_s, energy_j,
                        split=None, switches=None,
                        transfer_s=None) -> None:
        """Ingest one batch of completed tasks as parallel columns (all
        length n; ``deadline_s``/``split`` entries may be ``None``,
        ``split``/``switches`` may be ``None`` wholesale).  Equivalent
        to n :meth:`complete` calls in column order, but deferred."""
        n = len(names)
        for label, col in (("arrived_s", arrived_s),
                           ("started_s", started_s),
                           ("finished_s", finished_s), ("node", node),
                           ("node_id", node_id),
                           ("deadline_s", deadline_s),
                           ("energy_j", energy_j)):
            if len(col) != n:
                raise ValueError(f"column {label} has {len(col)} rows, "
                                 f"expected {n}")
        self._pending.append((list(names), arrived_s, started_s,
                              finished_s, node, node_id, deadline_s,
                              energy_j, split, switches, transfer_s))

    def _materialise(self) -> None:
        recs = self._records
        for (names, arrived, started, finished, node, node_id, deadline,
             energy, split, switches, transfer) in self._pending:
            for k in range(len(names)):
                recs.append(TaskRecord(
                    name=names[k], arrived_s=float(arrived[k]),
                    started_s=float(started[k]),
                    finished_s=float(finished[k]), node=node[k],
                    node_id=int(node_id[k]),
                    deadline_s=deadline[k], energy_j=float(energy[k]),
                    split=None if split is None else split[k],
                    switches=0 if switches is None
                    else int(switches[k]),
                    transfer_s=0.0 if transfer is None
                    else float(transfer[k])))
        self._pending.clear()

    @property
    def records(self) -> list[TaskRecord]:
        if self._pending:
            self._materialise()
        return self._records

    # -- reductions -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._records) + sum(len(b[0]) for b in self._pending)

    @property
    def deadline_misses(self) -> int:
        return sum(1 for r in self.records if r.missed)

    @property
    def makespan_s(self) -> float:
        return max((r.finished_s for r in self.records), default=0.0)

    @property
    def energy_j(self) -> float:
        return float(sum(r.energy_j for r in self.records))

    @staticmethod
    def _cvar_of(soj: np.ndarray, alpha: float) -> float:
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        if soj.size == 0:
            return 0.0
        var = np.percentile(soj, 100.0 * alpha)
        tail = soj[soj >= var]
        return float(tail.mean()) if tail.size else float(var)

    def cvar(self, alpha: float = 0.95) -> float:
        """CVaR_alpha of task sojourn times: the mean sojourn over the
        worst ``(1 - alpha)`` fraction of tasks — the tail statistic
        the tail-aware cost objective optimises for."""
        soj = np.asarray([r.sojourn_s for r in self.records], np.float64)
        return self._cvar_of(soj, alpha)

    def _node_stats(self) -> tuple[dict[str, float], dict[str, float]]:
        """One walk over the records producing both per-node reductions:
        ``(utilisation, mean queue length)`` — busy time and accrued
        queueing delay per node, each divided by the run's makespan.

        Nodes are identified by ``(node_id, node)`` so same-spec nodes
        do not merge; duplicates are labelled ``name@id``."""
        span = self.makespan_s
        busy: Counter = Counter()
        waits: Counter = Counter()
        for r in self.records:
            if r.node:
                busy[(r.node_id, r.node)] += r.finished_s - r.started_s
                waits[(r.node_id, r.node)] += r.wait_s
        names = Counter(name for _, name in busy)
        util: dict[str, float] = {}
        qlen: dict[str, float] = {}
        for nid, name in sorted(busy, key=lambda k: (str(k[1]),
                                                     -1 if k[0] is None
                                                     else k[0])):
            label = name if names[name] == 1 or nid is None \
                else f"{name}@{nid}"
            util[label] = busy[(nid, name)] / span if span > 0 else 0.0
            qlen[label] = waits[(nid, name)] / span if span > 0 else 0.0
        return util, qlen

    def queue_lens(self) -> dict[str, float]:
        """Per-node time-averaged queue length over the makespan
        (Little's law: total queueing delay accrued on the node divided
        by the run's span).  Node labels match :meth:`utilisation`."""
        return self._node_stats()[1]

    def utilisation(self) -> dict[str, float]:
        """Busy fraction per node over the run's makespan (labels as in
        :meth:`_node_stats`)."""
        return self._node_stats()[0]

    def summary(self, *, _util: Optional[dict] = None) -> dict:
        """Run-level metrics (the numbers a paper table would report).

        ``_util`` lets :meth:`to_rows` pass a precomputed utilisation
        dict so the records are walked once, not once per reduction."""
        soj = np.asarray([r.sojourn_s for r in self.records], np.float64)
        waits = np.asarray([r.wait_s for r in self.records], np.float64)
        util = self.utilisation() if _util is None else _util
        span = self.makespan_s
        out = {
            "n_tasks": len(self.records),
            "p50_completion_s": float(np.percentile(soj, 50))
            if soj.size else 0.0,
            "p90_completion_s": float(np.percentile(soj, 90))
            if soj.size else 0.0,
            "p99_completion_s": float(np.percentile(soj, 99))
            if soj.size else 0.0,
            # the tail statistic the tail-aware cost objective optimises
            "cvar95_completion_s": self._cvar_of(soj, 0.95),
            "mean_completion_s": float(soj.mean()) if soj.size else 0.0,
            "makespan_s": self.makespan_s,
            "deadline_misses": self.deadline_misses,
            "miss_rate": self.deadline_misses / len(self.records)
            if self.records else 0.0,
            "energy_j": self.energy_j,
            "mean_utilisation": float(np.mean(list(util.values())))
            if util else 0.0,
            "split_switches": int(sum(r.switches for r in self.records)),
            # queueing breakdown (all 0.0 without finite-capacity pools)
            "p99_wait_s": float(np.percentile(waits, 99))
            if waits.size else 0.0,
            "mean_wait_s": float(waits.mean()) if waits.size else 0.0,
            # fleet-wide time-averaged queue length (Little's law)
            "mean_queue_len": float(waits.sum()) / span
            if span > 0 else 0.0,
        }
        # counters and gauges ride along under their own names;
        # record-derived metrics win on collision (e.g.
        # "split_switches": the records count completed tasks, the
        # planner's counter also includes still-live ones on a
        # truncated run)
        out.update({k: int(v) for k, v in sorted(self.counters.items())
                    if k not in out})
        out.update({k: float(v) for k, v in sorted(self.gauges.items())
                    if k not in out})
        return out

    # -- export (the results/ record schema) ------------------------------
    def to_rows(self, name: str = "sim_stream") -> list[dict]:
        """Flat benchmark-style rows: one summary row plus one row per
        node's utilisation — the same ``[{"name": ..., ...}]`` shape as
        the ``results/bench_*.json`` files.  Both per-node reductions
        come from one record walk (:meth:`_node_stats`), reused by the
        summary row."""
        util, qlen = self._node_stats()
        rows = [{"name": name, **self.summary(_util=util)}]
        rows += [{"name": f"{name}_util_{node}", "utilisation": u,
                  "mean_queue_len": qlen.get(node, 0.0)}
                 for node, u in util.items()]
        return rows

    # -- export (the repro.obs metrics surface) ---------------------------
    def registry(self, prefix: str = "sim") -> "MetricsRegistry":
        """Lift this run into a :class:`repro.obs.MetricsRegistry`:
        every scheduler counter becomes a Prometheus counter, every
        gauge a gauge, and the sojourn/wait/transfer distributions land
        in fixed-boundary histograms — the standard metrics surface a
        serving plane scrapes (``to_prometheus`` dumps the text
        exposition format)."""
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        reg.counter(f"{prefix}_tasks_completed_total",
                    help="completed tasks").inc(len(self.records))
        reg.counter(f"{prefix}_deadline_misses_total",
                    help="tasks finishing past their deadline") \
            .inc(self.deadline_misses)
        reg.gauge(f"{prefix}_energy_joules",
                  help="total energy over the run").set(self.energy_j)
        reg.gauge(f"{prefix}_makespan_seconds").set(self.makespan_s)
        for key in sorted(self.counters):
            reg.counter(f"{prefix}_{key}_total").inc(self.counters[key])
        for key in sorted(self.gauges):
            reg.gauge(f"{prefix}_{key}").set(self.gauges[key])
        hists = {
            "sojourn_seconds": [r.sojourn_s for r in self.records],
            "wait_seconds": [r.wait_s for r in self.records],
            "transfer_seconds": [r.transfer_s for r in self.records],
        }
        for key, vals in hists.items():
            h = reg.histogram(f"{prefix}_task_{key}",
                              help=f"per-task {key.split('_')[0]} time")
            h.observe_many(vals)
        return reg

    def to_prometheus(self, prefix: str = "sim") -> str:
        """Prometheus text exposition of :meth:`registry`."""
        return self.registry(prefix).to_prometheus()

    def attribution(self) -> "RunAttribution":
        """The rows → analyze bridge: lift this run's task records into
        a :class:`repro.obs.analyze.RunAttribution` (phase attribution,
        critical paths, miss classification) without having traced the
        run — lifecycle spans are reconstructed from the records.  A
        traced run's ``attribute(tracer)`` additionally carries the
        control-plane instants the miss classifier corroborates
        against."""
        from repro.obs.analyze import attribute
        return attribute(self)

    def save(self, path: str, name: str = "sim_stream") -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_rows(name), f, indent=1, default=float)

    def table(self) -> str:
        """Human-readable summary table (used by the examples)."""
        s = self.summary()
        lines = [f"  {k:>20}: {v:.4g}" if isinstance(v, float)
                 else f"  {k:>20}: {v}" for k, v in s.items()]
        return "\n".join(lines)
