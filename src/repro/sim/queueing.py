"""Finite-capacity edge contention: server pools, heavy-tailed RTT.

The simulators in :mod:`repro.sim` historically modelled every node as
infinite-capacity: the scalar ``avail`` vector is a 1-server queue over
*believed* finish times, and network delay is a deterministic
``latency + bytes / bw`` term.  This module adds the missing contention
layer:

``ServerPool``
    a c-server FIFO queue per node tracking *realised* busy-until times,
    so sojourn = wait + service (+ transfer) and M/M/c statistics come
    out exactly;

``NodePools``
    a fleet of pools with an incrementally-maintained availability
    vector (the schedulers' hot path) plus a full ``recompute_avail``
    for cross-checking;

``WeibullRTT`` / ``LognormalRTT``
    seeded heavy-tailed network round-trip processes with closed-form
    ``mean`` / ``percentile`` / ``cvar`` (no scipy — the lognormal
    quantile uses the Acklam inverse-normal approximation and the CVaR
    closed form uses :func:`math.erf`);

``erlang_c`` / ``mm1_sojourn`` / ``mmc_sojourn``
    the queueing-theory closed forms the validation tests pin against.

All random processes accept ``int | np.random.SeedSequence`` seeds.
Passing an ``int`` reproduces the historical ``default_rng(int)``
stream bit-for-bit (``default_rng`` builds ``SeedSequence(int)``
internally); passing a spawned child keeps new processes statistically
independent of existing ones without perturbing them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.obs.trace import NULL_TRACER

Seed = Union[int, np.random.SeedSequence]

__all__ = [
    "ServerPool",
    "NodePools",
    "DelayProcess",
    "WeibullRTT",
    "LognormalRTT",
    "erlang_c",
    "mm1_sojourn",
    "mmc_sojourn",
    "spawn_streams",
]


def spawn_streams(seed: Seed, n: int) -> list[np.random.SeedSequence]:
    """``n`` independent child seed sequences of the run seed.

    Every stochastic process in a simulation should draw from its own
    child: adding a new process then consumes fresh entropy instead of
    shifting the draws of existing ones.
    """
    ss = seed if isinstance(seed, np.random.SeedSequence) \
        else np.random.SeedSequence(seed)
    return list(ss.spawn(int(n)))


# ---------------------------------------------------------------------------
# server pools


class ServerPool:
    """A c-server FIFO queue tracking realised busy-until times.

    ``capacity=None`` means infinite servers: admission never waits and
    the pool only records utilisation.  ``capacity=1`` with
    deterministic service reproduces the schedulers' historical scalar
    ``avail`` bookkeeping bit-for-bit (start = max(busy, now)).
    """

    __slots__ = ("capacity", "busy", "_infinite_busy", "_busy_area",
                 "_queue_area", "_last_t", "n_admitted")

    def __init__(self, capacity: Optional[int] = None,
                 available_at: float = 0.0) -> None:
        if capacity is not None and int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1 or None, "
                             f"got {capacity}")
        self.capacity = None if capacity is None else int(capacity)
        if self.capacity is None:
            self.busy = np.zeros(0, dtype=np.float64)
        else:
            self.busy = np.full(self.capacity, float(available_at),
                                dtype=np.float64)
        self._infinite_busy: list[float] = []
        self._busy_area = 0.0    # integral of in-service count over time
        self._queue_area = 0.0   # integral of waiting count over time
        self._last_t = float(available_at)
        self.n_admitted = 0

    # -- state ------------------------------------------------------------

    def next_free(self) -> float:
        """Earliest time any server frees up (realised)."""
        if self.capacity is None:
            return 0.0
        return float(self.busy.min())

    def wait(self, now: float) -> float:
        """Queueing delay a task arriving at ``now`` would incur."""
        if self.capacity is None:
            return 0.0
        return max(0.0, float(self.busy.min()) - float(now))

    def queue_len(self, now: float) -> int:
        """Number of servers that are busy strictly past ``now``."""
        if self.capacity is None:
            t = float(now)
            return sum(1 for b in self._infinite_busy if b > t)
        return int(np.count_nonzero(self.busy > float(now)))

    def utilisation(self, now: float) -> float:
        """Time-averaged fraction of servers busy on [start, now]."""
        if self.capacity is None or float(now) <= 0.0:
            return 0.0
        self._accrue(float(now))
        span = float(now) - 0.0
        if span <= 0.0:
            return 0.0
        return self._busy_area / (span * self.capacity)

    def mean_queue_len(self, now: float) -> float:
        """Time-averaged number of tasks waiting (not in service)."""
        if self.capacity is None or float(now) <= 0.0:
            return 0.0
        self._accrue(float(now))
        return self._queue_area / float(now)

    # -- admission --------------------------------------------------------

    def _accrue(self, t: float) -> None:
        if self.capacity is None or t <= self._last_t:
            return
        # piecewise-constant between events: count servers busy past
        # _last_t, integrate until min(their finish, t) step by step.
        lo, hi = self._last_t, t
        times = np.unique(np.clip(self.busy, lo, hi))
        prev = lo
        for edge in times:
            e = float(edge)
            if e <= prev:
                continue
            n_busy = int(np.count_nonzero(self.busy >= e))
            self._busy_area += (e - prev) * n_busy
            prev = e
        if prev < hi:
            n_busy = int(np.count_nonzero(self.busy > hi))
            self._busy_area += (hi - prev) * n_busy
        self._last_t = t

    def admit(self, now: float, service_s: float) -> tuple[float, float]:
        """Admit a task arriving at ``now`` needing ``service_s``.

        Returns ``(start, finish)``: the task starts when the earliest
        server frees (FIFO, first-index tie-break) and occupies it for
        ``service_s``.  Busy-until state is *realised* — callers pass
        the realised service time, not the believed one.
        """
        now = float(now)
        service_s = float(service_s)
        self.n_admitted += 1
        if self.capacity is None:
            start = now
            finish = now + service_s
            self._infinite_busy.append(finish)
            if len(self._infinite_busy) > 4096:
                self._infinite_busy = [
                    b for b in self._infinite_busy if b > now]
            return start, finish
        self._accrue(now)
        k = int(np.argmin(self.busy))
        start = max(float(self.busy[k]), now)
        if start > now:
            self._queue_area += (start - now)  # this task waits 1 * w
            # waiting happens in the future; fold into queue integral
            # directly (exact for per-task waiting-time accounting).
        finish = start + service_s
        self.busy[k] = finish
        return start, finish


class NodePools:
    """Server pools for a fleet of nodes + cached availability vector.

    ``avail`` mirrors what :class:`~repro.sim.stream.StreamScheduler`
    keeps today — per-node earliest-free time — but derived from
    realised pool state and updated *incrementally* on each admit
    (``O(c)`` per event) rather than recomputed across all nodes
    (``O(N·c)``, see :meth:`recompute_avail`; the benchmark pins the
    incremental path is not slower).
    """

    def __init__(self, pools: Sequence[ServerPool]) -> None:
        self.pools = list(pools)
        self.obs = NULL_TRACER                   # set by simulate_stream
        self.avail = np.array([p.next_free() for p in self.pools],
                              dtype=np.float64)

    @classmethod
    def uniform(cls, n_nodes: int, capacity: Optional[int],
                available_at: float = 0.0) -> "NodePools":
        return cls([ServerPool(capacity, available_at)
                    for _ in range(int(n_nodes))])

    def __len__(self) -> int:
        return len(self.pools)

    def wait(self, j: int, now: float) -> float:
        return self.pools[j].wait(now)

    def waits(self, now: float) -> np.ndarray:
        return np.maximum(self.avail - float(now), 0.0)

    def admit(self, j: int, now: float,
              service_s: float) -> tuple[float, float]:
        start, finish = self.pools[j].admit(now, service_s)
        self.avail[j] = self.pools[j].next_free()
        if self.obs.enabled and start > now:
            self.obs.instant(f"pool@{j}", "pool_wait", float(now),
                             args={"wait_s": start - now})
        return start, finish

    def recompute_avail(self) -> np.ndarray:
        """Full O(N·c) recompute — correctness cross-check for the
        incrementally-maintained ``avail`` cache."""
        return np.array([p.next_free() for p in self.pools],
                        dtype=np.float64)

    def utilisation(self, now: float) -> np.ndarray:
        return np.array([p.utilisation(now) for p in self.pools],
                        dtype=np.float64)

    def saturated(self, now: float, threshold: float = 0.9) -> np.ndarray:
        """Boolean mask of pools whose utilisation exceeds threshold."""
        return self.utilisation(now) > float(threshold)


# ---------------------------------------------------------------------------
# heavy-tailed delay processes

# Acklam's rational approximation to the inverse normal CDF (|eps| <
# 1.15e-9 over (0, 1)) — avoids a scipy dependency for the lognormal
# quantile.
_ACK_A = (-3.969683028665376e+01, 2.209460984245205e+02,
          -2.759285104469687e+02, 1.383577518672690e+02,
          -3.066479806614716e+01, 2.506628277459239e+00)
_ACK_B = (-5.447609879822406e+01, 1.615858368580409e+02,
          -1.556989798598866e+02, 6.680131188771972e+01,
          -1.328068155288572e+01)
_ACK_C = (-7.784894002430293e-03, -3.223964580411365e-01,
          -2.400758277161838e+00, -2.549732539343734e+00,
          4.374664141464968e+00, 2.938163982698783e+00)
_ACK_D = (7.784695709041462e-03, 3.224671290700398e-01,
          2.445134137142996e+00, 3.754408661907416e+00)


def _norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam approximation)."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0, 1), got {p}")
    a, b, c, d = _ACK_A, _ACK_B, _ACK_C, _ACK_D
    p_low, p_high = 0.02425, 1.0 - 0.02425
    if p < p_low:
        q = math.sqrt(-2.0 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q
                 + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0)
    if p > p_high:
        q = math.sqrt(-2.0 * math.log(1.0 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q
                  + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r
             + a[4]) * r + a[5]) * q / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
             + b[4]) * r + 1.0)


def _norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


class DelayProcess:
    """Protocol for seeded delay distributions (duck-typed).

    Implementations provide ``sample(n)``, ``mean()``,
    ``percentile(q)`` and ``cvar(alpha)``.
    """

    def sample(self, n: int = 1) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    def mean(self) -> float:  # pragma: no cover
        raise NotImplementedError

    def percentile(self, q: float) -> float:  # pragma: no cover
        raise NotImplementedError

    def cvar(self, alpha: float) -> float:  # pragma: no cover
        raise NotImplementedError

    def tail_stat(self, tail: str, alpha: float) -> float:
        """Dispatch helper: ``"p99"`` → percentile, ``"cvar"`` → CVaR."""
        if tail == "p99":
            return self.percentile(0.99)
        if tail == "cvar":
            return self.cvar(alpha)
        raise ValueError(f"unknown tail statistic {tail!r}; "
                         f"expected 'p99' or 'cvar'")


@dataclass
class WeibullRTT(DelayProcess):
    """Weibull-distributed round-trip delay, ``shape < 1`` heavy-tailed.

    ``sample`` draws ``scale * Weibull(shape)`` seconds.  Closed forms:
    mean = scale * Γ(1 + 1/shape); quantile
    ``scale * (-ln(1-q))^(1/shape)``; CVaR by trapezoidal quantile
    integration (the Weibull CVaR has no elementary closed form).
    """

    shape: float = 0.7
    scale: float = 0.01
    seed: Seed = 0

    def __post_init__(self) -> None:
        if self.shape <= 0.0 or self.scale < 0.0:
            raise ValueError("shape must be > 0 and scale >= 0")
        self._rng = np.random.default_rng(self.seed)

    def sample(self, n: int = 1) -> np.ndarray:
        return self.scale * self._rng.weibull(self.shape, size=int(n))

    def mean(self) -> float:
        return self.scale * math.gamma(1.0 + 1.0 / self.shape)

    def percentile(self, q: float) -> float:
        if not 0.0 <= q < 1.0:
            raise ValueError(f"q must be in [0, 1), got {q}")
        return self.scale * (-math.log(1.0 - q)) ** (1.0 / self.shape)

    def cvar(self, alpha: float = 0.99, n_grid: int = 512) -> float:
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        # CVaR_a = 1/(1-a) * ∫_a^1 quantile(u) du, trapezoid on a
        # uniform u-grid clipped just below 1.
        hi = 1.0 - (1.0 - alpha) * 1e-6
        us = np.linspace(alpha, hi, int(n_grid))
        qs = self.scale * (-np.log1p(-us)) ** (1.0 / self.shape)
        trapezoid = getattr(np, "trapezoid", np.trapz)
        return float(trapezoid(qs, us) / (hi - alpha))


@dataclass
class LognormalRTT(DelayProcess):
    """Lognormal round-trip delay — exp(N(mu, sigma^2)) seconds.

    All of mean / percentile / CVaR are closed-form:
    mean = exp(mu + sigma^2/2); quantile = exp(mu + sigma * z_q);
    CVaR_a = mean * Phi(sigma - z_a) / (1 - a).
    """

    mu: float = -5.0
    sigma: float = 1.0
    seed: Seed = 0

    def __post_init__(self) -> None:
        if self.sigma <= 0.0:
            raise ValueError("sigma must be > 0")
        self._rng = np.random.default_rng(self.seed)

    def sample(self, n: int = 1) -> np.ndarray:
        return self._rng.lognormal(self.mu, self.sigma, size=int(n))

    def mean(self) -> float:
        return math.exp(self.mu + 0.5 * self.sigma * self.sigma)

    def percentile(self, q: float) -> float:
        if not 0.0 < q < 1.0:
            raise ValueError(f"q must be in (0, 1), got {q}")
        return math.exp(self.mu + self.sigma * _norm_ppf(q))

    def cvar(self, alpha: float = 0.99) -> float:
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        z = _norm_ppf(alpha)
        return self.mean() * _norm_cdf(self.sigma - z) / (1.0 - alpha)


# ---------------------------------------------------------------------------
# queueing-theory closed forms (validation targets)


def erlang_c(c: int, a: float) -> float:
    """Erlang-C: P(wait > 0) for an M/M/c queue with offered load a.

    ``a = lambda / mu`` (erlangs); requires ``a < c`` for stability.
    """
    c = int(c)
    if c < 1:
        raise ValueError(f"c must be >= 1, got {c}")
    if not 0.0 <= a < c:
        raise ValueError(f"offered load a={a} must satisfy 0 <= a < c")
    if a == 0.0:
        return 0.0
    # sum_{k=0}^{c-1} a^k/k! computed iteratively to avoid overflow
    term = 1.0
    s = 1.0
    for k in range(1, c):
        term *= a / k
        s += term
    term_c = term * a / c  # a^c / c!
    top = term_c * c / (c - a)
    return top / (s + top)


def mm1_sojourn(lam: float, mu: float) -> float:
    """Mean sojourn (wait + service) for M/M/1: 1 / (mu - lambda)."""
    if lam >= mu:
        raise ValueError(f"unstable: lambda={lam} >= mu={mu}")
    return 1.0 / (mu - lam)


def mmc_sojourn(lam: float, mu: float, c: int) -> float:
    """Mean sojourn for M/M/c: Erlang-C wait + service.

    W = C(c, a) / (c*mu - lambda) + 1/mu, with a = lambda/mu.
    """
    a = lam / mu
    if a >= c:
        raise ValueError(f"unstable: offered load {a} >= c={c}")
    wq = erlang_c(int(c), a) / (c * mu - lam)
    return wq + 1.0 / mu
