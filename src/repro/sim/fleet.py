"""Fleet-scale time-slabbed simulation — the array-native twin of the
host event loop.

:func:`repro.sim.stream.simulate_stream` walks a heap one event at a
time: each arrival pays a Python ``etc_matrix`` row, each link tick a
per-process scalar ``step`` and a per-node spec rebuild, each completion
a ``TaskRecord``.  That per-event constant caps it around the ~300
in-flight tasks the streaming benchmarks measure.  This module drains
the same virtual timeline in *slabs* — the spans between link ticks,
inside which every per-node bandwidth is constant:

  * link drift: one batched ``step_batch`` per process for the whole
    run (:mod:`repro.sim.state`), giving the full ``[K, N]`` bandwidth
    trajectory and the per-tick changed-node masks as array ops;
  * arrivals: the ETC rows of every task arriving in a slab come from
    one broadcast over the slab's effective-bandwidth row;
  * offload splits: all tasks admitted in a slab that share a layer
    chain are decided in ONE ``decide_all`` call (``split_backend=``
    picks ``"numpy"``/``"jax"``/``"pallas"`` or ``"sharded"``, which
    runs the env axis ``shard_map``-sharded across the device mesh);
  * completions: telemetry lands as column batches
    (:meth:`repro.sim.telemetry.Telemetry.complete_arrays`), ordered by
    the exact (finish time, placement sequence) pop order of the heap.

The host loop stays the reference: :func:`simulate_fleet` is bit-for-bit
equal to it in f64 — same seeds, same arrival batching, same FIFO tie
semantics (pinned by the hypothesis equivalence suite in
``tests/test_fleet.py``).  Two orderings the heap makes implicit are
reproduced in closed form: arrivals always pop before a link tick at the
same instant (their sequence numbers predate every tick's), and a task
finishing exactly on a tick keeps that tick's re-push alive iff its
finish event was pushed after the tick (it arrived after the previous
tick).  A ``ParetoStreamScheduler`` re-picks against the live set, so
with ``split_planner=`` the timeline is replayed through a lightweight
heap (same (time, seq) discipline, none of the per-event rebuild work)
with slab-batched admissions.

Select it with ``simulate_stream(..., engine="fleet")``.  Inherently
sequential features are rejected rather than silently diverging:
``oracle=`` (its observations feed back into later placements),
``rebalance=True`` (migrations couple completions to placements), and
``cost=`` models (arbitrary host callables per arrival) all need
``engine="event"``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from repro.core import decisions as dec
from repro.core import scheduler as sch
from repro.core.offload import DEFAULT_EFFICIENCY
from repro.obs.trace import NULL_TRACER
from repro.sim.state import ClusterLinks, DriftingEnv
from repro.sim.telemetry import Telemetry

#: tick-chain generation block (amortises the cumsum over many slabs)
_TICK_CHUNK = 8192

#: singleton-batch placement runs at least this long lower to a jitted
#: lax.scan (below it, jit dispatch overhead beats the Python loop)
_SCAN_MIN = 512
#: fixed scan length — runs are chunked/padded to it so the jit compiles
#: once per fleet width, not once per run length
_SCAN_BLOCK = 4096
_SCAN_FNS: dict = {}


def _singleton_scan(n_nodes: int):
    """Jitted scan placing one run of singleton arrival batches.

    For a batch of one task, min-min and HEFT degenerate to the same
    update — ``fin = max(avail, t) + etc_row; j = argmin(fin);
    avail[j] = fin[j]`` — which under ``enable_x64`` is bit-for-bit the
    host's numpy arithmetic (IEEE elementwise ops, first-index argmin).
    Compiled once per fleet width; invalid (padding) steps carry
    ``avail`` through untouched.
    """
    fn = _SCAN_FNS.get(n_nodes)
    if fn is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def fn(avail, peak_eff, bwc_rows, ts, fls, ibs, segs, valid):
            def step(av, x):
                t, fl, ib, sg, ok = x
                etc_row = fl / peak_eff + ib / bwc_rows[sg]
                fin = jnp.maximum(av, t) + etc_row
                j = jnp.argmin(fin)
                start = jnp.maximum(av[j], t)
                av2 = jnp.where(ok, av.at[j].set(fin[j]), av)
                return av2, (j, start, fin[j], etc_row[j])
            return jax.lax.scan(step, avail, (ts, fls, ibs, segs, valid))
        _SCAN_FNS[n_nodes] = fn
    return fn


#: the bandwidth-row table is padded to a multiple of this so the jit
#: sees few distinct ``(n_nodes, K)`` shapes (one compile per bucket)
_ROW_PAD = 512


def _place_singleton_run(avail, peak_eff, ts, fls, ibs, bwc_rows, segs):
    """Run ``len(ts)`` singleton placements through the jitted scan,
    mutating ``avail`` in place.  ``bwc_rows`` is the small ``[K, N]``
    per-slab bandwidth table and ``segs`` the per-task row index — the
    row gather happens as a dynamic slice *inside* the scan, so the
    ``[n, N]`` expansion never materialises on the host.  Returns
    ``(j, start, finish, etc)`` host arrays, or ``None`` if jax is
    unavailable (callers fall back to the Python loop)."""
    try:
        import jax.numpy as jnp
        from jax.experimental import enable_x64
    except ImportError:                  # pragma: no cover - jax baked in
        return None
    n = len(ts)
    outs: list[tuple] = []
    with enable_x64():
        fn = _singleton_scan(avail.shape[0])
        av = jnp.asarray(avail)
        pk = jnp.asarray(peak_eff)
        k = bwc_rows.shape[0]
        k_pad = -(-k // _ROW_PAD) * _ROW_PAD
        rows = jnp.asarray(np.concatenate(
            [bwc_rows, np.ones((k_pad - k, bwc_rows.shape[1]))])
            if k_pad != k else bwc_rows)
        for lo in range(0, n, _SCAN_BLOCK):
            hi = min(lo + _SCAN_BLOCK, n)
            pad = _SCAN_BLOCK - (hi - lo)
            valid = np.zeros(_SCAN_BLOCK, bool)
            valid[:hi - lo] = True
            args = [np.concatenate([c[lo:hi],
                                    np.zeros((pad,) + c.shape[1:])])
                    if pad else c[lo:hi]
                    for c in (ts, fls, ibs)]
            sg = np.concatenate([segs[lo:hi], np.zeros(pad, np.intp)]) \
                if pad else segs[lo:hi]
            av, ys = fn(av, pk, rows, *args, sg, valid)
            outs.append(tuple(np.asarray(y)[:hi - lo] for y in ys))
        avail[:] = np.asarray(av)
    return tuple(np.concatenate([o[k] for o in outs]) for k in range(4))


def decide_all_sharded(layers, envs: dec.EnvArrays,
                       efficiency: float = DEFAULT_EFFICIENCY, *,
                       mesh=None) -> dec.DecisionPlan:
    """``decide_all`` with the environment axis sharded across devices.

    Wraps the jitted latency kernel in ``shard_map`` over ``mesh``
    (default: the repo's debug mesh over every visible device; a single
    device falls back to the plain jit path), padding the env axis to
    the shard count with :func:`repro.core.decisions.pad_envs` and
    trimming the results.  The maths is row-wise, so the result is
    bit-for-bit (f64) the numpy/jax ``decide_all``.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from jax.sharding import PartitionSpec as P

    from repro.kernels.decide_split import ops

    if mesh is None:
        n_dev = jax.device_count()
        if n_dev < 2:
            return dec.decide_all(layers, envs, efficiency, backend="jax")
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(n_dev)
    try:
        shard_map = jax.shard_map                    # jax >= 0.5
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    import inspect
    check_kw = ("check_vma" if "check_vma"
                in inspect.signature(shard_map).parameters
                else "check_rep")                    # pre-0.5 spelling
    axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    padded, n_orig = dec.pad_envs(envs, n_shards)
    flops, act = ops._layer_arrays(layers)
    dev, edge, bw, lat, inp, _, _ = ops._env_arrays(padded)
    env_spec = P(axes)                               # env axis over all
    with enable_x64():
        fn = shard_map(
            lambda f, a, d, e, b, l, i: ops._decide_latency(
                f, a, d, e, b, l, i, efficiency),
            mesh=mesh,
            in_specs=(P(), P(), env_spec, env_spec, env_spec, env_spec,
                      env_spec),
            out_specs=(env_spec,) * 5,
            **{check_kw: False})
        s, total, dev_s, xfer_s, edge_s = fn(
            *(jnp.asarray(x) for x in (flops, act, dev, edge, bw, lat,
                                       inp)))
        out = [np.asarray(x)[:n_orig]
               for x in (s, total, dev_s, xfer_s, edge_s)]
    return dec.DecisionPlan(np.asarray(out[0], np.int64),
                            *(np.asarray(x, np.float64)
                              for x in out[1:]))


def _split_decide(layers, envs, cost, backend) -> dec.DecisionPlan:
    if backend == "sharded":
        if cost is not None:
            raise ValueError("split_backend='sharded' supports the "
                             "analytic cost only (cost models lower via "
                             "backend='jax')")
        return decide_all_sharded(layers, envs)
    return dec.decide_all(layers, envs, cost=cost, backend=backend)


def simulate_fleet(tasks: Sequence[sch.Task], arrivals,
                   nodes: Sequence[sch.Node], *,
                   policy: str = "min_min", cost=None, oracle=None,
                   service_time_fn=None,
                   links: Optional[ClusterLinks] = None,
                   link_update_dt: float = 1.0,
                   split_planner=None,
                   split_env: Optional[DriftingEnv] = None,
                   split_layers=None, split_cost=None,
                   split_backend: str = "numpy",
                   rebalance: bool = False,
                   pools=None, rtt=None,
                   saturation_threshold: Optional[float] = None,
                   telemetry: Optional[Telemetry] = None,
                   obs=None) -> Telemetry:
    """Time-slabbed streaming simulation, bit-for-bit (f64) equal to
    ``simulate_stream(..., engine="event")`` on every supported
    configuration — see the module docstring for what is drained as
    array ops and why ``oracle=`` / ``rebalance=`` / ``cost=`` are
    rejected.  Normally reached via ``simulate_stream(...,
    engine="fleet")``.

    ``pools=`` routes placements through finite-capacity
    :class:`repro.sim.queueing.NodePools` (realised c-server busy
    state; the singleton-scan fast path is skipped since placements
    then depend on realised admissions), and ``rtt=`` adds one
    heavy-tailed network delay sample per task — both reproduce the
    host engine's draws exactly.  ``saturation_threshold=`` is
    rejected: the utilisation-edge trigger is inherently per-event.
    """
    if policy not in ("min_min", "heft"):
        raise ValueError(f"unknown policy {policy!r}; "
                         "use 'min_min' or 'heft'")
    if saturation_threshold is not None:
        raise ValueError(
            "engine='fleet' does not support saturation_threshold= — "
            "the pool-utilisation edge trigger fires mid-timeline, "
            "which is inherently per-event; use engine='event'")
    if pools is not None and len(pools) != len(nodes):
        raise ValueError(f"pools carries {len(pools)} pools for "
                         f"{len(nodes)} nodes")
    if oracle is not None:
        raise ValueError(
            "engine='fleet' does not support oracle= — online oracle "
            "observations feed back into later placements, which is "
            "inherently per-event; use engine='event'")
    if rebalance:
        raise ValueError(
            "engine='fleet' does not support rebalance=True — "
            "migrations couple completions back into placements; use "
            "engine='event'")
    if cost is not None:
        raise ValueError(
            "engine='fleet' vectorizes the analytic ETC only; cost= "
            "models run per-arrival on the host — use engine='event'")
    if split_planner is not None:
        if split_env is None or split_layers is None:
            raise ValueError("split_planner needs split_env= and "
                             "split_layers= (shared list or task -> "
                             "layers)")
        if not hasattr(split_planner, "admit_batch"):
            raise ValueError(
                "engine='fleet' needs a ParetoStreamScheduler-style "
                "planner (admit_batch / live); use engine='event' for "
                "custom planners")
        if split_cost is not None:
            raise ValueError("split_cost= only applies to the "
                             "decide-at-admission path (no "
                             "split_planner)")
    decide_splits = (split_planner is None and split_env is not None
                     and split_layers is not None)
    if split_cost is not None and not decide_splits:
        raise ValueError("split_cost= needs split_env= and "
                         "split_layers= without a split_planner")

    telemetry = telemetry if telemetry is not None else Telemetry()
    obs = obs if obs is not None else NULL_TRACER
    if pools is not None:
        pools.obs = obs
    if split_planner is not None:
        split_planner.telemetry = telemetry
        split_planner.obs = obs

    def layers_for(task: sch.Task):
        if callable(split_layers):
            return split_layers(task)
        return split_layers

    arrivals = np.asarray(arrivals, np.float64)
    if arrivals.shape != (len(tasks),):
        raise ValueError(
            f"arrivals must be [{len(tasks)}], got {arrivals.shape}")
    n_tasks = len(tasks)
    n_nodes = len(nodes)
    specs0 = [n.spec for n in nodes]
    node_names = [s.name for s in specs0]
    peak_eff = np.asarray([s.peak_flops_f32 for s in specs0],
                          np.float64) * DEFAULT_EFFICIENCY
    spec_bw0 = np.asarray([s.link_bw for s in specs0], np.float64)
    tdp = np.asarray([s.tdp_watts for s in specs0], np.float64)
    # with pools the availability vector IS the pools' earliest-free
    # cache (admissions update it in place), exactly as in the host
    # StreamScheduler
    avail = pools.avail if pools is not None else \
        np.asarray([n.available_at for n in nodes], np.float64).copy()
    flops_t = np.asarray([t.flops for t in tasks], np.float64)
    ib_t = np.asarray([t.input_bytes for t in tasks], np.float64)

    # array-native arrival batching: same stable argsort + exact-time
    # grouping as stream._batches_by_arrival, without materialising a
    # Python (time, members) list per batch
    order = np.argsort(arrivals, kind="stable")
    sorted_t = arrivals[order]
    if n_tasks:
        starts = np.flatnonzero(np.concatenate(
            ([True], sorted_t[1:] != sorted_t[:-1])))
        offsets = np.concatenate([starts, [n_tasks]]).astype(np.intp)
        batch_times = sorted_t[starts]
    else:
        offsets = np.zeros(1, np.intp)
        batch_times = np.zeros(0, np.float64)
    n_batches = len(batch_times)
    sizes = np.diff(offsets)
    last_arrival = float(batch_times[-1]) if n_batches else -np.inf
    drifting = (links is not None or split_env is not None) \
        and link_update_dt > 0
    dt = float(link_update_dt)

    # -- tick time chain: the exact floats of the host's `now + dt`
    # re-push arithmetic (cumsum is the same sequential accumulation)
    tick_times = np.zeros(0, np.float64)

    def ticks_until(t_lim: float, at_least: int = 1) -> None:
        nonlocal tick_times
        if not drifting:
            return
        while tick_times.size < at_least or tick_times[-1] < t_lim:
            last = float(tick_times[-1]) if tick_times.size else 0.0
            block = np.cumsum(np.concatenate(
                ([last], np.full(_TICK_CHUNK, dt))))[1:]
            if block[-1] <= last:
                raise RuntimeError(
                    f"link_update_dt={dt} cannot advance virtual time "
                    f"past {last} in float64")
            tick_times = np.concatenate([tick_times, block])

    # ticks strictly before the last arrival are exactly the ones any
    # placement can observe (an arrival at a tick's instant pops first:
    # its sequence number predates every tick's)
    ticks_until(last_arrival) if np.isfinite(last_arrival) else None
    k1 = int(np.searchsorted(tick_times, last_arrival, side="left")) \
        if drifting and n_tasks else 0

    # -- slab 1..k1 bandwidth trajectories: one batched step per process
    v0 = links.values() if links is not None else None
    traj1 = links.step_batch(dt, k1) if links is not None else None
    sp_v0 = split_env.link.value if split_env is not None else None
    sp1 = split_env.step_batch(dt, k1) if decide_splits else None

    # effective per-node bandwidth rows: a node's spec keeps its
    # original link_bw until the process value first CHANGES (the host
    # only rebuilds specs for changed nodes), then tracks the process
    if links is not None and k1:
        prev1 = np.vstack([v0[None, :], traj1[:-1]])
        changed1 = traj1 != prev1
        ever1 = np.logical_or.accumulate(changed1, axis=0)
        eff_rows = np.vstack([spec_bw0[None, :],
                              np.where(ever1, traj1, spec_bw0[None, :])])
    else:
        changed1 = None
        eff_rows = spec_bw0[None, :]
    bwc_rows = np.maximum(eff_rows, 1.0)

    # -- placement-time node specs (original spec until the link process
    # first changes, then the drifted bandwidth), cached per (node, bw)
    spec_cache: dict[tuple, object] = {}

    def spec_at(j: int, seg: int):
        bw = float(eff_rows[seg if links is not None else 0, j])
        spec = spec_cache.get((j, bw))
        if spec is None:
            spec = specs0[j] if bw == specs0[j].link_bw else \
                dataclasses.replace(specs0[j], link_bw=bw)
            spec_cache[(j, bw)] = spec
        return spec

    def pool_admit(rid: int, j: int, t: float, etc_v: float,
                   seg: int) -> tuple[float, float]:
        """StreamScheduler._admit op-for-op: realised service drawn at
        admission, pool updates ``avail`` in place."""
        service = etc_v
        if service_time_fn is not None:
            start_pred = max(pools.pools[j].next_free(), t)
            service = float(service_time_fn(tasks[rid], spec_at(j, seg),
                                            etc_v, start_pred))
        return pools.admit(j, t, service)

    # -- placements: per slab, ETC rows in one broadcast; the min-min /
    # HEFT rounds replicate StreamScheduler.on_arrivals op-for-op
    seg_of_batch = np.searchsorted(tick_times[:k1], batch_times,
                                   side="left")
    p_rid = np.empty(n_tasks, np.intp)     # all indexed by placement seq
    p_j = np.empty(n_tasks, np.intp)
    p_start = np.empty(n_tasks, np.float64)
    p_fin = np.empty(n_tasks, np.float64)  # believed finish
    p_etc = np.empty(n_tasks, np.float64)
    p_seg = np.empty(n_tasks, np.intp)
    min_min = policy == "min_min"
    # tick segment for split decisions (p_seg) vs the row the node
    # bandwidths come from (seg_etc): identical when links drift, but a
    # split-only run still advances through tick segments while every
    # ETC row keeps the static spec bandwidths
    seg_etc = seg_of_batch if links is not None \
        else np.zeros(n_batches, np.intp)
    nonsingle = np.flatnonzero(sizes != 1)
    pos = 0
    bi = 0
    while bi < n_batches:
        # realised pool admissions are sequential host state — the
        # jitted singleton scan only models the believed scalar queue
        if sizes[bi] == 1 and pools is None:
            nxt = np.searchsorted(nonsingle, bi)
            end = int(nonsingle[nxt]) if nxt < len(nonsingle) \
                else n_batches
            if end - bi >= _SCAN_MIN:
                rids = order[offsets[bi]:offsets[end]]  # one per batch
                res = _place_singleton_run(
                    avail, peak_eff, batch_times[bi:end], flops_t[rids],
                    ib_t[rids], bwc_rows, seg_etc[bi:end])
                if res is not None:
                    sl = slice(pos, pos + (end - bi))
                    p_rid[sl] = rids
                    p_j[sl], p_start[sl], p_fin[sl], p_etc[sl] = res
                    p_seg[sl] = seg_of_batch[bi:end]
                    pos += end - bi
                    bi = end
                    continue
        t = float(batch_times[bi])
        members = order[offsets[bi]:offsets[bi + 1]]
        s = int(seg_of_batch[bi])
        bwc = bwc_rows[int(seg_etc[bi])]
        etc = flops_t[members, None] / peak_eff[None, :] \
            + ib_t[members, None] / bwc[None, :]
        n_b = len(members)
        placed_rows: list[tuple] = []      # (row, node, start, fin, etc)
        if n_b == 1:
            fin_row = np.maximum(avail, t) + etc[0]
            j = int(np.argmin(fin_row))
            if pools is not None:
                start, finish = pool_admit(int(members[0]), j, t,
                                           float(etc[0, j]), s)
            elif min_min:
                start = float(np.maximum(avail[j], t))
                finish = float(fin_row[j])
                avail[j] = fin_row[j]
            else:                          # HEFT: start + float(etc)
                start = float(np.maximum(avail[j], t))
                finish = start + float(etc[0, j])
                avail[j] = finish
            placed_rows.append((0, j, start, finish, float(etc[0, j])))
        elif min_min:
            fin = np.maximum(avail, t)[None, :] + etc
            active = np.ones(n_b, bool)
            for _ in range(n_b):
                i, j = sch.masked_argmin(fin, active)
                if pools is not None:
                    start, finish = pool_admit(int(members[i]), j, t,
                                               float(etc[i, j]), s)
                else:
                    start = float(np.maximum(avail[j], t))
                    finish = float(fin[i, j])
                    avail[j] = fin[i, j]
                active[i] = False
                fin[:, j] = np.maximum(avail[j], t) + etc[:, j]
                placed_rows.append((i, j, start, finish,
                                    float(etc[i, j])))
        else:
            rank = np.argsort(-etc.mean(axis=1))
            for i in rank:
                i = int(i)
                j = int(np.argmin(np.maximum(avail, t) + etc[i]))
                if pools is not None:
                    start, finish = pool_admit(int(members[i]), j, t,
                                               float(etc[i, j]), s)
                else:
                    start = float(np.maximum(avail[j], t))
                    finish = start + float(etc[i, j])
                    avail[j] = finish
                placed_rows.append((i, j, start, finish,
                                    float(etc[i, j])))
        # map placements back to task indices FIFO per task object (the
        # same batch may carry one object twice)
        slots: dict[int, list[int]] = {}
        for rid in members:
            slots.setdefault(id(tasks[rid]), []).append(rid)
        for i, j, start, finish, etcv in placed_rows:
            p_rid[pos] = slots[id(tasks[members[i]])].pop(0)
            p_j[pos] = j
            p_start[pos] = start
            p_fin[pos] = finish
            p_etc[pos] = etcv
            p_seg[pos] = s
            pos += 1
        bi += 1
    if n_batches:
        telemetry.count("replans", n_batches)
        if obs.enabled:
            # same replan instants the host loop emits per arrive event,
            # as one deferred column batch (the 10%-overhead gate in
            # bench_fleet holds because the traced hot path only pays
            # appends, never a per-event Python loop)
            obs.instant_arrays("scheduler", "replan", batch_times,
                               args_cols={"batch": sizes})
    if min_min and n_tasks:
        telemetry.count("column_refreshes", n_tasks)

    # -- heavy-tailed network delay: one vectorized draw in placement
    # order (numpy Generators consume the bit stream identically for
    # sample(n) and n sequential sample(1) calls, and the RTT stream is
    # independent of the service stream, so this reproduces the host
    # engine's per-task draws exactly)
    rtt_draws = np.asarray(rtt.sample(n_tasks), np.float64) \
        if rtt is not None and n_tasks else None

    # -- realised finishes (with pools the realised service was already
    # consumed at admission, so the believed finish IS realised; else
    # the ground-truth seam runs per task against the placement slab's
    # effective-bandwidth spec)
    if pools is not None or service_time_fn is None:
        fin_real = p_fin if rtt_draws is None else p_fin + rtt_draws
    else:
        fin_real = np.empty(n_tasks, np.float64)
        for p in range(n_tasks):
            fin_real[p] = p_start[p] + float(service_time_fn(
                tasks[int(p_rid[p])], spec_at(int(p_j[p]),
                                              int(p_seg[p])),
                float(p_etc[p]), float(p_start[p])))
        if rtt_draws is not None:
            fin_real = fin_real + rtt_draws

    # -- how many ticks actually pop: every tick < T* re-pushes its
    # successor (arrivals or live tasks remain), the first tick >= T*
    # pops and usually stops; a task finishing exactly on it keeps one
    # more tick alive iff its finish event outranks the tick (arrived
    # after the previous tick)
    if drifting:
        t_star = max(last_arrival, float(fin_real.max())) if n_tasks \
            else -np.inf
        ticks_until(t_star) if np.isfinite(t_star) else ticks_until(0.0)
        k_low = int(np.searchsorted(tick_times, t_star, side="left")) \
            if n_tasks else 0
        k_pop = k_low + 1
        if n_tasks:
            t_bound = float(tick_times[k_low])
            t_prev = float(tick_times[k_low - 1]) if k_low else -np.inf
            ties = fin_real == t_bound
            if ties.any() and (arrivals[p_rid[ties]] > t_prev).any():
                k_pop += 1
    else:
        k_pop = 0

    # -- remaining link drift + per-tick changed-node refresh counts,
    # all as array ops over the [K, N] trajectory
    if links is not None and k_pop:
        traj2 = links.step_batch(dt, k_pop - k1)
        prev_last = traj1[-1] if k1 else v0
        changed2 = traj2 != np.vstack([prev_last[None, :], traj2[:-1]])
        n_refresh = int(changed2.sum()) \
            + (int(changed1.sum()) if changed1 is not None else 0)
        if n_refresh:
            telemetry.count("link_refreshes", n_refresh)
        if obs.enabled:
            per_tick = np.concatenate(
                [changed1.sum(axis=1) if changed1 is not None
                 else np.zeros(0, np.int64), changed2.sum(axis=1)])
            drifted = np.flatnonzero(per_tick)
            obs.instant_arrays("scheduler", "link_drift",
                               tick_times[drifted],
                               args_cols={"nodes": per_tick[drifted]})

    # -- offload splits
    split_by_rid: Optional[list] = None
    switches_by_rid: Optional[list] = None
    if decide_splits and n_tasks:
        split_env.step_batch(dt, k_pop - k1)     # advance to end state
        lay_by_rid = [layers_for(t) for t in tasks]
        groups: dict[tuple, list[int]] = {}
        for p in range(n_tasks):
            key = (int(p_seg[p]), id(lay_by_rid[int(p_rid[p])]))
            groups.setdefault(key, []).append(p)
        split_by_rid = [None] * n_tasks
        for (s, _lid), plist in groups.items():
            rids = p_rid[plist]
            lay = lay_by_rid[int(rids[0])]
            bw = sp_v0 if s == 0 else float(sp1[s - 1])
            envs = dec.make_envs(
                split_env.device, split_env.edge,
                link_bw=np.full(len(plist), bw),
                link_latency_s=split_env.link_latency_s,
                input_bytes=ib_t[rids])
            plan = _split_decide(lay, envs, split_cost, split_backend)
            for k, rid in enumerate(rids):
                split_by_rid[int(rid)] = int(plan.splits[k])
        telemetry.count("split_decides", n_tasks)
    elif split_planner is None and split_env is not None:
        split_env.step_batch(dt, k_pop)          # advance-only

    # -- planner replay: same (time, seq) heap discipline as the host,
    # but each pop is only the planner work — admissions slab-batched
    # per layer chain, completions pop the live state directly
    if split_planner is not None:
        split_by_rid = [None] * n_tasks
        switches_by_rid = [0] * n_tasks
        heap: list[tuple] = []
        seq = 0
        for bi in range(n_batches):
            heap.append((float(batch_times[bi]), seq, 0, bi))  # 0: arrive
            seq += 1
        if drifting:
            ticks_until(0.0)
            heap.append((float(tick_times[0]), seq, 2, 0))  # kind 2: link
            seq += 1
        heapq.heapify(heap)
        to_arrive = n_tasks
        live = 0
        ticks_done = 0
        while heap:
            t, _s, kind, payload = heapq.heappop(heap)
            if kind == 0:                        # arrive
                lo, hi = int(offsets[payload]), int(offsets[payload + 1])
                for p in range(lo, hi):          # finishes in place order
                    heapq.heappush(heap, (float(fin_real[p]), seq, 1, p))
                    seq += 1
                to_arrive -= hi - lo
                live += hi - lo
                order_keys: list[int] = []
                groups = {}
                for p in range(lo, hi):
                    rid = int(p_rid[p])
                    lay = layers_for(tasks[rid])
                    if id(lay) not in groups:
                        groups[id(lay)] = (lay, [])
                        order_keys.append(id(lay))
                    groups[id(lay)][1].append(rid)
                bw = split_env.link_bw
                for key in order_keys:
                    lay, rids = groups[key]
                    split_planner.admit_batch(
                        rids, lay, bw,
                        input_bytes=[tasks[r].input_bytes for r in rids],
                        now=t,
                        deadlines_s=[tasks[r].deadline_s for r in rids])
            elif kind == 1:                      # finish
                rid = int(p_rid[payload])
                st = split_planner.live.pop(rid)
                split_by_rid[rid] = st.pick
                switches_by_rid[rid] = st.switches
                live -= 1
            else:                                # link tick
                ticks_done += 1
                split_env.step(dt)
                split_planner.on_link(split_env.link_bw, now=t)
                if to_arrive > 0 or live > 0:
                    ticks_until(0.0, at_least=ticks_done + 1)
                    heapq.heappush(
                        heap, (float(tick_times[ticks_done]), seq, 2,
                               ticks_done))
                    seq += 1
        if drifting and ticks_done != k_pop:     # internal invariant
            raise AssertionError(
                f"fleet tick replay diverged from the closed form: "
                f"{ticks_done} ticks popped, expected {k_pop}")

    # -- telemetry: one column batch, in the exact pop order of the
    # host's finish events — (realised finish, placement seq)
    if n_tasks:
        ord_p = np.argsort(fin_real, kind="stable")
        rid_o = p_rid[ord_p]
        energy = (fin_real - p_start) * tdp[p_j]
        telemetry.complete_arrays(
            [tasks[r].name for r in rid_o],
            arrivals[rid_o], p_start[ord_p], fin_real[ord_p],
            node=[node_names[j] for j in p_j[ord_p]],
            node_id=p_j[ord_p],
            deadline_s=[tasks[r].deadline_s for r in rid_o],
            energy_j=energy[ord_p],
            split=None if split_by_rid is None
            else [split_by_rid[r] for r in rid_o],
            switches=None if switches_by_rid is None
            else [switches_by_rid[r] for r in rid_o],
            transfer_s=None if rtt_draws is None else rtt_draws[ord_p])
        if obs.enabled:
            # lifecycle spans as one deferred column batch, in the same
            # completion order the host engine emits them; deadline and
            # split ride as sojourn args so the analyze layer can
            # classify misses (None entries drop per row)
            args_cols = {
                "deadline_s": [tasks[r].deadline_s for r in rid_o],
                "split": None if split_by_rid is None
                else [split_by_rid[r] for r in rid_o],
            }
            obs.span_arrays(
                [f"{node_names[j]}@{j}" for j in p_j[ord_p]],
                rid_o, [tasks[r].name for r in rid_o],
                arrivals[rid_o], p_start[ord_p], fin_real[ord_p],
                transfer_s=None if rtt_draws is None
                else rtt_draws[ord_p],
                args_cols={k: v for k, v in args_cols.items()
                           if v is not None})
    return telemetry
