"""Drifting environment state: link bandwidth / backlog processes.

The batch decision core freezes the world at t=0; this module is what
un-freezes it.  Each :class:`LinkProcess` is a seeded stochastic process
advanced by ``step(dt)`` between events:

  * :class:`RandomWalkLink`  — geometric (log-space) random walk, the
                               slow-fading "drifting 6G link"
  * :class:`TwoStateLink`    — Gilbert–Elliott good/bad channel with
                               exponential dwell times (bursty outages)
  * :class:`DiurnalLink`     — deterministic sinusoid × optional
                               log-normal noise (cell-load tide)
  * :class:`FixedLink`       — constant (the static-world pin used by
                               the equivalence tests)

:class:`DriftingEnv` snapshots the current state into the exact
:class:`repro.core.decisions.EnvArrays` the batch core consumes, so
``decide_all`` / ``sweep_links`` and the jit/Pallas backends from the
kernel layer are reused *unchanged* — the simulator never forks the
decision math.  :class:`ClusterLinks` carries one process per scheduler
node for the ``[T, N]`` streaming placement path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.decisions import EnvArrays, make_envs
from repro.hw import DeviceSpec


@runtime_checkable
class LinkProcess(Protocol):
    """A seeded bandwidth process: ``value`` is the current bytes/s,
    ``step(dt)`` advances virtual time and returns the new value.

    The concrete processes below additionally implement
    ``step_batch(dt, n)`` — ``n`` steps of ``dt`` as one ``[n]`` array,
    bit-for-bit the values (and the RNG stream) of ``n`` scalar
    ``step(dt)`` calls.  It is intentionally *not* part of the protocol
    so user-defined processes keep working; :func:`step_batch` below
    falls back to the scalar loop for them."""

    @property
    def value(self) -> float: ...

    def step(self, dt: float) -> float: ...


def step_batch(process: LinkProcess, dt: float, n: int) -> np.ndarray:
    """``n`` steps of ``dt`` on ``process`` as one ``[n]`` float64 array.

    Dispatches to the process's vectorized ``step_batch`` when it has
    one (every process in this module does), else loops the scalar
    ``step`` — either way the values and the process's end state are
    bit-for-bit identical to ``n`` scalar calls."""
    fn = getattr(process, "step_batch", None)
    if fn is not None:
        return np.asarray(fn(dt, int(n)), np.float64)
    return np.asarray([process.step(dt) for _ in range(int(n))],
                      np.float64)


@dataclasses.dataclass
class FixedLink:
    """Constant bandwidth — the degenerate static-world process."""
    bw: float

    @property
    def value(self) -> float:
        return float(self.bw)

    def step(self, dt: float) -> float:
        return self.value

    def step_batch(self, dt: float, n: int) -> np.ndarray:
        return np.full(int(n), self.value, np.float64)


@dataclasses.dataclass
class RandomWalkLink:
    """Geometric random walk: ``log bw`` takes N(0, sigma²·dt) steps,
    clipped to ``[min_bw, max_bw]`` — slow fading around ``base_bw``."""
    base_bw: float
    sigma: float = 0.3           # log-space std per sqrt(second)
    min_bw: float = 1e4
    max_bw: float = 1e11
    seed: "int | np.random.SeedSequence" = 0

    def __post_init__(self):
        if not self.min_bw <= self.base_bw <= self.max_bw:
            raise ValueError("need min_bw <= base_bw <= max_bw")
        self._rng = np.random.default_rng(self.seed)
        self._log = math.log(self.base_bw)

    @property
    def value(self) -> float:
        return float(math.exp(self._log))

    def step(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        self._log += float(self._rng.normal(0.0,
                                            self.sigma * math.sqrt(dt)))
        self._log = min(max(self._log, math.log(self.min_bw)),
                        math.log(self.max_bw))
        return self.value

    def step_batch(self, dt: float, n: int) -> np.ndarray:
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        n = int(n)
        if n == 0:
            return np.zeros(0, np.float64)
        # one vectorized draw is the same RNG stream as n scalar draws;
        # cumsum is the same float ordering as sequential accumulation
        draws = self._rng.normal(0.0, self.sigma * math.sqrt(dt), size=n)
        logs = np.cumsum(np.concatenate(([self._log], draws)))[1:]
        lo = math.log(self.min_bw)
        hi = math.log(self.max_bw)
        if lo <= logs.min() and logs.max() <= hi:
            self._log = float(logs[-1])
            # math.exp, not np.exp: numpy's SIMD exp rounds differently
            # from libm on some platforms, and `value` uses math.exp
            return np.asarray([math.exp(x) for x in logs], np.float64)
        # a clip fired somewhere along the walk, so the later prefix
        # sums are wrong.  Accept clip-free prefixes in vectorized
        # chunks (doubling while clean, resetting after a clip): a
        # mostly-clean walk stays O(n), a boundary-pinned one degrades
        # to short lookaheads instead of a full scalar replay.
        out = np.empty(n, np.float64)
        log = self._log
        k = 0
        chunk = 32
        while k < n:
            m = min(chunk, n - k)
            logs = np.cumsum(np.concatenate(([log],
                                             draws[k:k + m])))[1:]
            bad = (logs < lo) | (logs > hi)
            if bad.any():
                b = int(np.argmax(bad))
                out[k:k + b] = [math.exp(x) for x in logs[:b]]
                # cumsum[b] == cumsum[b-1] + draw exactly, so clipping
                # it reproduces the scalar step
                log = min(max(float(logs[b]), lo), hi)
                out[k + b] = math.exp(log)
                k += b + 1
                chunk = 32
            else:
                out[k:k + m] = [math.exp(x) for x in logs]
                log = float(logs[-1])
                k += m
                chunk = min(chunk * 2, 4096)
        self._log = log
        return out


@dataclasses.dataclass
class TwoStateLink:
    """Gilbert–Elliott channel: good/bad bandwidth with exponential
    dwell times (means ``mean_good_s`` / ``mean_bad_s``)."""
    good_bw: float
    bad_bw: float
    mean_good_s: float = 5.0
    mean_bad_s: float = 1.0
    seed: "int | np.random.SeedSequence" = 0

    def __post_init__(self):
        if self.mean_good_s <= 0 or self.mean_bad_s <= 0:
            raise ValueError("dwell-time means must be positive")
        self._rng = np.random.default_rng(self.seed)
        self.good = True
        self._remaining = float(self._rng.exponential(self.mean_good_s))

    @property
    def value(self) -> float:
        return float(self.good_bw if self.good else self.bad_bw)

    def step(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        dt = float(dt)
        while dt >= self._remaining:       # may switch several times
            dt -= self._remaining
            self.good = not self.good
            mean = self.mean_good_s if self.good else self.mean_bad_s
            self._remaining = float(self._rng.exponential(mean))
        self._remaining -= dt
        return self.value

    def step_batch(self, dt: float, n: int) -> np.ndarray:
        # the dwell chain consumes a data-dependent number of RNG draws
        # per step, so there is no safe vectorized form — the scalar
        # loop is the bit-for-bit reference
        return np.asarray([self.step(dt) for _ in range(int(n))],
                          np.float64)


@dataclasses.dataclass
class DiurnalLink:
    """Sinusoidal capacity tide around ``base_bw`` with optional
    multiplicative log-normal noise — the diurnal cell-load model."""
    base_bw: float
    amplitude: float = 0.5       # fraction of base_bw, in [0, 1)
    period_s: float = 60.0
    noise_sigma: float = 0.0     # log-space noise std per step
    phase: float = 0.0
    seed: "int | np.random.SeedSequence" = 0

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        self._rng = np.random.default_rng(self.seed)
        self._t = 0.0
        self._noise = 1.0

    @property
    def value(self) -> float:
        tide = 1.0 + self.amplitude * math.sin(
            2.0 * math.pi * self._t / self.period_s + self.phase)
        return float(self.base_bw * tide * self._noise)

    def step(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        self._t += float(dt)
        if self.noise_sigma > 0:
            self._noise = float(np.exp(
                self._rng.normal(0.0, self.noise_sigma)))
        return self.value

    def step_batch(self, dt: float, n: int) -> np.ndarray:
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        n = int(n)
        if n == 0:
            return np.zeros(0, np.float64)
        # the time axis is the same float chain as repeated `_t += dt`
        ts = np.cumsum(np.concatenate(([self._t],
                                       np.full(n, float(dt)))))[1:]
        if self.noise_sigma > 0:
            noises = np.exp(self._rng.normal(0.0, self.noise_sigma,
                                             size=n))
            self._noise = float(noises[-1])
        else:
            noises = np.full(n, self._noise)
        # np.sin matches math.sin bit-for-bit on this platform (unlike
        # np.exp), so the tide vectorizes with the exact scalar
        # expression `value` uses
        tides = 1.0 + self.amplitude * np.sin(
            2.0 * math.pi * ts / self.period_s + self.phase)
        self._t = float(ts[-1])
        return self.base_bw * tides * noises


# --------------------------------------------------------------------------
# Snapshots into the batch decision core's EnvArrays
# --------------------------------------------------------------------------
@dataclasses.dataclass
class DriftingEnv:
    """One device↔edge pair whose link drifts over virtual time.

    ``snapshot()`` freezes the current state into an
    :class:`EnvArrays` (``E = len(input_bytes)``; scalar input gives
    ``E = 1``) so every existing consumer — ``decide_all``,
    ``sweep_links``, the cost models, the jit/Pallas kernels — runs on
    live state without modification.

    Snapshots are cached per (link observation, input-bytes) pair: a
    static link snapshots each distinct input size exactly once however
    many events fire, and any link movement invalidates the whole cache
    (``EnvArrays`` is frozen, so sharing the cached instance is safe).
    """
    device: DeviceSpec
    edge: DeviceSpec
    link: LinkProcess
    link_latency_s: float = 0.005
    input_bytes: float = 0.0
    _snap_bw: Optional[float] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _snap_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    def step(self, dt: float) -> float:
        return self.link.step(dt)

    def step_batch(self, dt: float, n: int) -> np.ndarray:
        """``[n]`` bandwidth trajectory: ``n`` link steps of ``dt``,
        bit-for-bit the scalar ``step`` loop (see :func:`step_batch`)."""
        return step_batch(self.link, dt, n)

    @property
    def link_bw(self) -> float:
        return self.link.value

    def snapshot(self, input_bytes=None) -> EnvArrays:
        ib = self.input_bytes if input_bytes is None else input_bytes
        ib = np.atleast_1d(np.asarray(ib, np.float64))
        bw = self.link.value
        if bw != self._snap_bw:          # link moved: every row is stale
            self._snap_cache.clear()
            self._snap_bw = bw
        key = (ib.shape, ib.tobytes())
        envs = self._snap_cache.get(key)
        if envs is None:
            if len(self._snap_cache) >= 512:
                self._snap_cache.clear()
            envs = make_envs(self.device, self.edge,
                             link_bw=np.full(ib.shape, bw),
                             link_latency_s=self.link_latency_s,
                             input_bytes=ib)
            self._snap_cache[key] = envs
        return envs


class ClusterLinks:
    """Per-node uplink processes for the streaming placement path.

    ``step(dt)`` advances every node's process and returns the ``[N]``
    bandwidth vector; ``changed(prev)`` gives the node indices whose
    bandwidth moved — the columns the incremental scheduler refreshes.
    """

    def __init__(self, processes: Sequence[LinkProcess]):
        if not processes:
            raise ValueError("need at least one link process")
        self.processes = list(processes)

    @classmethod
    def random_walk(cls, base_bws: Sequence[float], *, sigma: float = 0.3,
                    seed=0) -> "ClusterLinks":
        """Per-node random-walk links.  ``seed`` may be an ``int``
        (historical ``seed + j`` per-node streams, unchanged) or a
        ``np.random.SeedSequence`` whose spawned children seed each
        node independently."""
        if isinstance(seed, np.random.SeedSequence):
            kids = seed.spawn(len(list(base_bws)))
            return cls([RandomWalkLink(float(bw), sigma=sigma, seed=kid)
                        for kid, bw in zip(kids, base_bws)])
        return cls([RandomWalkLink(float(bw), sigma=sigma, seed=seed + j)
                    for j, bw in enumerate(base_bws)])

    def __len__(self) -> int:
        return len(self.processes)

    def values(self) -> np.ndarray:
        return np.asarray([p.value for p in self.processes], np.float64)

    def step(self, dt: float) -> np.ndarray:
        return np.asarray([p.step(dt) for p in self.processes],
                          np.float64)

    def step_batch(self, dt: float, n: int) -> np.ndarray:
        """``[n, N]`` bandwidth trajectory: every node advanced ``n``
        steps of ``dt`` in one vectorized draw per process — row ``k``
        is bit-for-bit what the ``k+1``-th ``step(dt)`` would return."""
        return np.stack([step_batch(p, dt, n) for p in self.processes],
                        axis=1)
