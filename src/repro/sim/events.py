"""Virtual clock, event heap, and arrival processes (the sim's time axis).

Everything in :mod:`repro.sim` is *event-driven*: a :class:`Clock` holds
virtual time, an :class:`EventQueue` orders (time, kind, payload) events
with FIFO tie-breaking, and the arrival-process generators below produce
the task arrival times that drive the streaming schedulers.  All
processes are seeded and fully deterministic — the same seed replays the
same run, which is what makes the sim smoke tests and the incremental-
vs-from-scratch benchmarks reproducible.

Every ``seed=`` parameter accepts ``int | np.random.SeedSequence``.
Passing an ``int`` reproduces the historical stream bit-for-bit
(``default_rng(int)`` builds ``SeedSequence(int)`` internally); for
multi-process runs, spawn independent children of the run seed with
:func:`repro.sim.queueing.spawn_streams` and hand one child to each
process (arrivals, link drift, RTT) — adding a new process then never
perturbs the draws of existing ones.

Arrival processes:

  * :func:`poisson_arrivals`  — homogeneous Poisson (exponential gaps)
  * :func:`trace_arrivals`    — replay a recorded timestamp trace
  * :func:`mmpp_arrivals`     — Markov-modulated Poisson (bursty: the
                                rate switches between states with
                                exponential dwell times)
  * :func:`diurnal_arrivals`  — sinusoidal rate (day/night load curve),
                                sampled by Lewis–Shedler thinning

The :class:`Clock` is also the seam :class:`repro.serve.continuous.
ContinuousBatchEngine` uses for arrival-time admission: inject one clock
into the engine and the simulator and both see the same virtual time.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Iterable, Optional, Union

import numpy as np

#: processes accept either a plain int (historical stream, unchanged)
#: or a spawned ``SeedSequence`` child (independent stream)
Seed = Union[int, np.random.SeedSequence]


class Clock:
    """Monotonic virtual time in seconds."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds (``dt < 0`` is an error)."""
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._now += float(dt)
        return self._now

    def advance_to(self, t: float) -> float:
        """Jump forward to absolute time ``t`` (no-op if already past)."""
        self._now = max(self._now, float(t))
        return self._now

    def __repr__(self) -> str:
        return f"Clock(now={self._now:.6f})"


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled event: ``kind`` names the handler, ``payload`` is
    handler-specific (task indices, a node id, ...)."""
    time: float
    kind: str
    payload: Any = None


class EventQueue:
    """Min-heap of events ordered by time, FIFO among equal times."""

    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str, payload: Any = None) -> Event:
        ev = Event(float(time), kind, payload)
        heapq.heappush(self._heap, (ev.time, next(self._seq), ev))
        return ev

    def push_batch(self, times, kind: str,
                   payloads: Optional[Iterable[Any]] = None) -> list[Event]:
        """Bulk push: one O(n) ``heapify`` instead of n O(log n)
        sift-ups — the arrival-seeding fast path.  Sequence numbers are
        assigned in input order, so FIFO tie-breaking is identical to n
        ``push`` calls (pops interleave correctly with earlier and later
        pushes because the (time, seq) order is total)."""
        times = list(times)
        payloads = list(payloads) if payloads is not None \
            else [None] * len(times)
        if len(payloads) != len(times):
            raise ValueError(f"got {len(times)} times but "
                             f"{len(payloads)} payloads")
        evs = [Event(float(t), kind, p) for t, p in zip(times, payloads)]
        self._heap.extend((ev.time, next(self._seq), ev) for ev in evs)
        heapq.heapify(self._heap)
        return evs

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


# --------------------------------------------------------------------------
# Arrival processes (all return sorted float64 arrays of absolute times)
# --------------------------------------------------------------------------
def poisson_arrivals(rate: float, *, n: Optional[int] = None,
                     horizon: Optional[float] = None, seed: "Seed" = 0,
                     start: float = 0.0) -> np.ndarray:
    """Homogeneous Poisson arrivals at ``rate`` events/s.

    Exactly one of ``n`` (event count) or ``horizon`` (duration in
    seconds, events strictly before ``start + horizon``) must be given.
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if (n is None) == (horizon is None):
        raise ValueError("give exactly one of n= or horizon=")
    rng = np.random.default_rng(seed)
    if n is not None:
        return start + np.cumsum(rng.exponential(1.0 / rate, size=int(n)))
    out: list[np.ndarray] = []
    t = 0.0
    chunk = max(int(rate * horizon * 1.5) + 16, 64)
    while t < horizon:
        gaps = rng.exponential(1.0 / rate, size=chunk)
        times = t + np.cumsum(gaps)
        out.append(times)
        t = float(times[-1])
    all_t = np.concatenate(out)
    return start + all_t[all_t < horizon]


def trace_arrivals(times: Iterable[float]) -> np.ndarray:
    """Replay a recorded arrival trace (validated sorted, finite, ≥ 0)."""
    t = np.asarray(list(times) if not isinstance(times, np.ndarray)
                   else times, np.float64)
    if t.ndim != 1:
        raise ValueError(f"trace must be 1-D, got shape {t.shape}")
    if t.size and (not np.isfinite(t).all() or (t < 0).any()):
        raise ValueError("trace times must be finite and non-negative")
    if t.size > 1 and (np.diff(t) < 0).any():
        raise ValueError("trace times must be sorted ascending")
    return t


def mmpp_arrivals(rates, dwell_s, *, horizon: float, seed: "Seed" = 0,
                  start: float = 0.0) -> np.ndarray:
    """Markov-modulated Poisson arrivals over ``[0, horizon)``.

    The process cycles through ``len(rates)`` states; state ``k`` emits a
    Poisson stream at ``rates[k]`` events/s for an exponential dwell of
    mean ``dwell_s[k]`` seconds.  Two states (quiet/burst) give the
    classic bursty 6G cell-load model; more states make a cycle.
    """
    rates = np.asarray(rates, np.float64)
    dwell = np.broadcast_to(np.asarray(dwell_s, np.float64), rates.shape)
    if rates.size == 0 or (rates < 0).any() or (dwell <= 0).any():
        raise ValueError("need ≥1 state, rates ≥ 0, dwell times > 0")
    rng = np.random.default_rng(seed)
    out: list[float] = []
    t, state = 0.0, 0
    while t < horizon:
        end = min(t + rng.exponential(dwell[state]), horizon)
        r = rates[state]
        if r > 0:
            tt = t + rng.exponential(1.0 / r)
            while tt < end:
                out.append(tt)
                tt += rng.exponential(1.0 / r)
        t = end
        state = (state + 1) % rates.size
    return start + np.asarray(out, np.float64)


def diurnal_arrivals(base_rate: float, *, horizon: float,
                     amplitude: float = 0.5, period_s: float = 60.0,
                     phase: float = 0.0, seed: "Seed" = 0,
                     start: float = 0.0) -> np.ndarray:
    """Sinusoidal-rate Poisson arrivals (the day/night load curve).

    Instantaneous rate ``base_rate * (1 + amplitude * sin(2πt/period +
    phase))``, sampled by thinning against the peak rate, so the output
    is an exact inhomogeneous Poisson draw.
    """
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    if base_rate <= 0 or period_s <= 0:
        raise ValueError("base_rate and period_s must be positive")
    rng = np.random.default_rng(seed)
    rate_max = base_rate * (1.0 + amplitude)
    out: list[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_max)
        if t >= horizon:
            break
        r = base_rate * (1.0 + amplitude
                         * np.sin(2.0 * np.pi * t / period_s + phase))
        if rng.random() * rate_max < r:
            out.append(t)
    return start + np.asarray(out, np.float64)
