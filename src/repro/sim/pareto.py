"""Pareto-aware streaming split planning: keep every live task's
(latency, energy, price) Pareto front alive and re-pick along it as the
link drifts, instead of committing to one scalarisation at admission.

This closes the seam PR 2 opened: :class:`repro.core.costs.
CompositeCost` can *extract* a per-environment Pareto front, but every
batch consumer immediately collapses it with one weighted argmin and
commits.  Under drifting 6G link state that committed split goes stale —
the split that was latency-optimal on a fast link ships too many bytes
once the link degrades.  :class:`ParetoStreamScheduler` instead:

  * at admission, computes the task's full ``[L+1, K]`` component
    matrix, extracts the non-dominated front over the configured
    ``pareto_objectives``, and picks the scalarised argmin *restricted
    to the front* (:func:`repro.core.costs.pareto_pick`);
  * on every link observation, recomputes the components of all live
    tasks in ONE batched ``cost.components`` call per distinct layer
    chain (the environments stack into one
    :class:`repro.core.decisions.EnvArrays`), re-extracts the current
    fronts, and re-picks — counting a *switch* whenever a task's chosen
    split moves;
  * verifies (``verify=True``, cheap) that every pick is on the current
    non-dominated front before accepting it.

Completion returns the realised components of both the live pick and
the admission-time pick under the *final* link state, so policies
("re-pick along the front" vs "commit at admission") can be compared on
what the task actually experienced.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import costs as co
from repro.core.decisions import make_envs
from repro.core.offload import LayerCost
from repro.hw import DeviceSpec, get_device
from repro.obs.trace import NULL_TRACER
from repro.sim.telemetry import Telemetry

#: default objective subset the domination test runs on (deadline slack
#: stays in the scalarisation but not the front, per the paper's
#: latency/energy/price trade-off)
PARETO_OBJECTIVES = ("latency_s", "energy_j", "price")


@dataclasses.dataclass
class SplitState:
    """One live task's split plan."""
    rid: int
    layers: Sequence[LayerCost]
    input_bytes: float
    deadline_s: Optional[float]
    pick: int                        # current split on the live front
    admission_pick: int
    front_size: int
    switches: int = 0
    history: list = dataclasses.field(default_factory=list)


class ParetoStreamScheduler:
    """Online device↔edge split planner that re-picks along live
    Pareto fronts.

    ``cost`` must expose the multi-objective ``components`` /
    ``objectives`` / ``scalarize`` surface (default: an equal-weight
    :class:`repro.core.costs.CompositeCost` over the analytic base);
    ``pareto_objectives`` names the objectives the domination test uses.
    """

    def __init__(self, cost=None, *, device: Optional[DeviceSpec] = None,
                 edge: Optional[DeviceSpec] = None,
                 pareto_objectives: Sequence[str] = PARETO_OBJECTIVES,
                 link_latency_s: float = 0.005, verify: bool = True,
                 telemetry: Optional[Telemetry] = None):
        self.cost = cost if cost is not None else co.CompositeCost(
            price_per_edge_s=0.1, price_per_gb=0.02)
        missing = set(pareto_objectives) - set(self.cost.objectives)
        if missing:
            raise KeyError(
                f"pareto objectives {sorted(missing)} not produced by "
                f"{type(self.cost).__name__} "
                f"(objectives: {list(self.cost.objectives)})")
        self.pareto_objectives = tuple(pareto_objectives)
        self.device = device or get_device("jetson-orin-nano")
        self.edge = edge or get_device("edge-server-a100")
        self.link_latency_s = link_latency_s
        self.verify = verify
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.obs = NULL_TRACER                   # set by simulate_stream
        self.live: dict[int, SplitState] = {}
        self.total_repicks = 0
        self.total_switches = 0
        self._env_key: Optional[tuple] = None    # one-slot env cache
        self._env_cache = None

    # -- internals --------------------------------------------------------
    def _envs(self, link_bw, input_bytes) -> "co.EnvArrays":
        """``link_bw`` may be a scalar (one observation for every row,
        the event-loop path) or an ``[E]`` vector (per-row observations,
        the fleet engine's slab-batched path).  A one-slot cache skips
        the rebuild when consecutive calls see the same observation and
        live set — the common static-link case."""
        ib = np.atleast_1d(np.asarray(input_bytes, np.float64))
        bw = np.broadcast_to(np.asarray(link_bw, np.float64), ib.shape)
        key = (bw.tobytes(), ib.tobytes())
        if key == self._env_key:
            return self._env_cache
        envs = make_envs(self.device, self.edge, link_bw=bw,
                         link_latency_s=self.link_latency_s,
                         input_bytes=ib)
        self._env_key, self._env_cache = key, envs
        return envs

    def _pick_rows(self, layers, link_bw, input_bytes
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(components [E, L+1, K], front [E, L+1], picks [E])`` for
        tasks sharing one layer chain at the current link state."""
        envs = self._envs(link_bw, input_bytes)
        comp = np.asarray(self.cost.components(layers, envs), np.float64)
        # rank with the model's own scalarisation (not a re-derived
        # weighted sum) so picks agree with decide_all(cost=...) up to
        # the front restriction, whatever the model's scalarize does
        front, picks = co.pareto_pick(comp, self.cost.objectives,
                                      subset=self.pareto_objectives,
                                      scalar=self.cost.scalarize(comp))
        if self.verify:
            rows = np.arange(len(picks))
            if not bool(front[rows, picks].all()):
                raise AssertionError(
                    "pareto_pick returned a dominated split — "
                    "cost model produced inconsistent components")
        return comp, front, picks

    # -- lifecycle --------------------------------------------------------
    def admit(self, rid: int, layers: Sequence[LayerCost],
              link_bw: float, *, input_bytes: float = 0.0,
              now: float = 0.0,
              deadline_s: Optional[float] = None) -> SplitState:
        """Plan the split for one admitted task at the current link
        observation; the task stays live (re-picked on every subsequent
        link event) until :meth:`complete`."""
        if rid in self.live:
            raise KeyError(f"rid {rid} already live")
        _, front, picks = self._pick_rows(layers, link_bw,
                                          [input_bytes])
        st = SplitState(rid=rid, layers=layers,
                        input_bytes=float(input_bytes),
                        deadline_s=deadline_s, pick=int(picks[0]),
                        admission_pick=int(picks[0]),
                        front_size=int(front[0].sum()),
                        history=[(float(now), int(picks[0]))])
        self.live[rid] = st
        self.telemetry.count("split_admissions")
        return st

    def admit_batch(self, rids: Sequence[int],
                    layers: Sequence[LayerCost], link_bw, *,
                    input_bytes: Sequence[float], now: float = 0.0,
                    deadlines_s: Optional[Sequence] = None
                    ) -> list[SplitState]:
        """Admit several tasks sharing one layer chain in ONE batched
        ``components`` call — the per-row picks are bit-for-bit what
        per-task :meth:`admit` calls at the same observations produce
        (the cost models are row-wise over the environment axis).  Used
        by the fleet engine to drain a whole slab's admissions at once.
        """
        rids = [int(r) for r in rids]
        ib = [float(b) for b in input_bytes]
        if deadlines_s is None:
            deadlines_s = [None] * len(rids)
        if not len(rids) == len(ib) == len(deadlines_s):
            raise ValueError("rids, input_bytes and deadlines_s must "
                             "have equal lengths")
        _, front, picks = self._pick_rows(layers, link_bw, ib)
        out = []
        for k, rid in enumerate(rids):
            if rid in self.live:
                raise KeyError(f"rid {rid} already live")
            st = SplitState(rid=rid, layers=layers, input_bytes=ib[k],
                            deadline_s=deadlines_s[k],
                            pick=int(picks[k]),
                            admission_pick=int(picks[k]),
                            front_size=int(front[k].sum()),
                            history=[(float(now), int(picks[k]))])
            self.live[rid] = st
            self.telemetry.count("split_admissions")
            out.append(st)
        return out

    def on_link(self, link_bw: float, now: float = 0.0) -> int:
        """Re-pick every live task along its *current* front at the new
        link observation.  Tasks sharing a layer-chain object are
        re-picked in one batched ``components`` call.  Returns the
        number of tasks whose split switched."""
        if not self.live:
            return 0
        groups: dict[int, list[SplitState]] = {}
        for st in self.live.values():
            groups.setdefault(id(st.layers), []).append(st)
        switched = 0
        for members in groups.values():
            _, front, picks = self._pick_rows(
                members[0].layers, link_bw,
                [st.input_bytes for st in members])
            for k, st in enumerate(members):
                self.total_repicks += 1
                self.telemetry.count("split_repicks")
                st.front_size = int(front[k].sum())
                new = int(picks[k])
                if new != st.pick:
                    if self.obs.enabled:
                        self.obs.instant(
                            "split_planner", "split_repick", float(now),
                            tid=st.rid,
                            args={"from": st.pick, "to": new})
                    st.pick = new
                    st.switches += 1
                    st.history.append((float(now), new))
                    switched += 1
                    self.total_switches += 1
                    self.telemetry.count("split_switches")
        return switched

    def on_saturation(self, link_bw: float, now: float = 0.0) -> int:
        """An edge pool's utilisation just crossed the saturation
        threshold from below: re-pick every live task's split along its
        current front (contention shifts the latency/energy trade-off,
        so picks made under an idle edge may now be tail-hostile).
        Counts ``split_saturation_repicks``; returns switches."""
        self.telemetry.count("split_saturation_repicks")
        return self.on_link(link_bw, now=now)

    def complete(self, rid: int, link_bw: float, *,
                 now: float = 0.0) -> dict:
        """Close a task's plan.  Returns its final pick, switch count,
        and the realised objective components — of both the live pick
        and the admission-time pick — under the final link state, so
        Pareto re-picking can be scored against commit-at-admission."""
        st = self.live.pop(rid)
        comp, _, _ = self._pick_rows(st.layers, link_bw,
                                     [st.input_bytes])
        names = tuple(self.cost.objectives)
        realised = {n: float(comp[0, st.pick, k])
                    for k, n in enumerate(names)}
        committed = {n: float(comp[0, st.admission_pick, k])
                     for k, n in enumerate(names)}
        return {
            "rid": rid, "pick": st.pick,
            "admission_pick": st.admission_pick,
            "switches": st.switches, "front_size": st.front_size,
            "history": list(st.history), "finished_s": float(now),
            "realised": realised, "realised_at_admission_pick": committed,
        }
