"""repro.sim — event-driven streaming offload simulator.

Closes the batch-world gap: tasks arrive over virtual time (Poisson,
trace, Markov-modulated, diurnal), link bandwidth and node backlog
drift as seeded stochastic processes, and the existing decision core
(``repro.core.decisions`` / ``costs`` and the jit/Pallas kernels) is
driven *online* through state snapshots — incremental re-planning on
the live ``[T, N]`` finish matrix (:class:`StreamScheduler`) and
Pareto-front re-picking of offload splits
(:class:`ParetoStreamScheduler`), with run telemetry in the same record
schema the batch benchmarks emit.

Seams (each pinned by tests/test_sim.py; the fast lane covers the
deterministic smoke, tier-1 adds the slow end-to-end run):

  * events    — virtual clock, event heap, arrival processes
  * state     — drifting links snapshotted into ``EnvArrays``
  * stream    — incremental online min-min/HEFT + the event loop
  * fleet     — time-slabbed array-native engine (bit-for-bit twin of
                the event loop; ``simulate_stream(..., engine="fleet")``)
  * pareto    — live Pareto-front split re-picking
  * queueing  — finite-capacity server pools, heavy-tailed RTT
                processes, Erlang-C/M/M/c validation closed forms
  * telemetry — p50/p99, misses, energy, utilisation, queue waits,
                re-plan counts
"""
from repro.sim.events import (Clock, Event, EventQueue, diurnal_arrivals,
                              mmpp_arrivals, poisson_arrivals,
                              trace_arrivals)
from repro.sim.fleet import decide_all_sharded, simulate_fleet
from repro.sim.pareto import PARETO_OBJECTIVES, ParetoStreamScheduler
from repro.sim.queueing import (DelayProcess, LognormalRTT, NodePools,
                                ServerPool, WeibullRTT, erlang_c,
                                mm1_sojourn, mmc_sojourn, spawn_streams)
from repro.sim.state import (ClusterLinks, DiurnalLink, DriftingEnv,
                             FixedLink, LinkProcess, RandomWalkLink,
                             TwoStateLink, step_batch)
from repro.sim.stream import StreamScheduler, simulate_stream
from repro.sim.telemetry import TaskRecord, Telemetry

__all__ = [
    "Clock", "Event", "EventQueue", "poisson_arrivals", "trace_arrivals",
    "mmpp_arrivals", "diurnal_arrivals", "LinkProcess", "FixedLink",
    "RandomWalkLink", "TwoStateLink", "DiurnalLink", "DriftingEnv",
    "ClusterLinks", "step_batch", "StreamScheduler", "simulate_stream",
    "simulate_fleet", "decide_all_sharded", "ParetoStreamScheduler",
    "PARETO_OBJECTIVES", "TaskRecord", "Telemetry", "ServerPool",
    "NodePools", "DelayProcess", "WeibullRTT", "LognormalRTT",
    "erlang_c", "mm1_sojourn", "mmc_sojourn", "spawn_streams",
]
