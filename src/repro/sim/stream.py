"""Online streaming placement: incremental min-min / HEFT over a live
``[T, N]`` finish matrix, plus the event-driven run loop.

The batch schedulers in :mod:`repro.core.scheduler` assume every task is
known at t=0 and place all of them in one pass.  :class:`StreamScheduler`
is their online twin: tasks arrive over virtual time, each admission
event extends the finish matrix by the arriving rows only, each
placement refreshes the placed node's column only, and each link-state
update refreshes the affected node's ETC column only — the matrix is
*never* rebuilt from scratch (``telemetry`` counts rows built and
columns refreshed; ``full_rebuilds`` stays 0 by construction).

Equivalence pin (tested): with every arrival at t=0 and static links,
``StreamScheduler.run`` reproduces the batch ``min_min`` / ``heft``
schedules bit-for-bit — same arithmetic, same
:func:`repro.core.scheduler.masked_argmin` tie-break.

:func:`simulate_stream` is the event loop tying the pieces together:
arrival events admit tasks, completion events free nodes (optionally
migrating the tail of the most backlogged queue onto the freed node),
link events drift the per-node uplinks (:class:`repro.sim.state.
ClusterLinks`) and the device↔edge split environment
(:class:`repro.sim.state.DriftingEnv`), and a
:class:`repro.sim.pareto.ParetoStreamScheduler` may ride along to keep
each live task's offload split on the Pareto front.  Results land in a
:class:`repro.sim.telemetry.Telemetry`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.core import scheduler as sch
from repro.obs.trace import NULL_TRACER, postmortem_dump
from repro.sim.events import EventQueue
from repro.sim.state import ClusterLinks, DriftingEnv
from repro.sim.telemetry import TaskRecord, Telemetry


def _batches_by_arrival(arrivals: np.ndarray
                        ) -> list[tuple[float, list[int]]]:
    """``(time, task indices)`` admission batches: arrival order, exact
    time ties grouped into one batch (stable within a batch)."""
    order = np.argsort(arrivals, kind="stable")
    out: list[tuple[float, list[int]]] = []
    k = 0
    while k < len(order):
        m = k
        t = float(arrivals[order[k]])
        while m < len(order) and arrivals[order[m]] == t:
            m += 1
        out.append((t, [int(i) for i in order[k:m]]))
        k = m
    return out


class StreamScheduler:
    """Incremental online min-min / HEFT placement.

    ``policy`` is ``"min_min"`` (globally smallest finish first, the
    classic online heuristic) or ``"heft"`` (rank arriving batch by mean
    ETC descending, place each on its earliest-finish node).  ``cost``
    plugs a :class:`repro.core.costs.CostModel` into the ETC rows
    (predictor-driven or multi-objective streaming placement); ``None``
    keeps the analytic roofline estimate.  ``rebalance=True`` lets
    :meth:`on_node_free` migrate the tail of the most backlogged queue
    onto a freed node when that strictly improves its finish time.
    """

    def __init__(self, nodes: Sequence[sch.Node], *,
                 policy: str = "min_min", cost=None,
                 rebalance: bool = False,
                 pools=None, service_time_fn=None,
                 telemetry: Optional[Telemetry] = None):
        if policy not in ("min_min", "heft"):
            raise ValueError(f"unknown policy {policy!r}; "
                             "use 'min_min' or 'heft'")
        self.policy = policy
        self.cost = cost
        self.rebalance = rebalance
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.nodes = [dataclasses.replace(n) for n in nodes]
        self.pools = pools
        self.service_time_fn = service_time_fn
        if pools is not None:
            if rebalance:
                raise ValueError(
                    "rebalance=True is incompatible with pools= — "
                    "migration bookkeeping assumes the believed scalar "
                    "queue, not realised c-server busy state")
            if len(pools) != len(self.nodes):
                raise ValueError(f"pools carries {len(pools)} pools for "
                                 f"{len(self.nodes)} nodes")
            # the availability vector IS the pools' earliest-free cache:
            # admissions update it in place through NodePools.admit
            self.avail = pools.avail
        else:
            self.avail = np.asarray([n.available_at for n in self.nodes],
                                    np.float64)
        self.assignments: list[sch.Assignment] = []
        self._node_of: dict[int, int] = {}       # id(assignment) -> node j
        self._etc_of: dict[int, float] = {}      # id(assignment) -> etc
        # incremental-work counters (full_rebuilds stays 0 by construction)
        self.rows_built = 0
        self.column_refreshes = 0
        self.link_refreshes = 0
        self.migrations = 0
        self.full_rebuilds = 0

    # -- ETC rows against the *current* node/link state -------------------
    def etc_rows(self, tasks: Sequence[sch.Task]) -> np.ndarray:
        """``[P, N]`` expected-time-to-compute of the arriving batch on
        every node, at the current link state."""
        etc = sch.etc_matrix(tasks, self.nodes, cost=self.cost)
        self.rows_built += len(tasks)
        return np.asarray(etc, np.float64)

    def set_link_bw(self, j: int, bw: float) -> None:
        """Drift node ``j``'s uplink: future ETC columns see ``bw``.
        Committed work keeps its transfer (already in flight)."""
        node = self.nodes[j]
        node.spec = dataclasses.replace(node.spec, link_bw=float(bw))
        self.link_refreshes += 1
        self.telemetry.count("link_refreshes")

    # -- admission --------------------------------------------------------
    def on_arrivals(self, tasks: Sequence[sch.Task], now: float = 0.0
                    ) -> list[sch.Assignment]:
        """Place an arriving batch (all tasks have arrival time ``now``).

        One ETC row per task, then min-min rounds over the masked finish
        matrix (or HEFT ranking); every placement refreshes only the
        placed node's column.  Returns the new assignments in placement
        order.
        """
        tasks = list(tasks)
        if not tasks:
            return []
        # queue-aware cost models price the wait as of the admission
        # instant (QueueAwareCost reads live pool state through set_now)
        if self.cost is not None and hasattr(self.cost, "set_now"):
            self.cost.set_now(now)
        etc = self.etc_rows(tasks)
        placed: list[sch.Assignment] = []
        self.telemetry.count("replans")
        if self.policy == "heft":
            order = np.argsort(-etc.mean(axis=1))
            for i in order:
                j = int(np.argmin(np.maximum(self.avail, now) + etc[i]))
                if self.pools is not None:
                    start, finish = self._admit(tasks[int(i)], j, now,
                                                float(etc[i, j]))
                else:
                    start = float(np.maximum(self.avail[j], now))
                    finish = start + float(etc[i, j])
                    self.avail[j] = finish
                placed.append(self._commit(tasks[int(i)], j, start,
                                           finish, float(etc[i, j])))
            return placed
        fin = np.maximum(self.avail, now)[None, :] + etc
        active = np.ones(len(tasks), bool)
        for _ in range(len(tasks)):
            i, j = sch.masked_argmin(fin, active)
            if self.pools is not None:
                start, finish = self._admit(tasks[i], j, now,
                                            float(etc[i, j]))
            else:
                start = float(np.maximum(self.avail[j], now))
                finish = float(fin[i, j])
                self.avail[j] = fin[i, j]
            active[i] = False
            fin[:, j] = np.maximum(self.avail[j], now) + etc[:, j]
            self.column_refreshes += 1
            self.telemetry.count("column_refreshes")
            placed.append(self._commit(tasks[i], j, start, finish,
                                       float(etc[i, j])))
        return placed

    def _admit(self, task: sch.Task, j: int, now: float,
               etc_tj: float) -> tuple[float, float]:
        """Route one placement through node ``j``'s server pool.

        The realised service time is drawn *at admission* (the pool
        tracks realised busy-until state, so queue statistics come out
        exact); ``self.avail`` is the pools' earliest-free cache and is
        updated in place by ``NodePools.admit``.  With ``capacity=1``
        and no ``service_time_fn`` this is bit-for-bit the historical
        scalar bookkeeping: ``start = max(avail[j], now)``,
        ``avail[j] = start + etc``.
        """
        service = etc_tj
        if self.service_time_fn is not None:
            start_pred = max(self.pools.pools[j].next_free(), float(now))
            service = float(self.service_time_fn(
                task, self.nodes[j].spec, etc_tj, start_pred))
        return self.pools.admit(j, now, service)

    def _commit(self, task: sch.Task, j: int, start: float, finish: float,
                etc_tj: float) -> sch.Assignment:
        a = sch.Assignment(task, self.nodes[j].spec.name, start, finish)
        self.assignments.append(a)
        self._node_of[id(a)] = j
        self._etc_of[id(a)] = etc_tj
        return a

    def etc_of(self, a: sch.Assignment) -> float:
        """The exact ETC value an assignment was placed with — the
        prediction an online oracle compares realised times against
        (recomputing it can disagree in the last ulp)."""
        return self._etc_of[id(a)]

    # -- node-free events -------------------------------------------------
    def node_index(self, a: sch.Assignment) -> int:
        """Node index an assignment currently sits on (spec names may
        repeat across nodes, so the name alone is not enough)."""
        return self._node_of[id(a)]

    def on_node_free(self, j: int, now: float
                     ) -> Optional[sch.Assignment]:
        """A task on node ``j`` just finished.  With ``rebalance=True``,
        try migrating the tail (last queued, not-yet-started) assignment
        of the most backlogged other node onto ``j`` when that strictly
        improves its finish; returns the migrated assignment (whose
        ``node``/``start``/``finish`` were updated in place), else
        ``None``."""
        if not self.rebalance:
            return None
        tails: dict[int, sch.Assignment] = {}
        for a in self.assignments:
            k = self._node_of[id(a)]
            if k != j and a.start > now and a.finish == self.avail[k]:
                tails[k] = a
        if not tails:
            return None
        k = max(tails, key=lambda k_: self.avail[k_])
        a = tails[k]
        etc_new = float(self.etc_rows([a.task])[0, j])
        start = float(np.maximum(self.avail[j], now))
        finish = start + etc_new
        if finish >= a.finish:
            return None
        self.avail[k] = a.start          # contiguous queue: tail pops off
        self.avail[j] = finish
        a.node = self.nodes[j].spec.name
        a.start, a.finish = start, finish
        self._node_of[id(a)] = j
        self._etc_of[id(a)] = etc_new
        self.migrations += 1
        self.telemetry.count("migrations")
        return a

    # -- conveniences -----------------------------------------------------
    def run(self, tasks: Sequence[sch.Task], arrivals) -> sch.Schedule:
        """Admit ``tasks`` at their ``arrivals`` times (batching ties)
        without the full event loop — the benchmark / equivalence path."""
        arrivals = np.asarray(arrivals, np.float64)
        if arrivals.shape != (len(tasks),):
            raise ValueError(
                f"arrivals must be [{len(tasks)}], got {arrivals.shape}")
        for t, batch in _batches_by_arrival(arrivals):
            self.on_arrivals([tasks[i] for i in batch], t)
        return self.schedule()

    def schedule(self) -> sch.Schedule:
        return sch.Schedule(list(self.assignments))


# --------------------------------------------------------------------------
# The event loop
# --------------------------------------------------------------------------
LayersFor = Union[Sequence, Callable[[sch.Task], Sequence]]


def simulate_stream(tasks: Sequence[sch.Task], arrivals,
                    nodes: Sequence[sch.Node], *,
                    policy: str = "min_min", cost=None,
                    oracle=None, service_time_fn=None,
                    links: Optional[ClusterLinks] = None,
                    link_update_dt: float = 1.0,
                    split_planner=None,
                    split_env: Optional[DriftingEnv] = None,
                    split_layers: Optional[LayersFor] = None,
                    split_cost=None, split_backend: str = "numpy",
                    rebalance: bool = False,
                    pools=None, rtt=None,
                    saturation_threshold: Optional[float] = None,
                    telemetry: Optional[Telemetry] = None,
                    obs=None,
                    engine: str = "event") -> Telemetry:
    """Run the full event-driven streaming simulation.

    Events, in virtual-time order with FIFO ties:

      * ``arrive``  — admit the batch of tasks arriving at that instant
                      through the incremental :class:`StreamScheduler`
                      (and, when a ``split_planner`` rides along, admit
                      each task's offload split against the current
                      ``split_env`` link observation)
      * ``finish``  — a task completes: record telemetry, free the node
                      (possibly migrating a queued task onto it), close
                      the task's split plan
      * ``link``    — every ``link_update_dt`` seconds of virtual time,
                      drift the per-node uplinks (``links``) and the
                      device↔edge environment (``split_env``), refresh
                      only the affected ETC columns, and let the split
                      planner re-pick along the live Pareto fronts

    ``service_time_fn(task, node_spec, etc_s, start_s) -> seconds``
    injects a ground-truth service-time model *independent of the
    scheduler's prediction*: the scheduler still queues and places by
    its believed ETC, but each task's completion event fires at
    ``start + actual``, and telemetry/energy/oracle observations record
    the realised time.  ``start_s`` (virtual time the task starts) lets
    the truth drift mid-run — e.g. a node that silently slows down at
    t=200.  Without this seam the simulator is model-driven — realised
    == predicted by construction — so it is what makes prediction
    error, and therefore online learning, visible in-sim.

    ``oracle`` plugs an :class:`repro.oracle.online.OnlineOracle` into
    the loop: its :class:`~repro.oracle.online.OracleCost` drives the
    scheduler's ETC rows, and every completion feeds ``(features,
    realised service time)`` back through ``observe_task`` — residual
    correction, Page–Hinkley drift detection, and window refits
    (telemetry counts ``oracle_observations`` / ``oracle_drift_triggers``
    / ``oracle_refits`` and gauges ``oracle_nrmse``).  Features and the
    transfer estimate are taken from the node spec *at placement* (link
    drift between placement and completion must not corrupt the
    (feature, target) pairs refits train on).  With a static
    environment, no ``service_time_fn``, and no drift the oracle is
    bit-transparent: placements are identical to running the same
    fitted model as a plain ``cost=PredictorCost(...)``.

    Without a ``split_planner``, passing ``split_env=`` +
    ``split_layers=`` enables *decide-at-admission*: each placed task's
    offload split is decided once via :func:`repro.core.decisions.
    decide_all` against the link observation at its arrival (optionally
    under ``split_cost=``; ``split_backend=`` picks ``"numpy"`` /
    ``"jax"`` / ``"pallas"`` / ``"sharded"``) and recorded on its
    :class:`TaskRecord` — the commit-at-admission baseline the Pareto
    planner is scored against, and the slab-batchable decision path the
    fleet engine drains in bulk.

    ``pools=`` (a :class:`repro.sim.queueing.NodePools`) replaces the
    believed scalar queue with finite-capacity c-server FIFO pools
    tracking *realised* busy state: sojourn = queue wait + service
    (+ transfer), recorded on each :class:`TaskRecord` and summarised
    as ``p99_wait_s`` / ``mean_wait_s`` / ``mean_queue_len``.  With
    pools the realised service time (``service_time_fn``) is drawn *at
    admission* so queue statistics come out exact; ``capacity=1`` with
    no service model is bit-for-bit the historical bookkeeping.
    ``rtt=`` (a :class:`repro.sim.queueing.DelayProcess`, e.g.
    :class:`~repro.sim.queueing.WeibullRTT`) samples one heavy-tailed
    network round-trip per task, delaying its completion event and
    booked as the record's ``transfer_s``.  ``saturation_threshold=``
    (needs ``split_planner=`` and ``pools=``) fires
    ``split_planner.on_saturation`` whenever any pool's utilisation
    crosses the threshold from below — tail-aware re-picks exactly when
    contention bites.

    ``obs=`` (a :class:`repro.obs.Tracer`) records the run as structured
    spans and instants in *virtual time*: one ``sojourn ⊃ queue_wait ·
    service · transfer`` lifecycle per task on its node's track, plus
    instants for replans, split re-picks, pool saturation, drift
    triggers, and oracle refits.  The default no-op tracer costs
    nothing, and a live tracer only observes values the loop already
    computes — traced runs are bit-for-bit identical to untraced ones.

    ``engine="fleet"`` dispatches the whole run to
    :func:`repro.sim.fleet.simulate_fleet`, the time-slabbed array-native
    twin of this loop — bit-for-bit equal telemetry in f64, orders of
    magnitude faster at fleet scale, but rejecting the inherently
    sequential features (``oracle=``, ``rebalance=True``, ``cost=``).

    Returns the filled :class:`Telemetry` (the scheduler's counters and
    one :class:`TaskRecord` per task).
    """
    if engine == "fleet":
        from repro.sim.fleet import simulate_fleet
        return simulate_fleet(
            tasks, arrivals, nodes, policy=policy, cost=cost,
            oracle=oracle, service_time_fn=service_time_fn, links=links,
            link_update_dt=link_update_dt, split_planner=split_planner,
            split_env=split_env, split_layers=split_layers,
            split_cost=split_cost, split_backend=split_backend,
            rebalance=rebalance, pools=pools, rtt=rtt,
            saturation_threshold=saturation_threshold,
            telemetry=telemetry, obs=obs)
    if engine != "event":
        raise ValueError(f"unknown engine {engine!r}; "
                         "use 'event' or 'fleet'")
    if saturation_threshold is not None and (
            split_planner is None or pools is None):
        raise ValueError("saturation_threshold= needs split_planner= "
                         "and pools= (it re-picks splits when pool "
                         "utilisation crosses the threshold)")
    telemetry = telemetry if telemetry is not None else Telemetry()
    obs = obs if obs is not None else NULL_TRACER
    if pools is not None:
        pools.obs = obs
    if oracle is not None:
        if cost is not None:
            raise ValueError("pass either cost= or oracle= — the oracle "
                             "supplies the scheduler's cost model "
                             "(oracle.cost_model())")
        cost = oracle.cost_model()
        oracle.telemetry = telemetry           # counters/gauges per run
        oracle.obs = obs                       # drift/refit instants
        oracle.registry.obs = obs              # publish instants
    if split_planner is not None:
        if split_env is None or split_layers is None:
            raise ValueError("split_planner needs split_env= and "
                             "split_layers= (shared list or task -> "
                             "layers)")
        if split_cost is not None:
            raise ValueError("split_cost= only applies to the "
                             "decide-at-admission path (no "
                             "split_planner)")
        split_planner.telemetry = telemetry    # one record per run
        split_planner.obs = obs                # split re-pick instants
    decide_splits = (split_planner is None and split_env is not None
                     and split_layers is not None)
    if split_cost is not None and not decide_splits:
        raise ValueError("split_cost= needs split_env= and "
                         "split_layers= without a split_planner")
    split_of: dict[int, int] = {}              # rid -> admission split

    def layers_for(task: sch.Task):
        if callable(split_layers):
            return split_layers(task)
        return split_layers

    sched = StreamScheduler(nodes, policy=policy, cost=cost,
                            rebalance=rebalance, pools=pools,
                            service_time_fn=service_time_fn,
                            telemetry=telemetry)
    arrivals = np.asarray(arrivals, np.float64)
    if arrivals.shape != (len(tasks),):
        raise ValueError(
            f"arrivals must be [{len(tasks)}], got {arrivals.shape}")

    q = EventQueue()
    batches = _batches_by_arrival(arrivals)
    q.push_batch([t for t, _ in batches], "arrive",
                 [batch for _, batch in batches])
    drifting = (links is not None or split_env is not None) \
        and link_update_dt > 0
    if drifting:
        q.push(link_update_dt, "link", None)

    to_arrive = len(tasks)
    live: dict[int, sch.Assignment] = {}         # rid -> assignment
    rid_of: dict[int, int] = {}                  # id(assignment) -> rid
    completed: set[int] = set()                  # id(assignment)
    spec_at_place: dict[int, object] = {}        # id(a) -> spec at placement
    real_finish: dict[int, float] = {}           # id(a) -> realised finish
    rtt_of: dict[int, float] = {}                # id(a) -> sampled RTT
    sat_was = False                              # saturation edge detector

    def schedule_finish(a: sch.Assignment) -> None:
        """Queue the completion event: at the believed finish, or at
        ``start + actual`` when a ground-truth model rides along (the
        scheduler's queue bookkeeping stays belief-driven).  With pools
        the realised service was already drawn at admission, so
        ``a.finish`` *is* the realised compute finish; a heavy-tailed
        ``rtt`` sample then delays the completion event further."""
        j = sched.node_index(a)
        spec_at_place[id(a)] = sched.nodes[j].spec
        t = a.finish
        if pools is None and service_time_fn is not None:
            t = a.start + float(service_time_fn(a.task,
                                                sched.nodes[j].spec,
                                                sched.etc_of(a), a.start))
        if rtt is not None:
            r = float(rtt.sample(1)[0])
            rtt_of[id(a)] = r
            t += r
        real_finish[id(a)] = t
        q.push(t, "finish", a)

    now = 0.0
    try:
        while q:
            ev = q.pop()
            now = ev.time
            if ev.kind == "arrive":
                batch = [tasks[i] for i in ev.payload]
                # map task objects back to their global indices (pick order
                # of the placements differs from input order)
                slots: dict[int, list[int]] = {}
                for rid, task in zip(ev.payload, batch):
                    slots.setdefault(id(task), []).append(rid)
                placed = sched.on_arrivals(batch, now)
                to_arrive -= len(batch)
                if obs.enabled:
                    obs.instant("scheduler", "replan", now,
                                args={"batch": len(batch)})
                for a in placed:
                    rid = slots[id(a.task)].pop(0)
                    live[rid] = a
                    rid_of[id(a)] = rid
                    schedule_finish(a)
                    if split_planner is not None:
                        split_planner.admit(
                            rid, layers_for(a.task), split_env.link_bw,
                            input_bytes=a.task.input_bytes, now=now,
                            deadline_s=a.task.deadline_s)
                    elif decide_splits:
                        from repro.sim.fleet import _split_decide
                        plan = _split_decide(
                            layers_for(a.task),
                            split_env.snapshot(a.task.input_bytes),
                            split_cost, split_backend)
                        split_of[rid] = int(plan.splits[0])
                        telemetry.count("split_decides")
                if saturation_threshold is not None:
                    sat_now = bool(pools.saturated(
                        now, saturation_threshold).any()) if now > 0 else False
                    if sat_now and not sat_was:
                        if obs.enabled:
                            obs.instant("scheduler", "pool_saturation", now,
                                        args={"threshold":
                                              saturation_threshold})
                        split_planner.on_saturation(split_env.link_bw, now=now)
                    sat_was = sat_now
            elif ev.kind == "finish":
                a = ev.payload
                if id(a) in completed or real_finish[id(a)] != now:
                    continue                         # stale (migrated) event
                completed.add(id(a))
                rid = rid_of[id(a)]
                j = sched.node_index(a)
                if oracle is not None:
                    # realised service time vs the exact ETC it was placed
                    # with — the profiling-in-the-loop feedback edge.  The
                    # placement-time spec keeps features/transfer consistent
                    # with what the prediction actually saw.
                    oracle.observe_task(a.task, spec_at_place[id(a)],
                                        realised_s=now - a.start,
                                        predicted_s=sched.etc_of(a), now=now,
                                        extra_transfer_s=rtt_of.get(id(a), 0.0))
                split, switches = None, 0
                if split_planner is not None:
                    rec = split_planner.complete(rid, split_env.link_bw,
                                                 now=now)
                    split, switches = rec["pick"], rec["switches"]
                elif decide_splits:
                    split = split_of.pop(rid)
                telemetry.complete(TaskRecord(
                    name=a.task.name, arrived_s=float(arrivals[rid]),
                    started_s=a.start, finished_s=now, node=a.node,
                    node_id=j, deadline_s=a.task.deadline_s,
                    energy_j=(now - a.start)
                    * sched.nodes[j].spec.tdp_watts,
                    split=split, switches=switches,
                    transfer_s=rtt_of.get(id(a), 0.0)))
                if obs.enabled:
                    span_args = {}
                    if split is not None:
                        span_args["split"] = split
                    if a.task.deadline_s is not None:
                        span_args["deadline_s"] = a.task.deadline_s
                    obs.task_spans(
                        f"{a.node}@{j}", rid, a.task.name,
                        float(arrivals[rid]), a.start, now,
                        transfer_s=rtt_of.get(id(a), 0.0),
                        args=span_args or None)
                del live[rid]
                migrated = sched.on_node_free(j, now)
                if migrated is not None:
                    schedule_finish(migrated)
            elif ev.kind == "link":
                if links is not None:
                    prev = links.values()
                    bws = links.step(link_update_dt)
                    changed = np.flatnonzero(bws != prev)
                    for j in changed:
                        sched.set_link_bw(int(j), float(bws[j]))
                    if obs.enabled and len(changed):
                        obs.instant("scheduler", "link_drift", now,
                                    args={"nodes": int(len(changed))})
                if split_env is not None:
                    split_env.step(link_update_dt)
                    if split_planner is not None:
                        split_planner.on_link(split_env.link_bw, now=now)
                if to_arrive > 0 or live:
                    q.push(now + link_update_dt, "link", None)
    except Exception as e:
        # flight-recorder post-mortem: dump the recent traced
        # history and the virtual clock before re-raising (no-op
        # with tracing off; never masks the original exception)
        postmortem_dump(obs, clock_s=now,
                        error=f"{type(e).__name__}: {e}")
        raise
    return telemetry
