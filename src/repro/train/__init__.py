from repro.train.loop import TrainConfig, TrainResult, train

__all__ = ["TrainConfig", "TrainResult", "train"]
