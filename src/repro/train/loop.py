"""LM training loop: config-driven, checkpointed, mesh-aware.

The same loop drives CPU-scale examples (reduced configs, debug mesh) and
the production launcher (``repro.launch.train``) — only the mesh and the
config differ.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.synthetic import train_batch
from repro.models import build_model
from repro.optim import adamw, warmup_cosine


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup_steps: int = 20
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: str = "checkpoints"
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps_per_s: float
    final_params: object


def train(cfg, tcfg: TrainConfig, *, batch_fn: Optional[Callable] = None,
          jit_step=None, params=None, opt_state=None,
          log_fn: Callable[[str], None] = print) -> TrainResult:
    """Train ``cfg`` (a ModelConfig) for ``tcfg.steps`` steps."""
    api = build_model(cfg, impl="chunked" if cfg.dtype == "bfloat16"
                      else "naive")
    opt = adamw(warmup_cosine(tcfg.lr, tcfg.warmup_steps, tcfg.steps))
    if params is None:
        params = api.init_params(jax.random.key(tcfg.seed))
    if opt_state is None:
        opt_state = opt.init(params)

    if jit_step is None:
        def step_fn(p, s, b):
            (loss, metrics), grads = jax.value_and_grad(
                api.train_loss, has_aux=True)(p, b)
            from repro.optim import apply_updates
            updates, s = opt.update(grads, s, p)
            return apply_updates(p, updates), s, loss, metrics
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    batch_fn = batch_fn or (
        lambda i: train_batch(cfg, tcfg.batch_size, tcfg.seq_len,
                              seed=tcfg.seed + i))
    losses = []
    t0 = None
    for i in range(tcfg.steps):
        batch = batch_fn(i)
        params, opt_state, loss, metrics = jit_step(params, opt_state, batch)
        if i == 0:
            jax.block_until_ready(loss)
            t0 = time.perf_counter()      # exclude compile
        losses.append(float(loss))
        if tcfg.log_every and (i % tcfg.log_every == 0 or
                               i == tcfg.steps - 1):
            log_fn(f"[train] step {i} loss={float(loss):.4f}")
        if tcfg.ckpt_every and i and i % tcfg.ckpt_every == 0:
            ckpt.save(f"{tcfg.ckpt_dir}/ckpt_{i}.npz", params, step=i)
    jax.block_until_ready(params)
    dt = time.perf_counter() - (t0 or time.perf_counter())
    sps = (tcfg.steps - 1) / dt if dt > 0 else float("nan")
    return TrainResult(losses=losses, steps_per_s=sps, final_params=params)
