"""Three-term roofline analysis from compiled XLA artifacts (deliverable g).

    compute_term    = HLO_FLOPs / peak_FLOP/s                 (per chip)
    memory_term     = HLO_bytes / HBM_bw                      (per chip)
    collective_term = collective_bytes / link_bw              (per chip)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, since the
SPMD module is per-device).  Collective bytes are NOT in cost_analysis —
we parse the optimized HLO (``compiled.as_text()``) and sum the *result*
shape bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (a slight upper bound for all-gather; convention
recorded here and in EXPERIMENTS.md).

Hardware constants per assignment: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.hw import TPU_V5E, DeviceSpec

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result of an HLO op: "  %name = bf16[128,2048]{1,0} all-gather(...)"
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" +
    "|".join(_COLLECTIVES) + r")\b")
# tuple-result collectives: "= (bf16[4,8]{...}, bf16[4,8]{...}) all-reduce"
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")\b")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def normalize_cost_analysis(cost) -> dict:
    """``compiled.cost_analysis()`` returns a per-device ``[dict]`` on
    jax 0.4.x and a plain ``dict`` on newer releases — accept both."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective kind over the optimized HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if line.lstrip().startswith("//"):
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    flops: float                    # per-device HLO FLOPs
    hbm_bytes: float                # per-device bytes accessed
    coll_bytes: float               # per-device collective bytes (result)
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0        # 6·N·D useful flops (global)
    chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / global HLO_FLOPs — catches remat/redundancy waste."""
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / (self.flops * self.chips)

    def row(self) -> dict:
        return {
            "name": self.name,
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "coll_breakdown": self.coll_breakdown,
        }


def analyze(name: str, cost: dict, hlo_text: str, *, chips: int,
            model_flops: float = 0.0,
            hw: DeviceSpec = TPU_V5E) -> Roofline:
    """Three-term roofline.

    FLOPs: loop-aware HLO parse (``repro.roofline_hlo``) — XLA's own
    cost_analysis visits while bodies once, undercounting scanned layers
    by ~L×.  Bytes: cost_analysis "bytes accessed" (each buffer counted
    once — a perfect-VMEM-reuse lower bound; the loop-multiplied
    no-reuse upper bound is recorded alongside in the dry-run JSON).
    """
    from repro.roofline_hlo import corrected_costs
    corrected = corrected_costs(hlo_text)
    cost = normalize_cost_analysis(cost)
    flops = max(float(cost.get("flops", 0.0)), corrected["flops"])
    byts = float(cost.get("bytes accessed", 0.0))
    # loop-aware collective bytes (per-step collectives inside scans count
    # once per trip); fall back to the flat text scan if parsing found none
    coll = {k: v for k, v in corrected["collectives"].items() if v}
    if not coll:
        coll = collective_bytes(hlo_text)
    total_coll = float(sum(coll.values()))
    return Roofline(
        name=name,
        flops=flops,
        hbm_bytes=byts,
        coll_bytes=total_coll,
        coll_breakdown={k: v for k, v in coll.items() if v},
        compute_s=flops / hw.peak_flops,
        memory_s=byts / hw.hbm_bw,
        collective_s=total_coll / hw.link_bw,
        model_flops=model_flops,
        chips=chips,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); 2·N·D for inference
    forward, 2·N_active per decoded token."""
    from repro.models import build_model, param_count
    n = param_count(build_model(cfg).param_shapes())
    if cfg.num_experts:
        # active params: replace routed-expert count with top_k
        expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts \
            * cfg.num_layers
        active_expert = expert * cfg.top_k / cfg.num_experts
        n = n - expert + active_expert
    tokens = shape.tokens if shape.mode != "decode" else shape.global_batch
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n * tokens
