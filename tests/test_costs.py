"""CostModel API tests: AnalyticCost pinned bit-for-bit to the latency
matrix, PredictorCost pinned to per-env scalar predictions, CompositeCost
objective semantics, cost-driven ETC matrices, and hypothesis property
tests for ``pareto_front`` (non-domination; a positively-weighted
scalarised argmin is always on the front)."""
import dataclasses
import inspect

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import costs as co
from repro.core import decisions as dec
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.core.predictors import GBTRegressor
from repro.hw import ALL_DEVICES, EDGE_DEVICES, get_device


def rand_layers(rng, n):
    return [off.LayerCost(f"l{i}",
                          flops=float(rng.uniform(1e6, 1e12)),
                          act_bytes=float(rng.uniform(1e2, 1e8)))
            for i in range(n)]


def grid_envs(n=32, device="pi5-arm", edge="edge-server-a100"):
    return dec.make_envs(get_device(device), get_device(edge),
                         link_bw=np.geomspace(1e4, 1e10, n),
                         input_bytes=4 * 32 * 784)


@pytest.fixture(scope="module")
def fitted_gbt():
    """Small profiling GBT over (layer, hardware) features → layer time."""
    rng = np.random.default_rng(0)
    layers = rand_layers(rng, 24)
    feats, ys = [], []
    for spec in EDGE_DEVICES.values():
        feats.append(co.default_layer_features(layers, spec))
        ys.append([off.layer_time(lc.flops, spec) for lc in layers])
    return GBTRegressor(n_trees=30, max_depth=4).fit(
        np.concatenate(feats), np.concatenate(ys))


# --------------------------------------------------------------------------
# AnalyticCost: bit-for-bit the latency matrix / historical decide_all
# --------------------------------------------------------------------------
@pytest.mark.parametrize("trial", range(5))
def test_analytic_cost_bit_for_bit(trial):
    rng = np.random.default_rng(trial)
    layers = rand_layers(rng, int(rng.integers(1, 20)))
    envs = grid_envs(16)
    comp = co.AnalyticCost().components(layers, envs)
    assert comp.shape == (16, len(layers) + 1, 1)
    assert np.array_equal(comp[..., 0], dec.latency_matrix(layers, envs))


@pytest.mark.parametrize("trial", range(5))
def test_decide_all_with_analytic_cost_matches_default(trial):
    rng = np.random.default_rng(100 + trial)
    layers = rand_layers(rng, int(rng.integers(1, 20)))
    envs = grid_envs(16)
    base = dec.decide_all(layers, envs)
    via_cost = dec.decide_all(layers, envs, cost=co.AnalyticCost())
    for field in ("splits", "total_time_s", "device_time_s",
                  "transfer_time_s", "edge_time_s"):
        assert np.array_equal(getattr(base, field), getattr(via_cost, field))
    assert via_cost.objectives == ("latency_s",)
    assert via_cost.components.shape == (16, 1)
    # plans without cost= keep the slim historical surface
    assert base.objectives == ("latency_s",)
    assert base.components is None
    np.testing.assert_array_equal(base.objective("latency_s"),
                                  base.total_time_s)


def test_sweep_links_cost_passthrough():
    rng = np.random.default_rng(1)
    layers = rand_layers(rng, 8)
    env = off.OffloadEnv(get_device("pi5-arm"),
                         get_device("edge-server-a100"),
                         link_bw=1e8, input_bytes=1e5)
    bws = np.geomspace(1e5, 1e9, 12)
    a = dec.sweep_links(layers, env, bws)
    b = dec.sweep_links(layers, env, bws, cost=co.AnalyticCost())
    assert np.array_equal(a.splits, b.splits)
    assert np.array_equal(a.total_time_s, b.total_time_s)


# --------------------------------------------------------------------------
# PredictorCost: pinned to per-env scalar predictions, one predict call
# --------------------------------------------------------------------------
def test_predictor_cost_matches_scalar_predictions(fitted_gbt):
    rng = np.random.default_rng(2)
    layers = rand_layers(rng, 10)
    device, edge = get_device("pi5-arm"), get_device("edge-server-a100")
    envs = grid_envs(8)
    cost = co.PredictorCost(fitted_gbt, device, edge)
    comp = cost.components(layers, envs)
    assert comp.shape == (8, 11, 1)

    def time_fn(lc, spec):
        one = co.default_layer_features([lc], spec)
        return max(float(fitted_gbt.predict(one)[0]), 0.0)

    for i in range(len(envs)):
        env = off.OffloadEnv(device, edge,
                             link_bw=float(envs.link_bw[i]),
                             link_latency_s=float(envs.link_latency_s[i]),
                             input_bytes=float(envs.input_bytes[i]))
        expect = off.split_times_all(layers, env, time_fn=time_fn)
        np.testing.assert_allclose(comp[i, :, 0], expect,
                                   rtol=1e-9, atol=1e-12)


def test_predictor_cost_one_predict_call_per_sweep(fitted_gbt):
    class Counting:
        calls = 0

        def predict(self, x):
            Counting.calls += 1
            return fitted_gbt.predict(x)

    rng = np.random.default_rng(3)
    layers = rand_layers(rng, 12)
    envs = grid_envs(1024)           # fleet-scale sweep, no per-env loop
    cost = co.PredictorCost(Counting(), get_device("pi5-arm"),
                            get_device("edge-server-a100"))
    plan = dec.decide_all(layers, envs, cost=cost)
    assert len(plan) == 1024
    assert Counting.calls == 1
    assert np.isfinite(plan.total_time_s).all()


def test_predictor_cost_breakdown_sums_to_total(fitted_gbt):
    rng = np.random.default_rng(4)
    layers = rand_layers(rng, 6)
    envs = grid_envs(5)
    plan = dec.decide_all(layers, envs, cost=co.PredictorCost(
        fitted_gbt, get_device("pi5-arm"), get_device("edge-server-a100")))
    np.testing.assert_allclose(
        plan.device_time_s + plan.transfer_time_s + plan.edge_time_s,
        plan.total_time_s, rtol=1e-9)


# --------------------------------------------------------------------------
# CompositeCost: objective semantics
# --------------------------------------------------------------------------
def test_composite_components_semantics():
    rng = np.random.default_rng(5)
    layers = rand_layers(rng, 9)
    envs = grid_envs(16)
    cost = co.CompositeCost(price_per_edge_s=0.2, price_per_gb=0.05,
                            deadline_s=0.01)
    comp = cost.components(layers, envs)
    assert comp.shape == (16, 10, 4)
    assert cost.objectives == ("latency_s", "energy_j", "price",
                               "deadline_slack_s")
    lat = comp[..., 0]
    np.testing.assert_allclose(lat, dec.latency_matrix(layers, envs),
                               rtol=1e-12)
    # energy must not silently be zero: specs carry tdp_watts
    assert (comp[..., 1] > 0).all()
    np.testing.assert_allclose(comp[..., 3],
                               np.maximum(lat - 0.01, 0.0), rtol=1e-12)
    # local-only split ships nothing -> zero transfer price, and with a
    # zero-cost edge column the price must be exactly 0
    free_edge = co.CompositeCost(price_per_edge_s=0.0, price_per_gb=1.0)
    comp2 = free_edge.components(layers, envs)
    np.testing.assert_array_equal(comp2[:, -1, 2], np.zeros(16))


def test_composite_scalarisation_weights():
    rng = np.random.default_rng(6)
    layers = rand_layers(rng, 7)
    envs = grid_envs(8)
    latency_only = co.CompositeCost(weights={"latency_s": 1.0})
    plan = dec.decide_all(layers, envs, cost=latency_only)
    base = dec.decide_all(layers, envs)
    assert np.array_equal(plan.splits, base.splits)
    # an enormous energy weight must not pick strictly dominated splits
    energy_heavy = co.CompositeCost(weights={"energy_j": 1.0})
    plan_e = dec.decide_all(layers, envs, cost=energy_heavy)
    comp = energy_heavy.components(layers, envs)
    rows = np.arange(len(envs))
    assert np.array_equal(plan_e.scalar_cost,
                          comp[rows, plan_e.splits, 1])
    np.testing.assert_array_equal(plan_e.objective("energy_j"),
                                  comp[rows, plan_e.splits, 1])


def test_decide_all_rejects_efficiency_with_cost():
    """efficiency= belongs to the analytic default; with cost= it would be
    silently ignored, so the combination must raise."""
    rng = np.random.default_rng(12)
    layers = rand_layers(rng, 4)
    envs = grid_envs(3)
    with pytest.raises(ValueError, match="efficiency"):
        dec.decide_all(layers, envs, 0.5, cost=co.AnalyticCost())
    # an explicit matching cost-model efficiency is the supported spelling
    plan = dec.decide_all(layers, envs, cost=co.AnalyticCost(0.5))
    base = dec.decide_all(layers, envs, 0.5)
    assert np.array_equal(plan.splits, base.splits)


def test_composite_requires_latency_parts_base():
    class TotalsOnly:
        objectives = ("latency_s",)

        def components(self, layers, envs):
            return np.zeros((len(envs), len(layers) + 1, 1))

        def scalarize(self, comp):
            return comp[..., 0]

    with pytest.raises(TypeError, match="latency_parts"):
        co.CompositeCost(base=TotalsOnly())


def test_composite_rejects_unknown_weight_names():
    rng = np.random.default_rng(10)
    layers = rand_layers(rng, 4)
    envs = grid_envs(3)
    cost = co.CompositeCost(weights={"energy": 1.0})   # typo: energy_j
    with pytest.raises(KeyError, match="energy"):
        dec.decide_all(layers, envs, cost=cost)


def test_envs_carry_tdp_watts():
    envs = grid_envs(4, device="pi5-arm", edge="edge-server-a100")
    assert np.all(envs.dev_tdp_watts == get_device("pi5-arm").tdp_watts)
    assert np.all(envs.edge_tdp_watts
                  == get_device("edge-server-a100").tdp_watts)
    listed = dec.stack_envs([off.OffloadEnv(
        get_device("xps15-i5"), get_device("gtx-1650"), link_bw=1e8)])
    assert listed.dev_tdp_watts[0] == get_device("xps15-i5").tdp_watts


def test_all_specs_expose_positive_tdp_feature():
    for spec in ALL_DEVICES.values():
        feats = spec.as_features()
        assert feats["hw_tdp_watts"] == spec.tdp_watts > 0, spec.name


# --------------------------------------------------------------------------
# Cost-driven ETC matrices + efficiency threading
# --------------------------------------------------------------------------
def rand_cluster(rng, n_tasks=8):
    nodes = [sch.Node(s) for s in EDGE_DEVICES.values()]
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                      input_bytes=float(rng.uniform(1e4, 1e7)))
             for i in range(n_tasks)]
    return tasks, nodes


def test_etc_matrix_analytic_cost_matches_exec_time():
    rng = np.random.default_rng(7)
    tasks, nodes = rand_cluster(rng)
    base = sch.etc_matrix(tasks, nodes)
    via_cost = sch.etc_matrix(tasks, nodes, cost=co.AnalyticCost())
    assert np.array_equal(base, via_cost)


def test_etc_matrix_predictor_cost_vectorised(fitted_gbt):
    class Counting:
        calls = 0

        def predict(self, x):
            Counting.calls += 1
            return fitted_gbt.predict(x)

    rng = np.random.default_rng(8)
    tasks, nodes = rand_cluster(rng, n_tasks=12)
    cost = co.PredictorCost(Counting(), get_device("pi5-arm"),
                            get_device("edge-server-a100"))
    etc = sch.etc_matrix(tasks, nodes, cost=cost)
    assert etc.shape == (12, len(nodes))
    assert Counting.calls == 1            # all (task, node) pairs batched
    assert (etc > 0).all()
    # schedulers consume it unchanged
    s = sch.min_min(tasks, nodes, etc)
    assert len(s.assignments) == len(tasks)


def test_node_exec_time_default_efficiency_is_shared():
    sig = inspect.signature(sch.Node.exec_time)
    assert sig.parameters["efficiency"].default is off.DEFAULT_EFFICIENCY
    node = sch.Node(get_device("pi5-arm"))
    task = sch.Task("t", flops=1e10, input_bytes=1e5)
    expect = (task.flops
              / (node.spec.peak_flops_f32 * off.DEFAULT_EFFICIENCY)
              + task.input_bytes / max(node.spec.link_bw, 1.0))
    assert node.exec_time(task) == expect


# --------------------------------------------------------------------------
# pareto_front property tests
# --------------------------------------------------------------------------
def _dominates(a, b):
    return bool(np.all(a <= b) and np.any(a < b))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 24), st.integers(1, 4))
def test_pareto_front_non_domination(seed, n, k):
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.0, 1.0, (n, k))
    front = co.pareto_front(c)
    assert front.shape == (n,) and front.any()
    on = np.flatnonzero(front)
    for i in on:                          # nothing dominates a front point
        for j in range(n):
            assert not _dominates(c[j], c[i])
    for i in np.flatnonzero(~front):      # every excluded point is dominated
        assert any(_dominates(c[j], c[i]) for j in range(n))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 24), st.integers(1, 4))
def test_pareto_scalarised_argmin_on_front(seed, n, k):
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.0, 1.0, (n, k))
    w = rng.uniform(0.1, 10.0, k)         # strictly positive weights
    best = int(np.argmin(c @ w))
    assert co.pareto_front(c)[best]


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(1, 9),
       st.integers(1, 3))
def test_pareto_front_batched_matches_per_row(seed, e, s, k):
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.0, 1.0, (e, s, k))
    batched = co.pareto_front(c)
    assert batched.shape == (e, s)
    for i in range(e):
        np.testing.assert_array_equal(batched[i], co.pareto_front(c[i]))


def test_pareto_front_on_decision_matrix():
    """The scalarised decide_all split is Pareto-optimal per environment."""
    rng = np.random.default_rng(9)
    layers = rand_layers(rng, 10)
    envs = grid_envs(32)
    cost = co.CompositeCost(weights={"latency_s": 1.0, "energy_j": 0.01,
                                     "price": 0.5},
                            price_per_edge_s=0.1, price_per_gb=0.01)
    front = cost.pareto(layers, envs)
    plan = dec.decide_all(layers, envs, cost=cost)
    rows = np.arange(len(envs))
    assert front[rows, plan.splits].all()
