"""Pallas kernel validation (interpret mode) against pure oracles —
shape/dtype sweeps per the assignment, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gbt_hist.kernel import grad_histogram_kernel
from repro.kernels.gbt_hist.ref import grad_histogram_ref
from repro.kernels.ssm_scan.ops import ssd_chunked_kernel


def rnd(seed, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32) \
        .astype(dtype) * 0.5


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Sq, Hq, Hkv, D, window, dtype, tol)
    (1, 128, 4, 4, 32, 0, jnp.float32, 2e-5),
    (2, 200, 8, 2, 64, 0, jnp.float32, 2e-5),
    (2, 65, 4, 1, 16, 0, jnp.float32, 2e-5),     # MQA + ragged seq
    (1, 256, 2, 2, 128, 31, jnp.float32, 2e-5),  # sliding window
    (2, 128, 4, 2, 64, 0, jnp.bfloat16, 3e-2),
    (1, 384, 6, 6, 64, 100, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("b,s,hq,hkv,d,window,dtype,tol", FLASH_CASES)
def test_flash_attention_matches_ref(b, s, hq, hkv, d, window, dtype, tol):
    q = rnd(1, b, s, hq, d, dtype=dtype)
    k = rnd(2, b, s, hkv, d, dtype=dtype)
    v = rnd(3, b, s, hkv, d, dtype=dtype)
    out = flash_attention(q, k, v, window=window, qblk=64, kblk=64)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(st.integers(17, 150), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32]), st.integers(0, 40))
def test_flash_attention_property(s, hkv, d, window):
    """Random (seq, heads, dim, window): kernel ≡ oracle."""
    hq = hkv * 2
    q, k, v = rnd(11, 1, s, hq, d), rnd(12, 1, s, hkv, d), rnd(13, 1, s,
                                                               hkv, d)
    out = flash_attention(q, k, v, window=window, qblk=32, kblk=32)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_matches_model_path():
    """Kernel ≡ the model's chunked_attention (the jnp twin)."""
    from repro.models.attention import chunked_attention
    q, k, v = rnd(21, 2, 96, 4, 32), rnd(22, 2, 96, 2, 32), rnd(23, 2, 96,
                                                                2, 32)
    out_k = flash_attention(q, k, v, qblk=32, kblk=32)
    out_m = chunked_attention(q, k, v, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# GBT gradient histogram
# --------------------------------------------------------------------------
HIST_CASES = [(100, 5, 16, 64), (1000, 19, 64, 512), (513, 3, 256, 256)]


@pytest.mark.parametrize("n,f,bins,blk", HIST_CASES)
def test_gbt_hist_matches_ref(n, f, bins, blk):
    rng = np.random.default_rng(n)
    codes = rng.integers(0, bins, size=(n, f)).astype(np.int32)
    grad = rng.normal(size=n).astype(np.float32)
    gsum, cnt = jax.jit(
        lambda c, g: grad_histogram_kernel(c, g, bins, blk=blk))(
        jnp.asarray(codes), jnp.asarray(grad))
    gsum_r, cnt_r = grad_histogram_ref(codes, grad, bins)
    np.testing.assert_allclose(np.asarray(gsum), gsum_r, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(cnt), cnt_r)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 600), st.integers(1, 8), st.sampled_from([8, 32, 64]))
def test_gbt_hist_property(n, f, bins):
    rng = np.random.default_rng(n * 7 + f)
    codes = rng.integers(0, bins, size=(n, f)).astype(np.int32)
    grad = rng.normal(size=n).astype(np.float32)
    gsum, cnt = jax.jit(
        lambda c, g: grad_histogram_kernel(c, g, bins, blk=128))(
        jnp.asarray(codes), jnp.asarray(grad))
    gsum_r, cnt_r = grad_histogram_ref(codes, grad, bins)
    np.testing.assert_allclose(np.asarray(gsum), gsum_r, rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_array_equal(np.asarray(cnt), cnt_r)
    # conservation invariants
    assert abs(float(cnt.sum()) - n * f) < 1e-6
    np.testing.assert_allclose(float(gsum.sum()), float(grad.sum()) * f,
                               rtol=1e-3, atol=1e-3)


def test_gbt_trains_with_kernel_backend():
    """End-to-end: GBT fit with use_kernel=True ≈ numpy backend."""
    from repro.core.predictors import GBTRegressor
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(300, 6)).astype(np.float32)
    y = np.sin(3 * x[:, 0]) + x[:, 1]
    m_np = GBTRegressor(n_trees=30, max_depth=4).fit(x, y)
    m_k = GBTRegressor(n_trees=30, max_depth=4, use_kernel=True).fit(x, y)
    p_np, p_k = m_np.predict(x), m_k.predict(x)
    np.testing.assert_allclose(p_k, p_np, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------
SSD_CASES = [(1, 64, 2, 8, 8, 16), (2, 100, 3, 16, 4, 32),
             (1, 33, 1, 4, 32, 8)]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_CASES)
def test_ssd_kernel_matches_model(b, s, h, p, n, chunk):
    from repro.models.mamba2 import ssd_chunked
    x = rnd(31, b, s, h, p)
    dt = jax.nn.softplus(rnd(32, b, s, h))
    bb, cc = rnd(33, b, s, n), rnd(34, b, s, n)
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    d_skip = jnp.ones((h,))
    y_k, st_k = ssd_chunked_kernel(x, dt, a_log, bb, cc, d_skip, chunk=chunk)
    y_m, st_m = ssd_chunked(x, dt, a_log, bb, cc, d_skip, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_sequential_ref():
    from repro.kernels.ssm_scan.kernel import ssd_scan_kernel
    from repro.kernels.ssm_scan.ref import ssd_scan_ref
    rng = np.random.default_rng(5)
    bh, nc, q, p, n, h = 4, 3, 8, 4, 6, 2
    xdt = rng.normal(size=(bh, nc, q, p)).astype(np.float32)
    loga = -np.abs(rng.normal(size=(bh, nc, q, 1))).astype(np.float32) * 0.1
    b = rng.normal(size=(bh // h, nc, q, n)).astype(np.float32)
    c = rng.normal(size=(bh // h, nc, q, n)).astype(np.float32)
    y_k, st_k = jax.jit(lambda *a: ssd_scan_kernel(
        *a, n_heads_per_batch=h))(jnp.asarray(xdt), jnp.asarray(loga),
                                  jnp.asarray(b), jnp.asarray(c))
    y_r, st_r = ssd_scan_ref(xdt, loga, b, c, n_heads_per_batch=h)
    np.testing.assert_allclose(np.asarray(y_k), y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), st_r, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# W8A16 int8 matmul (§Perf pair-A follow-up kernel)
# --------------------------------------------------------------------------
INT8_CASES = [(8, 32, 16, jnp.float32), (64, 128, 256, jnp.float32),
              (33, 70, 90, jnp.float32), (16, 64, 64, jnp.bfloat16)]


@pytest.mark.parametrize("m,k,n,dtype", INT8_CASES)
def test_int8_matmul_matches_ref(m, k, n, dtype):
    from repro.kernels.int8_matmul.ops import int8_matmul
    from repro.kernels.int8_matmul.ref import int8_matmul_ref, quantize
    rng = np.random.default_rng(m + n)
    w = rng.normal(size=(k, n)).astype(np.float32)
    w_q, scale = quantize(w)
    x = rnd(7, m, k, dtype=dtype)
    out = int8_matmul(x, jnp.asarray(w_q), jnp.asarray(scale),
                      bm=32, bn=32, bk=32)
    ref = int8_matmul_ref(x, jnp.asarray(w_q), jnp.asarray(scale))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_int8_quantisation_error_bounded():
    from repro.kernels.int8_matmul.ref import quant_error_bound
    rng = np.random.default_rng(0)
    w = rng.normal(size=(128, 64)).astype(np.float32)
    assert quant_error_bound(w) < 1.0 / 127.0


def test_int8_matmul_vs_full_precision_model_level():
    """End-to-end: dequantised matmul ≈ bf16 matmul within int8 error."""
    from repro.kernels.int8_matmul.ops import int8_matmul
    from repro.kernels.int8_matmul.ref import quantize
    rng = np.random.default_rng(3)
    w = (rng.normal(size=(96, 48)) * 0.05).astype(np.float32)
    x = rnd(9, 4, 96)
    w_q, scale = quantize(w)
    out_q = int8_matmul(x, jnp.asarray(w_q), jnp.asarray(scale),
                        bm=32, bn=32, bk=32)
    out_f = jnp.matmul(x, jnp.asarray(w))
    rel = float(jnp.abs(out_q - out_f).max()
                / (jnp.abs(out_f).max() + 1e-9))
    assert rel < 0.02, rel
