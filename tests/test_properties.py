"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_shim import given, settings, st

from repro.models.layers import apply_rope, rms_norm


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 32), st.integers(1, 500), st.integers(1, 500))
def test_rope_is_relative(head_dim, p1, p2):
    """⟨rope(q,p1+c), rope(k,p2+c)⟩ independent of the common offset c."""
    head_dim = head_dim * 2          # even
    q = jax.random.normal(jax.random.key(0), (1, 1, 1, head_dim))
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, head_dim))

    def dot_at(c):
        qa = apply_rope(q, jnp.array([[p1 + c]]), 10000.0)
        ka = apply_rope(k, jnp.array([[p2 + c]]), 10000.0)
        return float((qa * ka).sum())

    assert abs(dot_at(0) - dot_at(137)) < 1e-3


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.floats(0.1, 100.0))
def test_rope_preserves_norm(head_dim, scale):
    head_dim = head_dim * 2
    x = jax.random.normal(jax.random.key(2), (1, 3, 2, head_dim)) * scale
    y = apply_rope(x, jnp.arange(3)[None], 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 128), st.floats(0.5, 100.0))
def test_rms_norm_scale_invariant(d, scale):
    """Exact invariance only up to the eps regulariser — tolerance covers
    the eps/var ratio over the tested scale range."""
    x = jax.random.normal(jax.random.key(3), (2, d))
    s = jnp.zeros((d,))
    a = rms_norm(x, s)
    b = rms_norm(x * scale, s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(2, 16),
       st.integers(1, 8))
def test_moe_router_weights_normalised(t, e, k):
    from repro.configs import reduced_config
    from repro.models.moe import route
    k = min(k, e)
    cfg = reduced_config("deepseek-moe-16b").replace(num_experts=e, top_k=k)
    params = {"router": jax.random.normal(jax.random.key(4), (16, e))}
    x = jax.random.normal(jax.random.key(5), (t, 16))
    w, idx, aux = route(params, x, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(t), rtol=1e-5)
    assert int(idx.max()) < e and int(idx.min()) >= 0
    assert float(aux) >= 0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 100), st.integers(30, 200))
def test_swa_ring_slot_mask(pos, window):
    """Ring-buffer decode mask covers exactly min(pos+1, window) keys."""
    from repro.models.attention import decode_attention
    b, hq, hkv, dd = 1, 2, 1, 8
    q = jnp.ones((b, 1, hq, dd))
    k = jnp.ones((b, window, hkv, dd))
    v = jnp.arange(window, dtype=jnp.float32)[None, :, None, None] \
        * jnp.ones((b, window, hkv, dd))
    out = decode_attention(q, k, v, jnp.asarray(pos), window=window)
    # uniform scores -> output = mean of valid slot values
    valid_abs = [p for p in range(max(0, pos - window + 1), pos + 1)]
    expect = np.mean([p % window for p in valid_abs])
    np.testing.assert_allclose(float(out[0, 0, 0, 0]), expect, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(8, 40))
def test_gbt_monotone_fit_improves_with_trees(depth, n_trees):
    from repro.core.predictors import GBTRegressor, rmse
    rng = np.random.default_rng(depth * 100 + n_trees)
    x = rng.uniform(-1, 1, (200, 4)).astype(np.float32)
    y = np.sin(2 * x[:, 0]) + x[:, 1]
    few = GBTRegressor(n_trees=2, max_depth=depth).fit(x, y)
    many = GBTRegressor(n_trees=n_trees, max_depth=depth).fit(x, y)
    assert rmse(many.predict(x), y) <= rmse(few.predict(x), y) + 1e-9


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4))
def test_scheduler_makespan_lower_bounds(n_tasks, n_nodes):
    """makespan ≥ max single-task time and ≥ total-work / nodes bound."""
    from repro.core import scheduler as sch
    from repro.hw import EDGE_DEVICES
    rng = np.random.default_rng(n_tasks * 10 + n_nodes)
    nodes = [sch.Node(s) for s in list(EDGE_DEVICES.values())[:n_nodes]]
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 1e11)))
             for i in range(n_tasks)]
    etc = sch.etc_matrix(tasks, nodes)
    s = sch.min_min(tasks, nodes, etc)
    assert s.makespan >= etc.min(axis=1).max() - 1e-9
    assert s.makespan >= etc.min(axis=1).sum() / len(nodes) - 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 3))
def test_capacity_drops_only_reduce_moe_output(seed):
    """Tokens dropped by capacity produce strictly fewer combined outputs
    (never garbage): tiny capacity ⇒ output norm ≤ ample capacity."""
    from repro.configs import reduced_config
    from repro.models.moe import moe_mlp
    from repro.models.layers import init_tree
    from repro.models.moe import moe_param_shapes
    cfg = reduced_config("deepseek-moe-16b").replace(
        dtype="float32", num_shared_experts=0)
    params = init_tree(jax.random.key(seed), moe_param_shapes(cfg),
                       jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 10), (32, cfg.d_model))
    y_small, _ = moe_mlp(params, x, cfg.replace(capacity_factor=0.25))
    y_big, _ = moe_mlp(params, x, cfg.replace(capacity_factor=8.0))
    assert float(jnp.linalg.norm(y_small)) <= \
        float(jnp.linalg.norm(y_big)) * 1.5
    assert bool(jnp.isfinite(y_small).all())
