"""Offloading-policy and scheduler tests (paper §II-C/§II-D), incl.
hypothesis property tests on the decision invariants."""
import dataclasses

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import offload as off
from repro.core import scheduler as sch
from repro.core.workloads import WorkloadConfig
from repro.hw import EDGE_DEVICES, get_device


def make_env(link_bw=0.125e9):
    return off.OffloadEnv(device=get_device("pi5-arm"),
                          edge=get_device("edge-server-a100"),
                          link_bw=link_bw, input_bytes=4 * 32 * 784)


@pytest.fixture(scope="module")
def cnn_layers():
    wc = WorkloadConfig("cnn", 2, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32)
    return off.workload_layer_costs(wc)


def test_optimal_beats_degenerate(cnn_layers):
    env = make_env()
    best = off.optimal_split(cnn_layers, env)
    assert best.total_time_s <= off.local_only(cnn_layers, env).total_time_s
    assert best.total_time_s <= off.remote_only(cnn_layers, env).total_time_s
    assert best.total_time_s <= off.greedy_split(cnn_layers, env).total_time_s


def test_fast_link_prefers_edge(cnn_layers):
    """With a huge link and a fast edge server, offload early."""
    fast = make_env(link_bw=12.5e9)
    slow = make_env(link_bw=1e4)     # ~10 kB/s: any transfer dominates
    s_fast = off.optimal_split(cnn_layers, fast).split
    s_slow = off.optimal_split(cnn_layers, slow).split
    assert s_fast <= s_slow
    assert s_slow == len(cnn_layers)
    assert s_fast == 0


def test_qlearning_converges(cnn_layers):
    pol = off.QLearningPolicy(cnn_layers, make_env(), episodes=4000,
                              seed=1).train()
    assert pol.regret() < 0.05 * off.local_only(
        cnn_layers, make_env()).total_time_s + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(1e6, 1e12), st.floats(1e2, 1e8)),
                min_size=1, max_size=12),
       st.floats(1e5, 1e10))
def test_optimal_split_is_global_minimum(layer_spec, link_bw):
    layers = [off.LayerCost(f"l{i}", flops=f, act_bytes=a)
              for i, (f, a) in enumerate(layer_spec)]
    env = make_env(link_bw=link_bw)
    best = off.optimal_split(layers, env)
    for s in range(len(layers) + 1):
        assert best.total_time_s <= off.split_time(layers, s,
                                                   env).total_time_s + 1e-12


def test_transformer_layer_costs():
    from repro.configs import get_config
    cfg = get_config("qwen3-1.7b")
    layers = off.transformer_layer_costs(cfg, seq_len=1024, batch_size=4)
    assert len(layers) == cfg.num_layers
    assert all(l.flops > 0 and l.act_bytes > 0 for l in layers)


# --------------------------------------------------------------------------
# scheduler
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cluster():
    nodes = [sch.Node(spec) for spec in EDGE_DEVICES.values()]
    rng = np.random.default_rng(3)
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                      input_bytes=float(rng.uniform(1e4, 1e7)))
             for i in range(12)]
    return tasks, nodes


def test_minmin_beats_random(cluster):
    tasks, nodes = cluster
    etc = sch.etc_matrix(tasks, nodes)
    mk_minmin = sch.min_min(tasks, nodes, etc).makespan
    mk_rand = np.mean([sch.random_schedule(tasks, nodes, etc, seed=s
                                           ).makespan for s in range(10)])
    assert mk_minmin < mk_rand


def test_heft_close_to_optimal_small(cluster):
    tasks, nodes = cluster
    tasks = tasks[:6]
    nodes = nodes[:3]
    etc = sch.etc_matrix(tasks, nodes)
    opt = sch.optimal_bruteforce(tasks, nodes, etc).makespan
    heft = sch.heft(tasks, nodes, etc).makespan
    assert heft <= 1.6 * opt


def test_all_schedulers_complete_all_tasks(cluster):
    tasks, nodes = cluster
    etc = sch.etc_matrix(tasks, nodes)
    for name, fn in sch.SCHEDULERS.items():
        s = fn(tasks, nodes, etc)
        assert len(s.assignments) == len(tasks), name
        assert s.makespan > 0


def test_predictor_driven_etc(cluster):
    """Plug a trained GBT in as the ETC source (paper's pipeline)."""
    tasks, nodes = cluster
    rng = np.random.default_rng(0)
    # train a quick GBT mapping (log flops, log peak) -> analytic time
    feats, ys = [], []
    for t in tasks:
        for n in nodes:
            feats.append([np.log10(t.flops),
                          np.log10(n.spec.peak_flops_f32),
                          np.log10(max(t.input_bytes, 1.0))])
            ys.append(n.exec_time(t))
    from repro.core.predictors import GBTRegressor
    m = GBTRegressor(n_trees=60, max_depth=4).fit(
        np.array(feats, np.float32), np.array(ys))

    def predictor(t, n):
        f = np.array([[np.log10(t.flops), np.log10(n.spec.peak_flops_f32),
                       np.log10(max(t.input_bytes, 1.0))]], np.float32)
        return float(m.predict(f)[0])

    etc_pred = sch.etc_matrix(tasks, nodes, predictor)
    etc_true = sch.etc_matrix(tasks, nodes)
    mk_pred = sch.min_min(tasks, nodes, etc_true.copy() * 0 + etc_pred)
    mk_true = sch.min_min(tasks, nodes, etc_true)
    # predicted ETC must yield a schedule within 30% of the true-ETC one
    sim = sch.Schedule([
        dataclasses.replace(a) for a in mk_pred.assignments])
    assert sim.makespan <= 1.3 * mk_true.makespan


def test_mdp_lower_bounds_heuristics(cluster):
    tasks, nodes = cluster
    tasks, nodes = tasks[:5], nodes[:2]
    etc = sch.etc_matrix(tasks, nodes)
    mdp = sch.SchedulingMDP(tasks, nodes, etc, backlog_levels=24)
    v = mdp.solve()
    mk = sch.min_min(tasks, nodes, etc).makespan
    assert v <= mk * 1.1   # discretisation slack


def test_pomdp_belief_between_oblivious_and_omniscient():
    """QMDP belief scheduling beats oblivious and approaches omniscient as
    monitoring accuracy rises (paper §II-D PO-MDP formulation)."""
    from repro.core import pomdp
    from repro.hw import EDGE_DEVICES
    rng = np.random.default_rng(0)
    nodes = [sch.Node(s) for s in EDGE_DEVICES.values()]
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(5e10, 5e11)))
             for i in range(60)]

    def mean_makespan(policy, acc):
        return np.mean([pomdp.simulate(tasks, nodes, policy=policy,
                                       obs_accuracy=acc, seed=s)
                        for s in range(8)])

    omni = mean_makespan("omniscient", 0.9)
    belief_hi = mean_makespan("belief", 0.95)
    belief_lo = mean_makespan("belief", 0.4)
    obliv = mean_makespan("oblivious", 0.9)
    assert belief_hi <= obliv * 1.02, (belief_hi, obliv)
    assert omni <= belief_hi * 1.05
    # better monitoring -> better schedules
    assert belief_hi <= belief_lo * 1.05
