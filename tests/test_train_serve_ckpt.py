"""Training loop, serving engine and checkpoint round-trip tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt

# end-to-end train/serve/checkpoint round-trips: ~1 minute on CPU —
# excluded from the fast lane, covered by the tier-1 job
pytestmark = pytest.mark.slow
from repro.configs import reduced_config
from repro.serve import Request, ServeEngine
from repro.train import TrainConfig, train


def test_train_loop_decreases_loss():
    cfg = reduced_config("gemma-2b").replace(dtype="float32")
    res = train(cfg, TrainConfig(steps=8, batch_size=2, seq_len=32,
                                 lr=2e-3, log_every=0))
    assert res.losses[-1] < res.losses[0]
    assert np.isfinite(res.losses).all()


def test_serve_engine_batched_requests():
    cfg = reduced_config("qwen3-1.7b").replace(dtype="float32")
    engine = ServeEngine(cfg, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(4, 24)), dtype=np.int32),
        max_new_tokens=6) for i in range(5)]
    done = engine.serve(reqs)
    assert len(done) == 5
    assert all(r.output is not None and len(r.output) == 6 for r in done)
    assert engine.stats.tokens_out >= 5 * 6


def test_serve_greedy_deterministic():
    cfg = reduced_config("xlstm-350m").replace(dtype="float32")
    engine = ServeEngine(cfg, batch_size=2, max_len=48)
    prompts = np.tile(np.arange(8, dtype=np.int32)[None], (2, 1))
    a = engine.generate_batch(prompts, 5)
    b = engine.generate_batch(prompts, 5)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[0], a[1])   # identical rows


def test_serve_per_request_temperature_and_ttft():
    """serve() must honour each request's temperature (not chunk[0]'s) and
    populate first_token_s."""
    cfg = reduced_config("xlstm-350m").replace(dtype="float32")
    engine = ServeEngine(cfg, batch_size=2, max_len=48)
    prompts = np.tile(np.arange(8, dtype=np.int32)[None], (2, 1))
    greedy = engine.generate_batch(prompts, 5)
    mixed = engine.generate_batch(
        prompts, 5, temperature=np.array([0.0, 5.0], np.float32))
    # the greedy row is unaffected by its neighbour's sampling temperature
    np.testing.assert_array_equal(mixed[0], greedy[0])
    assert engine.last_first_token_s > 0

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=6,
                                        dtype=np.int32),
                    max_new_tokens=4, temperature=0.7 * i)
            for i in range(3)]
    done = engine.serve(reqs)
    assert len(done) == 3
    assert all(r.first_token_s > 0 for r in done)
    assert all(r.total_s >= r.first_token_s for r in done)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)]}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt_3.npz")
        ckpt.save(path, tree, step=3, metadata={"note": "t"})
        restored, meta = ckpt.restore(path, tree)
        assert meta["step"] == 3 and meta["note"] == "t"
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert ckpt.latest(td) == path


def test_continuous_batching_matches_static():
    """Continuous batching with ragged admission produces the same greedy
    tokens as one-request-at-a-time static decoding."""
    from repro.serve.continuous import ContinuousBatchEngine
    cfg = reduced_config("qwen3-1.7b").replace(dtype="float32")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (5, 9, 7, 12, 4, 6)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6, arrived_at=i * 0.01)
            for i, p in enumerate(prompts)]
    eng = ContinuousBatchEngine(cfg, slots=2, max_len=48, seed=3)
    done = eng.serve(reqs)
    assert len(done) == len(prompts)
    assert eng.occupancy > 1.0            # slots actually shared

    # reference: static batch-1 greedy decode with the same params
    ref_engine = ServeEngine(cfg, batch_size=1, max_len=48, seed=0)
    ref_engine.params = eng.params
    for r in sorted(done, key=lambda r: r.rid):
        out_ref = ref_engine.generate_batch(
            r.prompt[None], r.max_new_tokens)
        np.testing.assert_array_equal(r.output, out_ref[0])


def test_continuous_engine_replans_offload_per_admission():
    """With a cost model, every admitted request gets a fresh offload split
    planned against the link observation at admission time."""
    from repro.core.costs import AnalyticCost
    from repro.core.decisions import decide_all, make_envs
    from repro.core.offload import transformer_layer_costs
    from repro.hw import get_device
    from repro.serve.continuous import ContinuousBatchEngine
    cfg = reduced_config("qwen3-1.7b").replace(dtype="float32")
    # link degrades between admissions: first requests see wired, later
    # ones a congested cell link
    observations = iter([1.25e9, 1.25e9, 0.125e9 / 64, 0.125e9 / 64])
    eng = ContinuousBatchEngine(cfg, slots=2, max_len=48, seed=3,
                                cost=AnalyticCost(),
                                link_bw=lambda: next(observations))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (5, 9, 7, 12)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=3, arrived_at=i * 0.01)
            for i, p in enumerate(prompts)]
    done = eng.serve(reqs)
    assert len(done) == 4 and eng.replans == 4
    device, edge = get_device("jetson-orin-nano"), \
        get_device("edge-server-a100")
    for r, bw in zip(sorted(done, key=lambda r: r.rid),
                     [1.25e9, 1.25e9, 0.125e9 / 64, 0.125e9 / 64]):
        assert r.offload is not None
        assert r.admitted_at >= r.arrived_at
        layers = transformer_layer_costs(cfg, len(r.prompt), 1)
        envs = make_envs(device, edge, link_bw=np.asarray([bw]),
                         input_bytes=4.0 * len(r.prompt))
        expect = decide_all(layers, envs, cost=AnalyticCost())[0]
        assert r.offload.split == expect.split
        np.testing.assert_allclose(r.offload.total_time_s,
                                   expect.total_time_s, rtol=1e-12)


def test_continuous_engine_honours_arrival_clock():
    """Regression: serve() used to admit a request the moment a slot
    freed, ignoring ``arrived_at``.  The engine now threads virtual time
    (decode steps × step latency, idle jumps to the next arrival) and
    never admits a request before it arrives."""
    from repro.sim.events import Clock
    from repro.serve.continuous import ContinuousBatchEngine
    cfg = reduced_config("qwen3-1.7b").replace(dtype="float32")
    clock = Clock()
    eng = ContinuousBatchEngine(cfg, slots=2, max_len=48, seed=3,
                                clock=clock, step_latency_s=5e-3)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (5, 7, 6)]
    # r1 arrives while r0 decodes; r2 arrives long after the engine idles
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=4,
                    arrived_at=0.0),
            Request(rid=1, prompt=prompts[1], max_new_tokens=4,
                    arrived_at=0.012),
            Request(rid=2, prompt=prompts[2], max_new_tokens=4,
                    arrived_at=10.0)]
    done = eng.serve(reqs)
    assert len(done) == 3
    by = {r.rid: r for r in done}
    # the invariant the bug violated: no admission before arrival
    assert all(r.admitted_at >= r.arrived_at for r in done)
    assert by[0].admitted_at == 0.0
    # r1 had a free slot from t=0 but still waits for its arrival, then
    # is admitted within a step of it
    assert by[1].admitted_at <= 0.012 + 2 * eng.step_latency_s
    # idle engine jumps the clock to the next arrival, not before
    assert by[2].admitted_at == 10.0
    assert clock.now >= 10.0
    # outputs stay exactly the static greedy reference despite the gaps
    ref = ServeEngine(cfg, batch_size=1, max_len=48, seed=0)
    ref.params = eng.params
    for r in done:
        np.testing.assert_array_equal(
            r.output, ref.generate_batch(r.prompt[None],
                                         r.max_new_tokens)[0])
