"""Vectorized-vs-scalar equivalence for the batched decision core.

Pins the O(L) prefix-sum split evaluation, the batched environment sweep,
and the array-native schedulers to their retained scalar oracles.  Runs
without hypothesis on purpose: these are the tier-1 guarantees that the
perf rewrite changed nothing semantically.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import decisions as dec
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.hw import EDGE_DEVICES, get_device


def rand_layers(rng, n):
    return [off.LayerCost(f"l{i}",
                          flops=float(rng.uniform(1e6, 1e12)),
                          act_bytes=float(rng.uniform(1e2, 1e8)))
            for i in range(n)]


def rand_env(rng):
    specs = list(EDGE_DEVICES.values())
    return off.OffloadEnv(
        device=specs[int(rng.integers(len(specs)))],
        edge=specs[int(rng.integers(len(specs)))],
        link_bw=float(rng.uniform(1e4, 1e10)),
        link_latency_s=float(rng.uniform(0.0, 0.05)),
        input_bytes=float(rng.uniform(0.0, 1e7)))


# --------------------------------------------------------------------------
# split_times_all vs the scalar split_time, every split point
# --------------------------------------------------------------------------
@pytest.mark.parametrize("trial", range(20))
def test_split_times_all_matches_scalar(trial):
    rng = np.random.default_rng(trial)
    layers = rand_layers(rng, int(rng.integers(1, 24)))
    env = rand_env(rng)
    t_all = off.split_times_all(layers, env)
    assert t_all.shape == (len(layers) + 1,)
    for s in range(len(layers) + 1):
        d = off.split_time(layers, s, env)
        np.testing.assert_allclose(t_all[s], d.total_time_s,
                                   rtol=1e-9, atol=1e-9)


def test_split_components_match_scalar_fields():
    rng = np.random.default_rng(7)
    layers = rand_layers(rng, 9)
    env = rand_env(rng)
    dev_cum, xfer, edge_cum = off.split_components(layers, env)
    for s in range(len(layers) + 1):
        d = off.split_time(layers, s, env)
        np.testing.assert_allclose(dev_cum[s], d.device_time_s,
                                   rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(xfer[s], d.transfer_time_s,
                                   rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(edge_cum[s], d.edge_time_s,
                                   rtol=1e-9, atol=1e-9)


def test_split_times_all_empty_chain():
    env = rand_env(np.random.default_rng(0))
    t = off.split_times_all([], env)
    assert t.shape == (1,) and t[0] == 0.0


@pytest.mark.parametrize("trial", range(10))
def test_optimal_and_greedy_match_refs(trial):
    rng = np.random.default_rng(100 + trial)
    layers = rand_layers(rng, int(rng.integers(1, 20)))
    env = rand_env(rng)
    a, b = off.optimal_split(layers, env), off.optimal_split_ref(layers, env)
    np.testing.assert_allclose(a.total_time_s, b.total_time_s,
                               rtol=1e-9, atol=1e-9)
    g, h = off.greedy_split(layers, env), off.greedy_split_ref(layers, env)
    assert g.split == h.split
    np.testing.assert_allclose(g.total_time_s, h.total_time_s,
                               rtol=1e-9, atol=1e-9)


def test_optimal_split_honours_time_fn():
    rng = np.random.default_rng(3)
    layers = rand_layers(rng, 8)
    env = rand_env(rng)

    def tf(lc, dev):
        return lc.flops / dev.peak_flops_f32 * 2.0

    a = off.optimal_split(layers, env, time_fn=tf)
    b = off.optimal_split_ref(layers, env, time_fn=tf)
    np.testing.assert_allclose(a.total_time_s, b.total_time_s,
                               rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------
# batched environment sweep
# --------------------------------------------------------------------------
def test_latency_matrix_matches_per_env_vectors():
    rng = np.random.default_rng(11)
    layers = rand_layers(rng, 14)
    envs_list = [rand_env(rng) for _ in range(32)]
    lat = dec.latency_matrix(layers, dec.stack_envs(envs_list))
    assert lat.shape == (32, len(layers) + 1)
    for i, env in enumerate(envs_list):
        np.testing.assert_allclose(lat[i], off.split_times_all(layers, env),
                                   rtol=1e-9, atol=1e-9)


def test_decide_all_matches_scalar_loop():
    rng = np.random.default_rng(13)
    layers = rand_layers(rng, 10)
    env = rand_env(rng)
    bws = np.geomspace(1e4, 1e10, 64)
    plan = dec.sweep_links(layers, env, bws)
    assert len(plan) == 64
    for i, bw in enumerate(bws):
        d = off.optimal_split(layers,
                              dataclasses.replace(env, link_bw=float(bw)))
        np.testing.assert_allclose(plan.total_time_s[i], d.total_time_s,
                                   rtol=1e-9, atol=1e-9)
        got = plan[i]
        np.testing.assert_allclose(
            got.device_time_s + got.transfer_time_s + got.edge_time_s,
            got.total_time_s, rtol=1e-9, atol=1e-9)


def test_sweep_links_efficiency_passthrough():
    """sweep_links must honour efficiency= exactly like decide_all does —
    it used to silently pin DEFAULT_EFFICIENCY."""
    rng = np.random.default_rng(23)
    layers = rand_layers(rng, 9)
    env = rand_env(rng)
    bws = np.geomspace(1e5, 1e9, 16)
    plan = dec.sweep_links(layers, env, bws, 0.5)
    envs = dec.make_envs(env.device, env.edge, link_bw=bws,
                         link_latency_s=env.link_latency_s,
                         input_bytes=env.input_bytes)
    want = dec.decide_all(layers, envs, 0.5)
    assert np.array_equal(plan.splits, want.splits)
    assert np.array_equal(plan.total_time_s, want.total_time_s)
    # and a non-default efficiency must actually change the outcome
    base = dec.sweep_links(layers, env, bws)
    assert not np.array_equal(plan.total_time_s, base.total_time_s)


def test_sweep_links_rejects_efficiency_with_cost():
    """Same conflict guard as decide_all: efficiency= belongs to the
    analytic default and must not be silently dropped with cost=."""
    from repro.core import costs as co
    rng = np.random.default_rng(24)
    layers = rand_layers(rng, 4)
    env = rand_env(rng)
    with pytest.raises(ValueError, match="efficiency"):
        dec.sweep_links(layers, env, [1e8], 0.5, cost=co.AnalyticCost())


class _PriceOnlyCost:
    """Latency-free cost model: ranks splits by shipped bytes alone."""
    objectives = ("price",)

    def components(self, layers, envs):
        return dec.transfer_bytes(layers, envs)[..., None] * 1e-9

    def scalarize(self, components):
        return np.asarray(components)[..., 0]


def test_total_time_nan_without_latency_objective():
    """A cost model without "latency_s" has no seconds to report —
    total_time_s must be NaN, not the scalarised cost in arbitrary units
    (the ranking value lives in scalar_cost)."""
    rng = np.random.default_rng(25)
    layers = rand_layers(rng, 7)
    envs = dec.make_envs(get_device("pi5-arm"),
                         get_device("edge-server-a100"),
                         link_bw=np.geomspace(1e5, 1e9, 8),
                         input_bytes=1e5)
    plan = dec.decide_all(layers, envs, cost=_PriceOnlyCost())
    assert np.isnan(plan.total_time_s).all()
    assert np.isfinite(plan.scalar_cost).all()
    comp = _PriceOnlyCost().components(layers, envs)
    rows = np.arange(len(envs))
    np.testing.assert_array_equal(plan.scalar_cost,
                                  comp[rows, plan.splits, 0])
    np.testing.assert_array_equal(plan.objective("price"),
                                  comp[rows, plan.splits, 0])


def test_make_envs_broadcasts_device_vectors():
    devs = [get_device("pi5-arm"), get_device("xps15-i5")]
    envs = dec.make_envs(devs, get_device("edge-server-a100"),
                         link_bw=1e8, input_bytes=1e4)
    assert len(envs) == 2
    assert envs.dev_flops[0] != envs.dev_flops[1]
    assert (envs.edge_flops[0] == envs.edge_flops[1]
            == get_device("edge-server-a100").peak_flops_f32)


def test_qlearning_latency_table_matches_split_times():
    rng = np.random.default_rng(17)
    layers = rand_layers(rng, 6)
    env = rand_env(rng)
    pol = off.QLearningPolicy(layers, env, episodes=10)
    table = pol.latency_table()
    assert table.shape == (len(pol.link_buckets), len(layers) + 1)
    for b, bw in enumerate(pol.link_buckets):
        e = dataclasses.replace(env, link_bw=bw)
        np.testing.assert_allclose(table[b], off.split_times_all(layers, e),
                                   rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------
# vectorized schedulers vs scalar oracles
# --------------------------------------------------------------------------
def rand_instance(rng, n_tasks, n_nodes):
    specs = list(EDGE_DEVICES.values())
    nodes = [sch.Node(specs[int(rng.integers(len(specs)))])
             for _ in range(n_nodes)]
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(1e8, 1e12)),
                      input_bytes=float(rng.uniform(1e3, 1e7)))
             for i in range(n_tasks)]
    return tasks, nodes, sch.etc_matrix(tasks, nodes)


@pytest.mark.parametrize("trial", range(12))
@pytest.mark.parametrize("name", ["min_min", "max_min", "heft"])
def test_vectorized_scheduler_matches_ref(name, trial):
    rng = np.random.default_rng(trial * 31 + len(name))
    tasks, nodes, etc = rand_instance(rng, int(rng.integers(1, 40)),
                                      int(rng.integers(1, 8)))
    fast = sch.SCHEDULERS[name](tasks, nodes, etc)
    ref = sch.SCHEDULERS_REF[name](tasks, nodes, etc)
    assert fast.makespan == ref.makespan        # bit-for-bit
    assert len(fast.assignments) == len(tasks)
    for a, b in zip(fast.assignments, ref.assignments):
        assert (a.task.name, a.node) == (b.task.name, b.node)
        np.testing.assert_allclose([a.start, a.finish], [b.start, b.finish],
                                   rtol=0, atol=0)


def test_vectorized_scheduler_empty_tasks():
    """Draining to an empty queue must no-op, not crash (etc_matrix of an
    empty task list is 1-D)."""
    nodes = [sch.Node(s) for s in list(EDGE_DEVICES.values())[:2]]
    etc = sch.etc_matrix([], nodes)
    for name in ("min_min", "max_min", "heft"):
        s = sch.SCHEDULERS[name]([], nodes, etc)
        assert s.assignments == [] and s.makespan == 0.0, name


def test_vectorized_scheduler_respects_busy_nodes():
    """Non-zero ``available_at`` (infrastructure monitoring) must be read,
    not reset, by the array paths."""
    rng = np.random.default_rng(5)
    tasks, nodes, etc = rand_instance(rng, 10, 3)
    for j, n in enumerate(nodes):
        n.available_at = float(j) * 0.5
    for name in ("min_min", "max_min", "heft"):
        fast = sch.SCHEDULERS[name](tasks, nodes, etc)
        ref = sch.SCHEDULERS_REF[name](tasks, nodes, etc)
        assert fast.makespan == ref.makespan, name
        # inputs must not be mutated by either path
        assert [n.available_at for n in nodes] == [0.0, 0.5, 1.0]
