"""Model-level invariants:

  * chunked (flash-style) attention ≡ naive attention
  * mLSTM chunkwise ≡ mLSTM sequential recurrence
  * SSD chunked scan ≡ SSD single-step recurrence
  * step-by-step decode ≡ teacher-forced forward (per family)
  * MLA absorbed decode ≡ naive decode
  * sliding-window ring-buffer decode ≡ windowed full attention
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# per-family model equivalence sweeps: ~2 minutes on CPU — excluded from
# the fast lane, covered by the tier-1 job
pytestmark = pytest.mark.slow

from repro.configs import reduced_config
from repro.data.synthetic import prefill_batch
from repro.models import build_model

jax.config.update("jax_default_matmul_precision", "highest")


def rnd(key, *shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32) * 0.5


# --------------------------------------------------------------------------
# attention path equivalence
# --------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv,window", [(4, 4, 0), (8, 2, 0), (4, 1, 0),
                                           (4, 2, 7)])
def test_chunked_equals_naive(hq, hkv, window):
    from repro.models.attention import chunked_attention, naive_attention
    b, s, d = 2, 33, 16
    q, k, v = rnd(0, b, s, hq, d), rnd(1, b, s, hkv, d), rnd(2, b, s, hkv, d)
    ref = naive_attention(q, k, v, causal=True, window=window)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_mlstm_chunked_equals_sequential():
    from repro.models.xlstm import mlstm_chunked, mlstm_sequential
    b, s, h, d = 2, 37, 2, 8
    q, k, v = rnd(0, b, s, h, d), rnd(1, b, s, h, d), rnd(2, b, s, h, d)
    i_raw = rnd(3, b, s, h) * 2.0
    f_raw = rnd(4, b, s, h) * 2.0 + 2.0
    ref, (c_r, n_r, m_r) = mlstm_sequential(q, k, v, i_raw, f_raw)
    out, (c, n, m) = mlstm_chunked(q, k, v, i_raw, f_raw, chunk=8)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, c_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(m, m_r, rtol=1e-4, atol=1e-5)


def test_ssd_chunked_equals_stepwise():
    from repro.models.mamba2 import ssd_chunked, ssd_step
    b, s, h, p, n = 2, 19, 3, 4, 5
    x = rnd(0, b, s, h, p)
    dt = jax.nn.softplus(rnd(1, b, s, h))
    bb, cc = rnd(2, b, s, n), rnd(3, b, s, n)
    a_log = jnp.log(jnp.array([1.0, 2.0, 4.0]))
    d_skip = jnp.ones((h,))
    y_chunk, state_chunk = ssd_chunked(x, dt, a_log, bb, cc, d_skip, chunk=4)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_step(state, x[:, t], dt[:, t], a_log, bb[:, t],
                            cc[:, t], d_skip)
        ys.append(y)
    ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(state_chunk, state, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# decode ≡ forward per family
# --------------------------------------------------------------------------
DECODE_ARCHS = ["qwen3-1.7b", "deepseek-v2-lite-16b", "deepseek-moe-16b",
                "xlstm-350m", "zamba2-1.2b", "whisper-tiny", "gemma-2b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_forward(name):
    """prefill(S) then decode one token ≡ prefill(S+1) last-token logits."""
    cfg = reduced_config(name).replace(dtype="float32")
    if cfg.num_experts:
        # decode never hits the capacity limit, so disable dropping in the
        # teacher-forced reference for an apples-to-apples comparison
        cfg = cfg.replace(capacity_factor=8.0)
    api = build_model(cfg, impl="naive")
    params = api.init_params(jax.random.key(1))
    s, b = 12, 2
    pb = prefill_batch(cfg, b, s + 1, seed=3)

    def shorten(batch):
        out = dict(batch)
        if "tokens" in out:
            out["tokens"] = out["tokens"][:, :s]
        if "embeds" in out:
            out["embeds"] = out["embeds"][:, :s]
        return out

    logits_full, _ = api.prefill(params, pb, s + 4)
    _, cache = api.prefill(params, shorten(pb), s + 4)
    if cfg.family == "vlm":
        pytest.skip("vlm decodes tokens but prefills embeds (no shared path)")
    next_tok = {"token": pb["tokens"][:, s:s + 1]}
    logits_step, _ = api.decode_step(params, next_tok, cache)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full),
                               rtol=5e-4, atol=5e-4)


def test_mla_absorbed_equals_naive_decode():
    cfg = reduced_config("deepseek-v2-lite-16b").replace(dtype="float32")
    api_n = build_model(cfg.replace(mla_absorbed=False), impl="naive")
    api_a = build_model(cfg.replace(mla_absorbed=True), impl="naive")
    params = api_n.init_params(jax.random.key(2))
    pb = prefill_batch(cfg, 2, 10)
    _, cache = api_n.prefill(params, pb, 16)
    tok = {"token": jnp.array([[3], [5]], jnp.int32)}
    ln, _ = api_n.decode_step(params, tok, cache)
    la, _ = api_a.decode_step(params, tok, cache)
    np.testing.assert_allclose(np.asarray(la), np.asarray(ln),
                               rtol=5e-4, atol=5e-4)


def test_sliding_window_ring_decode():
    """SWA ring-buffer decode ≡ full-cache decode with window mask."""
    cfg = reduced_config("qwen3-1.7b").replace(dtype="float32")
    cfg_swa = cfg.with_window(8)
    api = build_model(cfg, impl="naive")
    api_swa = build_model(cfg_swa, impl="naive")
    params = api.init_params(jax.random.key(4))
    s = 13
    pb = prefill_batch(cfg, 2, s, seed=7)
    _, cache_full = api.prefill(params, pb, s + 4)
    _, cache_ring = api_swa.prefill(params, pb, s + 4)
    assert cache_ring["layers"]["k"].shape[2] == 8   # ring bounded by window
    tok = {"token": pb["tokens"][:, -1:]}
    # reference: decode against the full cache of the *windowed* model
    # (window masking applied inside decode_attention via cfg.window)
    cfg_wfull = cfg.replace(window=8)
    import repro.models.transformer as tr

    # full-cache windowed decode: use the unwindowed cache but mask manually
    logits_ring, _ = api_swa.decode_step(params, tok, cache_ring)
    # brute force: forward the whole sequence + window via naive attention
    full_tokens = jnp.concatenate([pb["tokens"], tok["token"]], axis=1)
    logits_ref, _ = tr.forward(params, {"tokens": full_tokens}, cfg_swa,
                               impl="naive")
    np.testing.assert_allclose(np.asarray(logits_ring[:, 0]),
                               np.asarray(logits_ref[:, -1]),
                               rtol=5e-4, atol=5e-4)
