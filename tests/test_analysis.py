"""repro.analysis invariant linter: fixture, suppression and self-clean
tests.

Each shipped rule gets a golden pair — a known-bad snippet it must fire
on and a clean snippet it must stay silent on — plus suppression
round-trips and the KRN001 deliberate-desync fixtures the acceptance
criteria call out.  The self-clean test is the real contract: the
linter reports zero findings at severity >= warning over ``src/``.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (REGISTRY, Severity, analyze_source,
                            analyze_sources, run_paths)
from repro.analysis.cli import main as cli_main

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def ids(findings):
    return [f.rule for f in findings]


def check(source, *, module="", path="snippet.py", select=None):
    return analyze_source(textwrap.dedent(source), module=module,
                          path=path, select=select)


# --------------------------------------------------------------------------
# RNG001 — legacy global np.random.*
# --------------------------------------------------------------------------
class TestRNG001:
    def test_fires_on_legacy_calls(self):
        bad = """
            import numpy as np
            np.random.seed(0)
            x = np.random.rand(4)
            y = np.random.randint(0, 10)
        """
        found = check(bad, select=["RNG001"])
        assert ids(found) == ["RNG001"] * 3

    def test_fires_on_randomstate_and_import(self):
        bad = """
            import numpy as np
            from numpy.random import rand
            rs = np.random.RandomState(3)
        """
        assert ids(check(bad, select=["RNG001"])) == ["RNG001"] * 2

    def test_silent_on_generator_api(self):
        clean = """
            import numpy as np
            rng = np.random.default_rng(0)
            ss = np.random.SeedSequence(42)
            gen = np.random.Generator(np.random.PCG64(ss))
            x = rng.normal(size=3)
        """
        assert check(clean, select=["RNG001"]) == []


# --------------------------------------------------------------------------
# RNG002 — fresh literal/OS-entropy seeds inside repro.sim / repro.oracle
# --------------------------------------------------------------------------
class TestRNG002:
    BAD = """
        import numpy as np
        def arrivals():
            rng = np.random.default_rng(1234)
            return rng.random(8)
        def entropy():
            return np.random.default_rng()
    """

    def test_fires_inside_sim(self):
        found = check(self.BAD, module="repro.sim.arrivals",
                      select=["RNG002"])
        assert ids(found) == ["RNG002"] * 2

    def test_fires_inside_oracle(self):
        assert ids(check(self.BAD, module="repro.oracle.online",
                         select=["RNG002"])) == ["RNG002"] * 2

    def test_silent_outside_scope(self):
        # benchmarks/examples may pin literal seeds freely
        assert check(self.BAD, module="repro.core.workloads",
                     select=["RNG002"]) == []
        assert check(self.BAD, module="", select=["RNG002"]) == []

    def test_silent_on_threaded_seed(self):
        clean = """
            import numpy as np
            from repro.sim.queueing import spawn_streams
            def make(seed):
                rng = np.random.default_rng(seed)
                child = np.random.default_rng(spawn_streams(seed, 2)[0])
                return rng, child
            class P:
                def __post_init__(self):
                    self._rng = np.random.default_rng(self.seed)
        """
        assert check(clean, module="repro.sim.state",
                     select=["RNG002"]) == []


# --------------------------------------------------------------------------
# DET001 — matmul in fma-sensitive modules
# --------------------------------------------------------------------------
class TestDET001:
    def test_fires_in_tagged_module(self):
        bad = """
            # repro: module-tags=fma-sensitive
            import numpy as np
            import jax.numpy as jnp
            def scalarize(comp, w):
                return comp @ w
            def lower(a, b):
                return jnp.dot(a, b) + np.einsum("ij,j->i", a, b)
        """
        found = check(bad, select=["DET001"])
        assert ids(found) == ["DET001"] * 3

    def test_silent_without_tag(self):
        bad = """
            import numpy as np
            def scalarize(comp, w):
                return comp @ w
        """
        assert check(bad, select=["DET001"]) == []

    def test_silent_on_sequential_accumulation(self):
        clean = """
            # repro: module-tags=fma-sensitive
            import numpy as np
            def scalarize(comp, w):
                out = comp[..., 0] * w[0]
                for k in range(1, w.size):
                    out = out + comp[..., k] * w[k]
                return out
        """
        assert check(clean, select=["DET001"]) == []


# --------------------------------------------------------------------------
# DET002 — wall clock in virtual-time modules
# --------------------------------------------------------------------------
class TestDET002:
    BAD = """
        import time
        from datetime import datetime
        def step(clock):
            now = time.time()
            t = time.perf_counter()
            stamp = datetime.now()
            return now + t
    """

    def test_fires_in_sim_and_serve(self):
        assert ids(check(self.BAD, module="repro.sim.events",
                         select=["DET002"])) == ["DET002"] * 3
        assert ids(check(self.BAD, module="repro.serve.continuous",
                         select=["DET002"])) == ["DET002"] * 3

    def test_silent_outside_scope(self):
        # benchmarks and the profiler measure real wall time by design
        assert check(self.BAD, module="repro.core.profiler",
                     select=["DET002"]) == []

    def test_silent_on_virtual_clock(self):
        clean = """
            def step(clock, queue):
                now = clock.now
                evt = queue.pop(now)
                return now, evt
        """
        assert check(clean, module="repro.sim.events",
                     select=["DET002"]) == []


# --------------------------------------------------------------------------
# JIT001 — jitted functions closing over mutable state
# --------------------------------------------------------------------------
class TestJIT001:
    def test_fires_on_mutable_global_read(self):
        bad = """
            import jax
            CACHE = {}
            @jax.jit
            def f(x):
                return x + CACHE["bias"]
        """
        assert ids(check(bad, select=["JIT001"])) == ["JIT001"]

    def test_fires_on_rebound_global_and_attr_store(self):
        bad = """
            import jax
            SCALE = 1.0
            SCALE = 2.0
            @jax.jit
            def g(self, x):
                self.cached = x * SCALE
                return self.cached
        """
        assert sorted(ids(check(bad, select=["JIT001"]))) == \
            ["JIT001", "JIT001"]

    def test_silent_on_constant_closure(self):
        clean = """
            import functools
            import jax
            import numpy as np
            TABLE = np.arange(8.0)        # immutable-by-convention const
            @functools.partial(jax.jit, static_argnames=("n",))
            def f(x, n):
                local = {}
                local["y"] = x * TABLE[0]
                return local["y"] + n
        """
        assert check(clean, select=["JIT001"]) == []


# --------------------------------------------------------------------------
# JIT002 — Python branches on traced arguments
# --------------------------------------------------------------------------
class TestJIT002:
    def test_fires_on_if_and_while(self):
        bad = """
            import jax
            @jax.jit
            def f(x, lo):
                if x > 0:
                    return x
                while lo < 4:
                    lo = lo + 1
                return lo
        """
        assert ids(check(bad, select=["JIT002"])) == ["JIT002"] * 2

    def test_static_argnames_exempt(self):
        clean = """
            import functools
            import jax
            import jax.numpy as jnp
            @functools.partial(jax.jit, static_argnames=("causal",))
            def f(q, causal):
                if causal:
                    return jnp.tril(q)
                return jnp.where(q > 0, q, 0.0)
        """
        assert check(clean, select=["JIT002"]) == []

    def test_static_argnums_and_is_none_exempt(self):
        clean = """
            import functools
            import jax
            @functools.partial(jax.jit, static_argnums=(1,))
            def f(x, mode, scale=None):
                if scale is None:
                    scale = 1.0
                if mode == "fast":
                    return x * scale
                return x
        """
        assert check(clean, select=["JIT002"]) == []


# --------------------------------------------------------------------------
# KRN001 — kernel-triple signature + SPEC layout contracts
# --------------------------------------------------------------------------
GOOD_KERNEL = """
    SPEC_A, SPEC_B, SPEC_C = range(3)
    SPEC_D = 3
    SPEC_LEN = 4
    def pack_spec(weights):
        return weights
"""

GOOD_REF = """
    def toy_ref(x, w, scale=1.0, *, clip=None):
        return x * w * scale
"""

GOOD_OPS = """
    def toy(x, w, scale=1.0, *, clip=None, block=128, interpret=None):
        return x * w * scale
"""


def kernel_fixture(name, ref=GOOD_REF, ops=GOOD_OPS, kernel=GOOD_KERNEL):
    files = [
        (f"src/repro/kernels/{name}/ref.py",
         f"repro.kernels.{name}.ref", textwrap.dedent(ref)),
        (f"src/repro/kernels/{name}/ops.py",
         f"repro.kernels.{name}.ops", textwrap.dedent(ops)),
        (f"src/repro/kernels/{name}/kernel.py",
         f"repro.kernels.{name}.kernel", textwrap.dedent(kernel)),
    ]
    return analyze_sources(files, select=["KRN001"])


class TestKRN001:
    def test_clean_triple_is_silent(self):
        assert kernel_fixture("toy") == []

    def test_spec_len_desync_row_out_of_range(self):
        # the historical hazard: rows grown 9 -> 12 but SPEC_LEN stale
        bad = """
            SPEC_A, SPEC_B, SPEC_C = range(3)
            SPEC_WAIT, SPEC_TEXC, SPEC_W4 = range(3, 6)
            SPEC_LEN = 3
        """
        found = kernel_fixture("toy", kernel=bad)
        assert ids(found) == ["KRN001"] * 3     # rows 3,4,5 out of range
        assert "out of range" in found[0].message

    def test_spec_len_desync_unused_rows(self):
        # the inverse: SPEC_LEN bumped, constants not re-laid
        bad = """
            SPEC_A, SPEC_B = range(2)
            SPEC_LEN = 4
        """
        found = kernel_fixture("toy", kernel=bad)
        assert ids(found) == ["KRN001"]
        assert "desynced" in found[0].message

    def test_spec_constants_without_len(self):
        found = kernel_fixture("toy", kernel="SPEC_A, SPEC_B = range(2)\n")
        assert ids(found) == ["KRN001"]
        assert "SPEC_LEN" in found[0].message

    def test_signature_drift_positional(self):
        drifted = """
            def toy(x, weights, scale=1.0, *, clip=None):
                return x
        """
        found = kernel_fixture("toy", ops=drifted)
        assert ids(found) == ["KRN001"]
        assert "positional parameters diverge" in found[0].message

    def test_signature_drift_missing_kwonly(self):
        drifted = """
            def toy(x, w, scale=1.0, *, block=128):
                return x
        """
        found = kernel_fixture("toy", ops=drifted)
        assert ids(found) == ["KRN001"]
        assert "clip" in found[0].message

    def test_jax_suffix_pairing(self):
        ref = """
            def toy_ref(x, w):
                return x * w
        """
        ops = """
            def toy_jax(x, wrong_name):
                return x
        """
        found = kernel_fixture("toy", ref=ref, ops=ops)
        assert ids(found) == ["KRN001"]

    def test_real_decide_split_layout_is_clean(self):
        path = os.path.join(SRC, "repro/kernels/decide_split/kernel.py")
        assert [f for f in run_paths([path], select=["KRN001"])] == []


# --------------------------------------------------------------------------
# UNIT001 — mixed unit-suffix arithmetic
# --------------------------------------------------------------------------
class TestUNIT001:
    def test_fires_on_mixed_add_and_sub(self):
        bad = """
            def cost(lat_s, ship_bytes, link_bw):
                a = lat_s + ship_bytes
                b = ship_bytes - link_bw
                return a, b
        """
        assert ids(check(bad, select=["UNIT001"])) == ["UNIT001"] * 2

    def test_fires_through_nested_same_unit_sums(self):
        bad = """
            def cost(wait_s, service_s, act_bytes):
                return wait_s + service_s + act_bytes
        """
        assert ids(check(bad, select=["UNIT001"])) == ["UNIT001"]

    def test_silent_on_conversions_and_same_unit(self):
        clean = """
            def cost(lat_s, ship_bytes, link_bw, wait_s):
                xfer_s = lat_s + ship_bytes / max(link_bw, 1.0)
                total_s = xfer_s + wait_s
                return total_s
        """
        assert check(clean, select=["UNIT001"]) == []


# --------------------------------------------------------------------------
# Suppressions: per-line, per-file, round-trips
# --------------------------------------------------------------------------
class TestSuppressions:
    BAD_LINE = """
        import numpy as np
        np.random.seed(0)
    """

    def test_line_disable_suppresses(self):
        src = """
            import numpy as np
            np.random.seed(0)  # repro: disable=RNG001
        """
        assert check(src, select=["RNG001"]) == []

    def test_line_disable_is_line_scoped(self):
        src = """
            import numpy as np
            np.random.seed(0)  # repro: disable=RNG001
            np.random.seed(1)
        """
        found = check(src, select=["RNG001"])
        assert len(found) == 1 and found[0].line == 4

    def test_wrong_rule_id_does_not_suppress(self):
        src = """
            import numpy as np
            np.random.seed(0)  # repro: disable=DET002
        """
        assert ids(check(src, select=["RNG001"])) == ["RNG001"]

    def test_disable_all_on_line(self):
        src = """
            import numpy as np
            np.random.seed(0)  # repro: disable=all
        """
        assert check(src) == []

    def test_file_disable(self):
        src = """
            # repro: disable-file=RNG001
            import numpy as np
            np.random.seed(0)
            np.random.rand(2)
        """
        assert check(src, select=["RNG001"]) == []

    def test_directive_inside_string_is_inert(self):
        src = '''
            import numpy as np
            DOC = "example:  # repro: disable-file=RNG001"
            np.random.seed(0)
        '''
        assert ids(check(src, select=["RNG001"])) == ["RNG001"]

    def test_round_trip_remove_comment_refires(self):
        suppressed = """
            import numpy as np
            np.random.seed(0)  # repro: disable=RNG001
        """
        assert check(suppressed, select=["RNG001"]) == []
        refired = suppressed.replace("  # repro: disable=RNG001", "")
        assert ids(check(refired, select=["RNG001"])) == ["RNG001"]


# --------------------------------------------------------------------------
# Framework: severity filtering, syntax errors, registry, CLI
# --------------------------------------------------------------------------
class TestFramework:
    def test_all_eight_rules_registered(self):
        expected = {"RNG001", "RNG002", "DET001", "DET002", "JIT001",
                    "JIT002", "KRN001", "UNIT001"}
        assert expected <= set(REGISTRY)
        for rid in expected:
            assert REGISTRY[rid].title
            assert REGISTRY[rid].severity in tuple(Severity)

    def test_syntax_error_becomes_finding(self):
        found = analyze_source("def broken(:\n", path="broken.py")
        assert ids(found) == ["SYNTAX"]
        assert found[0].severity is Severity.ERROR

    def test_unknown_select_raises(self):
        with pytest.raises(ValueError, match="NOPE999"):
            analyze_source("x = 1\n", select=["NOPE999"])

    def test_cli_json_and_exit_codes(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text("import numpy as np\nnp.random.seed(0)\n")
        rc = cli_main(["--format", "json", str(bad)])
        payload = json.loads(capsys.readouterr().out)
        assert rc == 1
        assert payload["counts"]["error"] == 1
        assert payload["findings"][0]["rule"] == "RNG001"
        # --select an unrelated rule: clean exit
        assert cli_main(["--select", "DET001", str(bad)]) == 0
        capsys.readouterr()
        # fail-level above the finding severity: report but exit 0
        warn = tmp_path / "warn.py"
        warn.write_text("def f(a_s, b_bytes):\n    return a_s + b_bytes\n")
        assert cli_main(["--fail-level", "error", str(warn)]) == 0
        capsys.readouterr()

    def test_cli_list_rules(self, capsys):
        assert cli_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        assert "KRN001" in out and "RNG001" in out


# --------------------------------------------------------------------------
# Self-clean: the tree's invariants hold, machine-checked
# --------------------------------------------------------------------------
class TestSelfClean:
    def test_src_is_clean_at_warning_and_above(self):
        found = [f for f in run_paths([SRC])
                 if f.severity >= Severity.WARNING]
        assert found == [], "\n".join(f.render() for f in found)

    def test_module_main_exits_zero_on_src(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "src"],
            cwd=ROOT, capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": SRC + os.pathsep
                 + os.environ.get("PYTHONPATH", "")})
        assert proc.returncode == 0, proc.stdout + proc.stderr
