"""repro.sim test lanes.

Fast lane: equivalence pins (streaming at t=0/static links is bit-for-bit
the batch schedulers; ``decisions.replan`` splices rows bit-for-bit),
hypothesis properties (no task starts before its arrival; Pareto re-picks
stay on the current non-dominated front; streaming deadline misses match
the batch ``Schedule.deadline_misses``), and a deterministic-seed
end-to-end smoke (≤5 s).  Tier-1 adds the slow diurnal/Pareto run.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro import sim
from repro.core import costs as co
from repro.core import decisions as dec
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.core.workloads import WorkloadConfig
from repro.hw import EDGE_DEVICES, get_device


def make_tasks(n, seed=3, deadlines=False):
    rng = np.random.default_rng(seed)
    return [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                     input_bytes=float(rng.uniform(1e4, 1e7)),
                     deadline_s=float(rng.uniform(0.02, 2.0))
                     if deadlines else None)
            for i in range(n)]


def make_nodes(n=None):
    specs = list(EDGE_DEVICES.values())
    n = n or len(specs)
    return [sch.Node(specs[j % len(specs)]) for j in range(n)]


@pytest.fixture(scope="module")
def cnn_layers():
    wc = WorkloadConfig("cnn", 2, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32)
    return off.workload_layer_costs(wc)


# --------------------------------------------------------------------------
# events: clock, queue, arrival processes
# --------------------------------------------------------------------------
def test_clock_monotonic():
    c = sim.Clock()
    assert c.advance(1.5) == 1.5
    assert c.advance_to(1.0) == 1.5          # never backwards
    assert c.advance_to(2.0) == 2.0
    with pytest.raises(ValueError):
        c.advance(-0.1)


def test_event_queue_orders_by_time_fifo_on_ties():
    q = sim.EventQueue()
    q.push(2.0, "b")
    q.push(1.0, "a1")
    q.push(1.0, "a2")
    assert q.peek_time() == 1.0
    kinds = [q.pop().kind for _ in range(3)]
    assert kinds == ["a1", "a2", "b"]
    assert not q


@pytest.mark.parametrize("gen", [
    lambda s: sim.poisson_arrivals(20.0, n=50, seed=s),
    lambda s: sim.poisson_arrivals(20.0, horizon=2.0, seed=s),
    lambda s: sim.mmpp_arrivals([5.0, 80.0], [0.5, 0.2], horizon=2.0,
                                seed=s),
    lambda s: sim.diurnal_arrivals(30.0, horizon=2.0, amplitude=0.8,
                                   period_s=1.0, seed=s),
])
def test_arrival_processes_sorted_and_deterministic(gen):
    a, b = gen(7), gen(7)
    np.testing.assert_array_equal(a, b)      # seeded: exact replay
    assert (np.diff(a) >= 0).all()
    assert (a >= 0).all()
    assert a.size > 0


def test_arrival_horizon_respected():
    a = sim.poisson_arrivals(100.0, horizon=1.5, seed=0)
    assert (a < 1.5).all()
    d = sim.diurnal_arrivals(50.0, horizon=1.0, seed=1)
    assert (d < 1.0).all()


def test_trace_arrivals_validates():
    np.testing.assert_array_equal(sim.trace_arrivals([0.0, 1.0, 1.0, 2.5]),
                                  [0.0, 1.0, 1.0, 2.5])
    with pytest.raises(ValueError):
        sim.trace_arrivals([1.0, 0.5])       # unsorted
    with pytest.raises(ValueError):
        sim.trace_arrivals([-1.0, 0.5])      # negative


def test_poisson_needs_exactly_one_bound():
    with pytest.raises(ValueError):
        sim.poisson_arrivals(1.0, seed=0)
    with pytest.raises(ValueError):
        sim.poisson_arrivals(1.0, n=5, horizon=1.0, seed=0)


# --------------------------------------------------------------------------
# state: link processes + EnvArrays snapshots
# --------------------------------------------------------------------------
def test_link_processes_bounded_and_deterministic():
    w1 = sim.RandomWalkLink(1e8, sigma=1.0, min_bw=1e6, max_bw=1e9, seed=4)
    w2 = sim.RandomWalkLink(1e8, sigma=1.0, min_bw=1e6, max_bw=1e9, seed=4)
    for _ in range(50):
        v = w1.step(0.5)
        assert v == w2.step(0.5)             # same seed, same path
        assert 1e6 <= v <= 1e9 + 1e-6
    g = sim.TwoStateLink(1.25e9, 2e6, mean_good_s=0.5, mean_bad_s=0.5,
                         seed=1)
    seen = {g.value}
    for _ in range(100):
        seen.add(g.step(0.3))
    assert seen == {1.25e9, 2e6}             # Gilbert–Elliott: two states
    d = sim.DiurnalLink(1e8, amplitude=0.5, period_s=1.0)
    vals = [d.step(0.05) for _ in range(40)]
    assert max(vals) <= 1.5e8 + 1e-6 and min(vals) >= 0.5e8 - 1e-6
    assert max(vals) > 1.2e8 and min(vals) < 0.8e8   # actually tides


def test_drifting_env_snapshot_feeds_decide_all(cnn_layers):
    env = sim.DriftingEnv(device=get_device("pi5-arm"),
                          edge=get_device("edge-server-a100"),
                          link=sim.FixedLink(0.125e9),
                          input_bytes=4 * 32 * 784)
    snap = env.snapshot()
    ref = dec.make_envs(env.device, env.edge, link_bw=np.asarray([0.125e9]),
                        link_latency_s=0.005,
                        input_bytes=np.asarray([4 * 32 * 784.0]))
    np.testing.assert_array_equal(snap.link_bw, ref.link_bw)
    np.testing.assert_array_equal(snap.input_bytes, ref.input_bytes)
    # the batch core consumes the snapshot unchanged, and agrees with the
    # scalar oracle on the frozen state
    plan = dec.decide_all(cnn_layers, snap)
    scalar = off.optimal_split(cnn_layers, off.OffloadEnv(
        env.device, env.edge, 0.125e9, input_bytes=4 * 32 * 784))
    assert int(plan.splits[0]) == scalar.split
    np.testing.assert_allclose(plan.total_time_s[0], scalar.total_time_s,
                               rtol=1e-15)


# --------------------------------------------------------------------------
# equivalence pins: streaming at t=0 / static links == batch, bit-for-bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy,batch_fn", [("min_min", sch.min_min),
                                             ("heft", sch.heft)])
def test_stream_t0_static_matches_batch_bitforbit(policy, batch_fn):
    tasks, nodes = make_tasks(23), make_nodes()
    etc = sch.etc_matrix(tasks, nodes)
    batch = batch_fn(tasks, nodes, etc)
    stream = sim.StreamScheduler(nodes, policy=policy)
    out = stream.run(tasks, np.zeros(len(tasks)))
    assert len(out.assignments) == len(batch.assignments)
    for a, b in zip(batch.assignments, out.assignments):
        assert a.task is b.task
        assert a.node == b.node
        assert a.start == b.start            # bit-for-bit, no tolerance
        assert a.finish == b.finish
    assert out.makespan == batch.makespan
    assert stream.full_rebuilds == 0


def test_stream_incremental_work_is_per_arrival():
    """Staggered arrivals: one ETC row per task, one column refresh per
    placement, never a full rebuild."""
    tasks, nodes = make_tasks(17), make_nodes()
    arr = sim.poisson_arrivals(50.0, n=len(tasks), seed=2)
    s = sim.StreamScheduler(nodes)
    out = s.run(tasks, arr)
    assert len(out.assignments) == len(tasks)
    assert s.rows_built == len(tasks)
    assert s.column_refreshes == len(tasks)
    assert s.full_rebuilds == 0
    starts = {a.task.name: a.start for a in out.assignments}
    for t, a in zip(tasks, arr):
        assert starts[t.name] >= a


def test_set_link_bw_refreshes_future_etc_columns():
    tasks, nodes = make_tasks(1), make_nodes()
    s = sim.StreamScheduler(nodes)
    before = s.etc_rows(tasks)[0]
    s.set_link_bw(0, 1.0)                    # node 0's uplink collapses
    after = s.etc_rows(tasks)[0]
    assert after[0] > before[0] * 100
    np.testing.assert_array_equal(after[1:], before[1:])
    assert s.link_refreshes == 1


def test_stream_rejects_unknown_policy_and_bad_arrivals():
    nodes = make_nodes()
    with pytest.raises(ValueError):
        sim.StreamScheduler(nodes, policy="fifo")
    with pytest.raises(ValueError):
        sim.StreamScheduler(nodes).run(make_tasks(3), [0.0, 1.0])


# --------------------------------------------------------------------------
# incremental decisions.replan
# --------------------------------------------------------------------------
def synth_layers(L, seed=0):
    rng = np.random.default_rng(seed)
    return [off.LayerCost(f"l{i}", flops=float(rng.uniform(1e8, 1e11)),
                          act_bytes=float(rng.uniform(1e3, 1e7)))
            for i in range(L)]


@pytest.mark.parametrize("cost", [
    None,
    co.CompositeCost(weights={"latency_s": 1.0, "energy_j": 0.05,
                              "price": 1.0},
                     price_per_edge_s=0.1, price_per_gb=0.01),
])
def test_replan_changed_rows_bitforbit(cost):
    layers = synth_layers(24)
    bws = np.geomspace(1e5, 1e10, 64)
    envs = dec.make_envs(get_device("pi5-arm"),
                         get_device("edge-server-a100"), link_bw=bws,
                         input_bytes=1e5)
    prev = dec.decide_all(layers, envs, cost=cost)
    bws2 = bws.copy()
    changed = np.zeros(64, bool)
    changed[[3, 17, 40, 63]] = True
    bws2[changed] *= 0.01                    # those links degraded
    envs2 = dec.make_envs(get_device("pi5-arm"),
                          get_device("edge-server-a100"), link_bw=bws2,
                          input_bytes=1e5)
    inc = dec.replan(layers, envs2, prev, changed, cost=cost)
    full = dec.decide_all(layers, envs2, cost=cost)
    np.testing.assert_array_equal(inc.splits, full.splits)
    np.testing.assert_array_equal(inc.total_time_s, full.total_time_s)
    np.testing.assert_array_equal(inc.device_time_s, full.device_time_s)
    np.testing.assert_array_equal(inc.transfer_time_s,
                                  full.transfer_time_s)
    np.testing.assert_array_equal(inc.edge_time_s, full.edge_time_s)
    if cost is not None:
        np.testing.assert_array_equal(inc.components, full.components)
        np.testing.assert_array_equal(inc.scalar_cost, full.scalar_cost)
    # no changed rows -> the previous plan comes back untouched
    assert dec.replan(layers, envs2, inc, np.zeros(64, bool),
                      cost=cost) is inc


def test_replan_guards():
    layers = synth_layers(8)
    envs = dec.make_envs(get_device("pi5-arm"),
                         get_device("edge-server-a100"),
                         link_bw=np.geomspace(1e6, 1e9, 16),
                         input_bytes=1e5)
    prev = dec.decide_all(layers, envs)
    with pytest.raises(ValueError):          # wrong mask shape
        dec.replan(layers, envs, prev, np.zeros(4, bool))
    comp = co.CompositeCost()
    with pytest.raises(ValueError):          # objective stack changed
        dec.replan(layers, envs, prev, np.asarray([0, 1]), cost=comp)


# --------------------------------------------------------------------------
# pareto_pick + ParetoStreamScheduler
# --------------------------------------------------------------------------
def test_pareto_pick_is_front_restricted_scalar_argmin():
    rng = np.random.default_rng(0)
    comp = rng.uniform(0.0, 1.0, size=(5, 12, 3))
    names = ("latency_s", "energy_j", "price")
    w = {"latency_s": 1.0, "energy_j": 0.0, "price": 0.0}
    front, picks = co.pareto_pick(comp, names, w)
    scalar = co.scalarize_weighted(comp, names, w)
    for e in range(5):
        assert front[e, picks[e]]            # every pick non-dominated
        on_front = np.flatnonzero(front[e])
        assert scalar[e, picks[e]] == scalar[e, on_front].min()
    with pytest.raises(KeyError):
        co.pareto_pick(comp, names, w, subset=("latency_s", "typo"))
    # a precomputed ranking matrix (a model's own scalarize) overrides
    # the weighted sum and must match the component shape
    _, picks2 = co.pareto_pick(comp, names, scalar=scalar)
    for e in range(5):
        assert front[e, picks2[e]]
    with pytest.raises(ValueError):
        co.pareto_pick(comp, names, scalar=scalar[:, :4])


def test_pareto_stream_scheduler_lifecycle(cnn_layers):
    pl = sim.ParetoStreamScheduler(device=get_device("pi5-arm"),
                                   edge=get_device("edge-server-a100"))
    st0 = pl.admit(0, cnn_layers, 1.25e9, input_bytes=1e5)
    assert st0.front_size >= 1
    assert 0 <= st0.pick <= len(cnn_layers)
    with pytest.raises(KeyError):
        pl.admit(0, cnn_layers, 1.25e9)      # rid already live
    # a collapsing link must eventually pull the pick toward local-only
    switched = pl.on_link(10.0)
    assert pl.live[0].pick == len(cnn_layers)
    assert switched in (0, 1)
    rec = pl.complete(0, 10.0)
    assert rec["pick"] == len(cnn_layers)
    assert rec["switches"] == pl.total_switches
    assert not pl.live
    assert set(rec["realised"]) == set(pl.cost.objectives)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 8), st.integers(1, 5))
def test_pareto_repick_stays_on_current_front(seed, n_layers, n_events):
    """Per-event re-picks are non-dominated on the *current* front,
    verified independently of the scheduler's own mask."""
    layers = synth_layers(n_layers, seed=seed)
    cost = co.CompositeCost(weights={"latency_s": 1.0, "energy_j": 0.02,
                                     "price": 1.0},
                            price_per_edge_s=0.1, price_per_gb=0.05)
    pl = sim.ParetoStreamScheduler(cost, device=get_device("pi5-arm"),
                                   edge=get_device("edge-server-a100"))
    link = sim.RandomWalkLink(0.125e9, sigma=1.5, seed=seed + 1)
    pl.admit(0, layers, link.value, input_bytes=1e5)
    pl.admit(1, layers, link.value, input_bytes=3e6)
    obj_idx = [cost.objectives.index(n) for n in pl.pareto_objectives]
    for _ in range(n_events):
        bw = link.step(1.0)
        pl.on_link(bw)
        for state in pl.live.values():
            envs = dec.make_envs(pl.device, pl.edge,
                                 link_bw=np.asarray([bw]),
                                 link_latency_s=pl.link_latency_s,
                                 input_bytes=np.asarray(
                                     [state.input_bytes]))
            comp = np.asarray(cost.components(layers, envs))[0]
            front = co.pareto_front(comp[:, obj_idx])
            assert front[state.pick]


# --------------------------------------------------------------------------
# streaming invariants + telemetry vs the batch world
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 14), st.integers(1, 5),
       st.booleans())
def test_no_task_starts_before_arrival(seed, n_tasks, n_nodes, rebalance):
    rng = np.random.default_rng(seed)
    tasks = make_tasks(n_tasks, seed=seed)
    nodes = make_nodes(n_nodes)
    arrivals = np.sort(rng.uniform(0.0, 2.0, size=n_tasks))
    links = sim.ClusterLinks.random_walk(
        [n.spec.link_bw for n in nodes], sigma=0.6, seed=seed)
    tel = sim.simulate_stream(tasks, arrivals, nodes, links=links,
                              link_update_dt=0.25, rebalance=rebalance)
    assert len(tel) == n_tasks
    for r in tel.records:
        assert r.started_s >= r.arrived_s
        assert r.finished_s > r.started_s
        assert r.energy_j >= 0.0


def test_stream_deadline_misses_match_batch():
    """Telemetry's miss count on the t=0/static problem equals the batch
    ``Schedule.deadline_misses`` — the metric is the same quantity."""
    tasks, nodes = make_tasks(25, deadlines=True), make_nodes()
    etc = sch.etc_matrix(tasks, nodes)
    batch = sch.min_min(tasks, nodes, etc)
    assert batch.deadline_misses() > 0       # a meaningful pin
    tel = sim.simulate_stream(tasks, np.zeros(len(tasks)), nodes)
    assert tel.deadline_misses == batch.deadline_misses()
    fin_batch = {a.task.name: a.finish for a in batch.assignments}
    for r in tel.records:
        assert r.finished_s == fin_batch[r.name]
    s = tel.summary()
    assert s["deadline_misses"] == batch.deadline_misses()
    assert s["makespan_s"] == batch.makespan


def test_telemetry_rows_match_results_schema(tmp_path):
    tel = sim.Telemetry()
    tel.complete(sim.TaskRecord("a", 0.0, 0.5, 2.0, node="n0",
                                deadline_s=1.0, energy_j=3.0))
    tel.complete(sim.TaskRecord("b", 0.0, 2.0, 3.0, node="n1"))
    rows = tel.to_rows("unit")
    assert rows[0]["name"] == "unit"
    assert rows[0]["deadline_misses"] == 1
    assert all(isinstance(r, dict) and "name" in r for r in rows)
    path = tmp_path / "rows.json"
    tel.save(str(path), "unit")
    import json
    assert json.loads(path.read_text())[0]["n_tasks"] == 2
    util = tel.utilisation()
    assert all(0.0 <= u <= 1.0 for u in util.values())


def test_utilisation_keeps_same_spec_nodes_apart():
    """Clusters repeat device specs; utilisation must key on node
    identity, not the (non-unique) spec name — merging same-named nodes
    used to report busy fractions > 1."""
    tel = sim.Telemetry()
    for nid in range(3):                     # three xps15-i5 nodes, each
        tel.complete(sim.TaskRecord(f"t{nid}", 0.0, 0.0, 10.0,
                                    node="xps15-i5", node_id=nid))
    util = tel.utilisation()
    assert len(util) == 3
    assert set(util) == {"xps15-i5@0", "xps15-i5@1", "xps15-i5@2"}
    assert all(u == 1.0 for u in util.values())
    assert tel.summary()["mean_utilisation"] == 1.0
    # a full sim over a duplicate-spec cluster stays within [0, 1]
    spec = get_device("xps15-i5")
    nodes = [sch.Node(spec) for _ in range(4)]
    tel2 = sim.simulate_stream(make_tasks(12), np.zeros(12), nodes)
    assert all(0.0 <= u <= 1.0 for u in tel2.utilisation().values())
    assert 0.0 <= tel2.summary()["mean_utilisation"] <= 1.0


def test_rebalance_migrates_queue_tail_onto_freed_node():
    """Link drift between placement and node-free makes migration pay:
    a queued-but-unstarted tail moves onto the freed node when its link
    recovered, strictly improving that task's finish."""
    a100 = get_device("edge-server-a100")
    nodes = [sch.Node(dataclasses.replace(a100, name="n0")),
             sch.Node(dataclasses.replace(a100, name="n1"))]
    s = sim.StreamScheduler(nodes, rebalance=True)
    big = sch.Task("big", flops=5e12, input_bytes=1e5)
    (a_big,) = s.on_arrivals([big], 0.0)
    assert s.node_index(a_big) == 0          # tie-break: first node
    s.set_link_bw(0, 1.0)                    # n0's uplink collapses...
    (a_q1,) = s.on_arrivals([sch.Task("q1", flops=5e12,
                                      input_bytes=1e5)], 0.01)
    (a_q2,) = s.on_arrivals([sch.Task("q2", flops=1e11,
                                      input_bytes=1e5)], 0.02)
    assert s.node_index(a_q1) == 1 and s.node_index(a_q2) == 1
    assert a_q2.start > a_big.finish         # q2 queued behind q1
    s.set_link_bw(0, a100.link_bw)           # ...and recovers in time
    old_finish = a_q2.finish
    migrated = s.on_node_free(0, now=a_big.finish)
    assert migrated is a_q2
    assert s.node_index(a_q2) == 0
    assert a_q2.finish < old_finish          # strictly better, or no move
    assert s.migrations == 1
    # no further candidate: the remaining tail started already
    assert s.on_node_free(0, now=a_q2.finish) is None


# --------------------------------------------------------------------------
# end-to-end: deterministic smoke (fast) + the full diurnal run (slow)
# --------------------------------------------------------------------------
def _smoke_run(seed):
    tasks = make_tasks(50, seed=seed, deadlines=True)
    nodes = make_nodes()
    arrivals = sim.mmpp_arrivals([20.0, 200.0], [0.4, 0.1], horizon=2.0,
                                 seed=seed)[:len(tasks)]
    tasks = tasks[:len(arrivals)]
    links = sim.ClusterLinks.random_walk(
        [n.spec.link_bw for n in nodes], sigma=0.5, seed=seed + 1)
    wc = WorkloadConfig("cnn", 2, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32)
    layers = off.workload_layer_costs(wc)
    env = sim.DriftingEnv(device=get_device("pi5-arm"),
                          edge=get_device("edge-server-a100"),
                          link=sim.TwoStateLink(1.25e9, 2e5,
                                                mean_good_s=0.4,
                                                mean_bad_s=0.4,
                                                seed=seed + 2),
                          input_bytes=1e5)
    planner = sim.ParetoStreamScheduler(device=get_device("pi5-arm"),
                                        edge=get_device(
                                            "edge-server-a100"))
    tel = sim.simulate_stream(tasks, arrivals, nodes, links=links,
                              link_update_dt=0.1, split_planner=planner,
                              split_env=env, split_layers=layers)
    recs = tuple((r.name, r.arrived_s, r.started_s, r.finished_s, r.node,
                  r.split, r.switches) for r in tel.records)
    return tel.summary(), recs


def test_sim_smoke_deterministic_seed():
    """Full event loop (MMPP arrivals, drifting cluster links, Pareto
    split planner) replays exactly under one seed — the fast-lane smoke."""
    (s1, r1), (s2, r2) = _smoke_run(0), _smoke_run(0)
    assert s1 == s2
    assert r1 == r2
    assert s1["n_tasks"] == len(r1) > 0
    assert s1["p99_completion_s"] >= s1["p50_completion_s"] >= 0.0
    assert s1["replans"] > 0 and s1["column_refreshes"] > 0
    assert "full_rebuilds" not in s1         # never counted: never done


@pytest.mark.slow
def test_sim_end_to_end_diurnal_pareto_slow():
    """The committed-example scenario at full size: diurnal arrivals,
    drifting links, Pareto re-picking.  The planner must actually switch
    splits under the drifting link, and every record must respect the
    streaming invariants."""
    rng = np.random.default_rng(0)
    n = 300
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(5e10, 8e11)),
                      input_bytes=float(rng.uniform(1e5, 1e7)),
                      deadline_s=float(rng.uniform(5, 120)))
             for i in range(n)]
    nodes = make_nodes()
    arrivals = sim.diurnal_arrivals(12.0, horizon=30.0, amplitude=0.9,
                                    period_s=10.0, seed=1)[:n]
    tasks = tasks[:len(arrivals)]
    links = sim.ClusterLinks.random_walk(
        [nd.spec.link_bw for nd in nodes], sigma=0.7, seed=2)
    wc = WorkloadConfig("cnn", 2, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32)
    layers = off.workload_layer_costs(wc)
    env = sim.DriftingEnv(device=get_device("pi5-arm"),
                          edge=get_device("edge-server-a100"),
                          link=sim.TwoStateLink(1.25e9, 2e5,
                                                mean_good_s=2.0,
                                                mean_bad_s=2.0, seed=3),
                          input_bytes=1e5)
    # the pi5 → A100 pair keeps a multi-point front (a fast local device
    # collapses it to local-only and nothing would ever switch)
    planner = sim.ParetoStreamScheduler(device=get_device("pi5-arm"),
                                        edge=get_device(
                                            "edge-server-a100"))
    tel = sim.simulate_stream(tasks, arrivals, nodes, policy="min_min",
                              links=links, link_update_dt=0.5,
                              split_planner=planner, split_env=env,
                              split_layers=layers, rebalance=True)
    assert len(tel) == len(tasks)
    for r in tel.records:
        assert r.started_s >= r.arrived_s
        assert r.finished_s > r.started_s
    # drifting two-state link MUST move the picks at least once
    assert planner.total_switches >= 1
    s = tel.summary()
    assert s["split_switches"] >= 1
    assert s["split_repicks"] > 0
    assert 0.0 <= s["mean_utilisation"] <= 1.0
    assert tel.makespan_s >= float(arrivals.max())
