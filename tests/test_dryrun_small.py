"""Dry-run machinery on a tiny forced-host-device mesh (subprocess so the
512-device production flag never leaks into other tests)."""
import json
import subprocess
import sys

import pytest

# subprocess jax re-imports + 8-device mesh dry-runs: minutes on CPU —
# excluded from the fast lane, covered by the tier-1 job
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import reduced_config
from repro.configs.base import InputShape
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import assemble
from repro.roofline import analyze, model_flops_estimate

mesh = jax.make_mesh((4, 2), ("data", "model"))
out = {}
for arch, shape in [
    ("qwen3-1.7b", InputShape("t", 64, 8, "train")),
    ("deepseek-moe-16b", InputShape("p", 64, 8, "prefill")),
    ("zamba2-1.2b", InputShape("d", 64, 8, "decode")),
]:
    cfg = reduced_config(arch)
    step = assemble(cfg, shape, mesh, auto_knobs=False)
    with mesh:
        compiled = step.jitted.lower(*step.arg_specs).compile()
    cost = compiled.cost_analysis()
    roof = analyze(arch, cost, compiled.as_text(), chips=8,
                   model_flops=model_flops_estimate(cfg, shape))
    out[arch] = {"flops": roof.flops, "dominant": roof.dominant,
                 "mem": compiled.memory_analysis().temp_size_in_bytes}
print(json.dumps(out))
"""


def test_dryrun_pipeline_on_debug_mesh():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=540,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert set(out) == {"qwen3-1.7b", "deepseek-moe-16b", "zamba2-1.2b"}
    for arch, rec in out.items():
        assert rec["flops"] > 0, arch
        assert rec["dominant"] in ("compute", "memory", "collective")
