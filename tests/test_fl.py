"""Federated profiling-model training tests (paper §II-B)."""
import numpy as np
import pytest

# multi-round FedAvg fits: ~1.5 minutes on CPU — excluded from the fast
# lane, covered by the tier-1 job
pytestmark = pytest.mark.slow

from repro.core.fl import (Client, DPConfig, FedAvgConfig, clip_update,
                           global_norm, privatise_update, run_fedavg,
                           split_clients)


def _toy(n=400, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(n, 6)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + x[:, 1] * x[:, 2])[:, None].astype(np.float32)
    return x, y


def test_split_clients_partitions_everything():
    x, y = _toy()
    clients = split_clients(x, y, 5)
    assert len(clients) == 5
    assert sum(len(c.x) for c in clients) == len(x)


def test_fedavg_learns():
    x, y = _toy(600)
    clients = split_clients(x[:480], y[:480], 4)
    cfg = FedAvgConfig(rounds=10, local_epochs=2, lr=3e-3, hidden=(32, 16))
    res = run_fedavg(clients, cfg, central_test=(x[480:], y[480:]))
    hist = [h["federated_rmse"] for h in res.round_history]
    assert hist[-1] < hist[0] * 0.7, hist
    assert res.centralised_rmse is not None and res.centralised_rmse < 1.0


def test_fedavg_with_dp_still_learns_but_noisier():
    x, y = _toy(600, seed=1)
    clients = split_clients(x[:480], y[:480], 4)
    plain = run_fedavg(clients, FedAvgConfig(rounds=8, hidden=(32, 16),
                                             lr=3e-3))
    dp = run_fedavg(clients, FedAvgConfig(
        rounds=8, hidden=(32, 16), lr=3e-3,
        dp=DPConfig(epsilon=4.0, clip_norm=0.5)))
    assert dp.federated_rmse >= plain.federated_rmse * 0.5  # sanity
    # DP must cost accuracy (noise is really being added)
    assert dp.federated_rmse > plain.federated_rmse


def test_dp_clip_and_noise():
    import jax.numpy as jnp
    tree = {"w": jnp.ones((10, 10)) * 5.0}
    clipped = clip_update(tree, 1.0)
    assert abs(global_norm(clipped) - 1.0) < 1e-5
    rng = np.random.default_rng(0)
    cfg = DPConfig(epsilon=1.0, delta=1e-5, clip_norm=1.0)
    noised = privatise_update(tree, cfg, rng)
    assert float(jnp.std(noised["w"])) > 0.5 * cfg.sigma
