"""repro.obs.analyze test lanes.

The tentpole contracts, each pinned here:

  * **attribution is exact**: per-run aggregates recomputed from spans
    alone equal ``Telemetry.summary()`` float-for-float (same
    p50/p90/p99/mean completion, same wait stats, same miss counts) —
    the spans carry the same values in the same completion order;
  * **phases decompose**: ``sojourn = queue_wait + service + transfer +
    residual`` within 1e-9 on random traced runs, both engines
    (hypothesis property);
  * **diff is a true zero test**: ``diff(run, run)`` — and
    event-vs-fleet on identical seeds — is identically zero (every
    delta 0.0, every K-S statistic 0.0, no unmatched tasks);
  * **sketches are accurate**: streaming p99 within 2% relative error
    of the exact ``np.percentile`` on ≥10⁴-sample streams, mergeable,
    bounded, exact when small;
  * **the gate has teeth**: ``regress`` exits 0 on the committed
    baselines (selftest) and non-zero on a synthetically perturbed
    copy;
  * **miss classification is stable**: golden-file pin of the
    classifier on a saturating MMPP run.
"""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro import sim
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.hw import EDGE_DEVICES, get_device
from repro.obs import MetricsRegistry, Tracer, postmortem_dump
from repro.obs.analyze import (MISS_CAUSES, QuantileSketch, TraceTable,
                               attribute, compare_rows, diff,
                               ks_statistic, load, selftest)
from repro.obs.analyze.cli import main as analyze_main

SPECS = list(EDGE_DEVICES.values())
REPO = pathlib.Path(__file__).resolve().parent.parent
GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


def make_tasks(n, seed=3, deadline_slack=None):
    rng = np.random.default_rng(seed)
    return [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                     input_bytes=float(rng.uniform(1e4, 1e7)),
                     deadline_s=float(rng.uniform(*deadline_slack))
                     if deadline_slack else None)
            for i in range(n)]


def make_nodes(n):
    return [sch.Node(SPECS[j % len(SPECS)]) for j in range(n)]


def run_traced(engine, *, n_tasks=40, n_nodes=3, seed=5,
               contended=True, deadlines=True):
    """One traced simulate_stream run -> (Telemetry, Tracer)."""
    tasks = make_tasks(n_tasks, seed=seed,
                       deadline_slack=(0.05, 2.0) if deadlines else None)
    arrivals = sim.poisson_arrivals(15.0, n=n_tasks, seed=seed)
    kw = {}
    if contended:
        kw["pools"] = sim.NodePools.uniform(n_nodes, 1)
        kw["rtt"] = sim.WeibullRTT(shape=0.7, scale=0.01, seed=seed + 9)
    obs = Tracer()
    tel = sim.simulate_stream(tasks, arrivals, make_nodes(n_nodes),
                              policy="min_min", engine=engine, obs=obs,
                              **kw)
    return tel, obs


# --------------------------------------------------------------------------
# attribution: exact summary reproduction from spans alone
# --------------------------------------------------------------------------
EXACT_KEYS = ("n_tasks", "p50_completion_s", "p90_completion_s",
              "p99_completion_s", "mean_completion_s", "p99_wait_s",
              "mean_wait_s", "deadline_misses", "miss_rate")


@pytest.mark.parametrize("engine", ["event", "fleet"])
def test_attribution_reproduces_summary_exactly(engine):
    tel, obs = run_traced(engine)
    s_span = attribute(obs).summary()
    s_tel = tel.summary()
    for k in EXACT_KEYS:
        assert s_span[k] == s_tel[k], (k, s_span[k], s_tel[k])


@pytest.mark.parametrize("engine", ["event", "fleet"])
def test_attribution_phase_totals_and_critical_path(engine):
    tel, obs = run_traced(engine)
    run = attribute(obs)
    totals = run.phase_totals()
    assert totals["sojourn"] == pytest.approx(
        totals["queue_wait"] + totals["service"] + totals["transfer"]
        + totals["residual"])
    shares = run.phase_shares()
    assert sum(shares.values()) == pytest.approx(1.0)
    # per-node roll-up covers every task exactly once
    assert sum(d["n_tasks"] for d in run.by_track().values()) \
        == len(run.tasks)
    # critical paths cover each sojourn, ordered and gap-free
    for i in range(len(run.tasks)):
        segs = run.critical_path(i)
        assert segs, "no critical path for a completed task"
        assert sum(d for _, d, _ in segs) == pytest.approx(
            float(run.tasks.sojourn_s[i]))
        assert run.dominant_phase(i) == max(
            segs, key=lambda s: s[1])[0]


@settings(max_examples=12, deadline=None)
@given(engine=st.sampled_from(["event", "fleet"]),
       seed=st.integers(0, 50), n_tasks=st.integers(5, 30),
       contended=st.booleans())
def test_phases_sum_to_sojourn_property(engine, seed, n_tasks,
                                        contended):
    _, obs = run_traced(engine, n_tasks=n_tasks, seed=seed,
                        contended=contended)
    t = attribute(obs).tasks
    assert len(t) == n_tasks
    recon = t.queue_wait_s + t.service_s + t.transfer_s + t.residual_s
    assert np.abs(t.sojourn_s - recon).max() <= 1e-9
    # residual is float residue, not a real phase
    assert np.abs(t.residual_s).max() <= 1e-9
    # phase matrix agrees with the columns
    assert np.abs(t.phase_matrix().sum(axis=1)
                  - t.sojourn_s).max() <= 1e-9


def test_telemetry_bridge_matches_tracer_phases():
    tel, obs = run_traced("event")
    via_rows = tel.attribution()
    via_spans = attribute(obs)
    assert len(via_rows.tasks) == len(via_spans.tasks)
    # same completion order, same records -> identical phase columns
    np.testing.assert_array_equal(via_rows.tasks.sojourn_s,
                                  via_spans.tasks.sojourn_s)
    np.testing.assert_array_equal(via_rows.tasks.queue_wait_s,
                                  via_spans.tasks.queue_wait_s)
    np.testing.assert_array_equal(via_rows.tasks.transfer_s,
                                  via_spans.tasks.transfer_s)


def test_summary_new_keys():
    tel, _ = run_traced("event")
    s = tel.summary()
    soj = sorted(r.sojourn_s for r in tel.records)
    assert s["p90_completion_s"] == float(np.percentile(soj, 90))
    assert s["miss_rate"] == s["deadline_misses"] / s["n_tasks"]
    assert sim.Telemetry().summary()["miss_rate"] == 0.0


# --------------------------------------------------------------------------
# differential profiling
# --------------------------------------------------------------------------
def test_diff_run_with_itself_is_identically_zero():
    _, obs = run_traced("event")
    for align in ("task", "arrival"):
        rep = diff(obs, obs, align=align)
        assert rep.is_zero
        assert rep.only_a == rep.only_b == 0
        for p in rep.phases.values():
            assert (p.mean_delta, p.p50_delta, p.p90_delta,
                    p.p99_delta, p.ks) == (0.0,) * 5
        assert all(r["sojourn_delta_s"] == 0.0
                   for r in rep.top_regressions)


def test_diff_event_vs_fleet_identical_seeds_all_zero():
    _, obs_e = run_traced("event")
    _, obs_f = run_traced("fleet")
    rep = diff(obs_e, obs_f)
    assert rep.is_zero, rep.table_str()


def test_diff_detects_regression():
    _, a = run_traced("event", seed=5)
    _, b = run_traced("event", seed=6)     # different run: must move
    rep = diff(a, b)
    assert not rep.is_zero
    assert rep.matched == len(load(a).lifecycles())
    d = rep.to_dict()
    assert set(d["phases"]) == {"sojourn", "queue_wait", "service",
                                "transfer"}
    assert "diff" in rep.table_str()


def test_ks_statistic_properties():
    rng = np.random.default_rng(0)
    x = rng.normal(size=500)
    assert ks_statistic(x, x) == 0.0
    # disjoint supports -> maximal distance
    assert ks_statistic(x, x + 100.0) == 1.0
    # shifted distributions are detectably apart
    assert 0.0 < ks_statistic(x, x + 0.5) < 1.0
    assert ks_statistic(np.empty(0), np.empty(0)) == 0.0
    assert ks_statistic(np.empty(0), x) == 1.0


# --------------------------------------------------------------------------
# streaming quantile sketch
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential",
                                  "bimodal"])
def test_sketch_p99_within_2pct(dist):
    rng = np.random.default_rng(42)
    n = 20_000
    x = {"lognormal": lambda: rng.lognormal(0.0, 1.0, n),
         "uniform": lambda: rng.uniform(0.0, 10.0, n),
         "exponential": lambda: rng.exponential(2.0, n),
         "bimodal": lambda: np.concatenate(
             [rng.normal(1.0, 0.1, n // 2),
              rng.normal(10.0, 1.0, n // 2)])}[dist]()
    s = QuantileSketch("lat")
    # streamed in chunks, as a serving loop would
    for chunk in np.array_split(x, 37):
        s.observe_many(chunk)
    assert s.n_centroids <= 128
    assert len(s) == x.size and s.sum == pytest.approx(x.sum())
    for q in (0.5, 0.9, 0.99):
        exact = float(np.percentile(x, 100 * q))
        assert abs(s.quantile(q) - exact) <= 0.02 * abs(exact), (q, dist)
    # extremes are exact
    assert s.quantile(0.0) == x.min() and s.quantile(1.0) == x.max()


def test_sketch_exact_when_small():
    x = np.asarray([3.0, 1.0, 4.0, 1.5, 9.0])
    s = QuantileSketch(max_centroids=16)
    s.observe_many(x)
    assert s.quantile(0.0) == 1.0 and s.quantile(1.0) == 9.0
    assert s.quantile(0.5) == pytest.approx(np.percentile(x, 50), rel=0.3)
    assert s.mean == pytest.approx(x.mean())


def test_sketch_merge_approximates_union():
    rng = np.random.default_rng(1)
    a, b = rng.lognormal(0, 1, 8000), rng.lognormal(0.5, 0.8, 8000)
    sa, sb = QuantileSketch("a"), QuantileSketch("b")
    sa.observe_many(a)
    sb.observe_many(b)
    sa.merge(sb)
    both = np.concatenate([a, b])
    assert len(sa) == both.size
    assert sa.sum == pytest.approx(both.sum())
    for q in (0.5, 0.99):
        exact = float(np.percentile(both, 100 * q))
        assert abs(sa.quantile(q) - exact) <= 0.02 * abs(exact)


def test_sketch_validations():
    s = QuantileSketch()
    with pytest.raises(ValueError, match="non-finite"):
        s.observe(float("nan"))
    with pytest.raises(ValueError, match="q must be"):
        s.quantile(1.5)
    with pytest.raises(ValueError, match="max_centroids"):
        QuantileSketch(max_centroids=2)
    assert s.quantile(0.5) == 0.0          # empty sketch


def test_registry_summary_kind():
    reg = MetricsRegistry()
    q = reg.quantile("sojourn_seconds", help="live sojourn")
    assert q is reg.quantile("sojourn_seconds")      # idempotent
    q.observe_many(np.arange(1.0, 101.0))
    text = reg.to_prometheus()
    assert "# TYPE sojourn_seconds summary" in text
    assert 'sojourn_seconds{quantile="0.99"}' in text
    assert "sojourn_seconds_count 100" in text
    rows = reg.to_rows()
    (srow,) = [r for r in rows if "quantiles" in r]
    assert srow["count"] == 100 and "0.99" in srow["quantiles"]
    with pytest.raises(TypeError, match="already registered"):
        reg.counter("sojourn_seconds")
    with pytest.raises(ValueError, match="max_centroids"):
        reg.quantile("sojourn_seconds", max_centroids=64)


def test_serving_engines_expose_live_quantiles():
    # the wiring seam, without a model: engines register their sketches
    # at construction; here we mimic the completion path's observes
    reg = MetricsRegistry()
    soj = reg.quantile("serve_sojourn_seconds")
    rng = np.random.default_rng(3)
    soj.observe_many(rng.exponential(0.1, 500))
    text = reg.to_prometheus()
    assert 'serve_sojourn_seconds{quantile="0.5"}' in text
    import inspect
    from repro.serve.continuous import ContinuousBatchEngine
    from repro.serve.engine import ServeEngine
    assert "metrics" in inspect.signature(
        ContinuousBatchEngine.__init__).parameters
    assert "metrics" in inspect.signature(ServeEngine.__init__).parameters


# --------------------------------------------------------------------------
# miss attribution: taxonomy + golden pin on a saturating MMPP run
# --------------------------------------------------------------------------
def _mmpp_saturating_run():
    """A deliberately saturated run: bursty MMPP arrivals into
    capacity-1 pools with heavy-tailed RTT and tight absolute
    deadlines — misses from contention AND from the RTT tail."""
    n_nodes = 3
    arrivals = sim.mmpp_arrivals([40.0, 400.0], [0.5, 0.2],
                                 horizon=2.0, seed=11)
    rng = np.random.default_rng(11)
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 2e11)),
                      input_bytes=float(rng.uniform(1e4, 1e6)),
                      deadline_s=float(arrivals[i]
                                       + rng.uniform(0.005, 0.3)))
             for i in range(len(arrivals))]
    obs = Tracer()
    tel = sim.simulate_stream(
        tasks, arrivals, make_nodes(n_nodes), policy="min_min",
        pools=sim.NodePools.uniform(n_nodes, 1),
        rtt=sim.WeibullRTT(shape=0.6, scale=0.02, seed=13),
        engine="event", obs=obs)
    return tel, obs


def test_miss_attribution_taxonomy():
    tel, obs = run_traced("event", n_tasks=60, seed=9)
    ma = attribute(obs).miss_attribution()
    assert ma["n_misses"] == tel.summary()["deadline_misses"]
    assert sum(ma["by_cause"].values()) == ma["n_misses"]
    assert set(ma["by_cause"]) == set(MISS_CAUSES)
    for m in ma["misses"]:
        assert m["cause"] in MISS_CAUSES
        assert m["excess_s"] > 0.0
        assert m["dominant_phase"] in ("queue_wait", "transfer",
                                       "service")
        # cause follows the dominant phase
        assert {"queue_wait": "pool_contention",
                "transfer": m["cause"],     # link_drift or rtt_tail
                "service": "service_underprediction"}[
                    m["dominant_phase"]] == m["cause"]


def test_miss_attribution_golden_mmpp():
    _, obs = _mmpp_saturating_run()
    ma = attribute(obs).miss_attribution()
    got = {
        "n_tasks": ma["n_tasks"], "n_misses": ma["n_misses"],
        "by_cause": ma["by_cause"],
        "misses": [{"task": m["task"], "cause": m["cause"],
                    "dominant_phase": m["dominant_phase"]}
                   for m in ma["misses"]],
    }
    path = GOLDEN / "miss_attribution_mmpp.json"
    want = json.loads(path.read_text())
    assert got == want, (
        "miss classifier drifted from the golden file; if the change "
        "is intentional, regenerate tests/golden/"
        "miss_attribution_mmpp.json")
    # the saturating run must actually exercise the classifier
    assert ma["n_misses"] >= 5
    assert ma["by_cause"]["pool_contention"] >= 1


def test_instant_corroboration_windows():
    tr = Tracer()
    tr.task_spans("n@0", 0, "a", 0.0, 0.9, 1.0,
                  args={"deadline_s": 0.5})
    tr.task_spans("n@0", 1, "b", 0.0, 0.0, 0.6)
    tr.instant("scheduler", "pool_saturation", 0.4)
    run = attribute(tr)
    (miss,) = run.miss_attribution()["misses"]
    assert miss["cause"] == "pool_contention"
    assert miss["corroborated"] and miss["evidence"] == [
        "pool_saturation"]
    table = run.table.instants_in(0.0, 1.0, names=("pool_saturation",))
    assert len(table) == 1
    assert run.table.instants_in(0.5, 1.0) == []


# --------------------------------------------------------------------------
# trace table ingestion paths
# --------------------------------------------------------------------------
def test_from_chrome_round_trip(tmp_path):
    _, obs = run_traced("event", n_tasks=20)
    path = tmp_path / "trace.json"
    obs.export_chrome(str(path))
    t_exact = TraceTable.from_tracer(obs).lifecycles()
    t_chrome = load(str(path)).lifecycles()
    assert len(t_chrome) == len(t_exact)
    # µs round-trip: endpoints within 1e-9 s of the exact floats
    np.testing.assert_allclose(t_chrome.sojourn_s, t_exact.sojourn_s,
                               atol=1e-9)
    np.testing.assert_allclose(t_chrome.queue_wait_s,
                               t_exact.queue_wait_s, atol=1e-9)
    assert t_chrome.task == t_exact.task
    # deadline args survive the export
    assert np.isfinite(t_chrome.deadline_s).all()


def test_span_arrays_args_cols():
    tr = Tracer()
    tr.span_arrays(["n@0", "n@1"], [0, 1], ["x", "y"], [0.0, 1.0],
                   [0.1, 1.0], [0.5, 2.0],
                   args_cols={"deadline_s": [0.4, None],
                              "split": [3, None]})
    t = load(tr).lifecycles()
    assert t.deadline_s[0] == 0.4 and np.isnan(t.deadline_s[1])
    assert t.split[0] == 3 and t.split[1] == -1
    assert bool(t.missed[0]) and not bool(t.missed[1])
    with pytest.raises(ValueError, match="args column"):
        tr.span_arrays(["n@0"], [0], ["x"], [0.0], [0.0], [1.0],
                       args_cols={"deadline_s": [1.0, 2.0]})


# --------------------------------------------------------------------------
# regression gating
# --------------------------------------------------------------------------
def test_compare_rows_directions():
    base = [{"name": "b", "us_per_call": 100.0, "events_per_sec": 1e4,
             "rel_err": 0.01, "backend": "jax", "n_envs": 64}]
    assert compare_rows(base, base).ok
    # lower-better regression flags; improvement doesn't
    worse = [{**base[0], "us_per_call": 130.0}]
    rep = compare_rows(base, worse)
    assert not rep.ok and rep.regressions[0].metric == "us_per_call"
    better = [{**base[0], "us_per_call": 50.0, "events_per_sec": 9e4}]
    rep = compare_rows(base, better)
    assert rep.ok and len(rep.improvements) == 2
    # higher-better regression flags
    rep = compare_rows(base, [{**base[0], "events_per_sec": 100.0}])
    assert not rep.ok
    # config change flags
    rep = compare_rows(base, [{**base[0], "backend": "numpy"}])
    assert not rep.ok
    # missing row fails, extra row doesn't
    rep = compare_rows(base, [{"name": "other", "us_per_call": 1.0}])
    assert not rep.ok and rep.missing_rows == ["b"]
    assert rep.extra_rows == ["other"]
    # per-metric tolerance override
    rep = compare_rows(base, worse, tol={"us_per_call": 0.5})
    assert rep.ok
    rep = compare_rows(base, worse, tol={"b.us_per_call": 0.5})
    assert rep.ok


def test_selftest_on_committed_baselines():
    from repro.obs.analyze.regress import load_rows
    ok, text = selftest(load_rows(str(REPO / "BENCH_7.json")))
    assert ok, text
    assert "selftest PASS" in text


@pytest.mark.parametrize("bench", ["BENCH_3.json", "BENCH_6.json"])
def test_regress_cli_exit_codes(bench, tmp_path, capsys):
    base = str(REPO / bench)
    # committed baseline vs itself: clean gate, exit 0
    assert analyze_main(["regress", base, base]) == 0
    # selftest mode: exit 0, proves perturbations are caught
    assert analyze_main(["regress", base, "--selftest"]) == 0
    # synthetically perturbed copy: exit 1
    rows = json.loads(pathlib.Path(base).read_text())
    for r in rows:
        for k, v in list(r.items()):
            if isinstance(v, float) and v != 0:
                r[k] = v * 2.0 if not any(
                    s in k for s in ("per_sec", "per_s", "speedup")) \
                    else v / 2.0
    bad = tmp_path / "fresh.json"
    bad.write_text(json.dumps(rows))
    assert analyze_main(["regress", base, str(bad)]) == 1
    # IO error: exit 2
    assert analyze_main(["regress", base,
                         str(tmp_path / "nope.json")]) == 2
    capsys.readouterr()


def test_regress_cli_json_report(tmp_path, capsys):
    base = str(REPO / "BENCH_7.json")
    out = tmp_path / "report.json"
    assert analyze_main(["regress", base, base,
                         "--json", str(out)]) == 0
    rep = json.loads(out.read_text())
    assert rep["ok"] and rep["checked"] > 0
    capsys.readouterr()


# --------------------------------------------------------------------------
# CLI: attribution + diff on exported traces
# --------------------------------------------------------------------------
def test_cli_attribution_and_diff(tmp_path, capsys):
    _, obs_a = run_traced("event", n_tasks=20)
    _, obs_b = run_traced("fleet", n_tasks=20)
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    obs_a.export_chrome(str(pa))
    obs_b.export_chrome(str(pb))
    out = tmp_path / "attr.json"
    assert analyze_main(["attribution", str(pa), "--misses",
                         "--json", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["summary"]["n_tasks"] == 20
    assert set(report["miss_attribution"]["by_cause"]) \
        == set(MISS_CAUSES)
    dout = tmp_path / "diff.json"
    assert analyze_main(["diff", str(pa), str(pb),
                         "--json", str(dout)]) == 0
    d = json.loads(dout.read_text())
    # same seeds through both engines, µs round-trip: deltas ≈ 0
    assert d["matched"] == 20
    assert abs(d["phases"]["sojourn"]["mean_delta"]) < 1e-6
    assert analyze_main(["attribution",
                         str(tmp_path / "missing.json")]) == 2
    capsys.readouterr()


# --------------------------------------------------------------------------
# flight-recorder post-mortem
# --------------------------------------------------------------------------
def test_postmortem_on_engine_crash(tmp_path, monkeypatch, capsys):
    monkeypatch.chdir(tmp_path)

    class BoomRTT:
        def sample(self, n):
            raise RuntimeError("boom")

    tasks = make_tasks(1, deadline_slack=(0.5, 1.0))
    nodes = make_nodes(1)
    obs = Tracer()
    with pytest.raises(RuntimeError, match="boom"):
        sim.simulate_stream(tasks, np.asarray([0.0]), nodes,
                            rtt=BoomRTT(), obs=obs)
    dump = json.loads(
        (tmp_path / "results" / "postmortem.json").read_text())
    assert dump["error"].startswith("RuntimeError")
    assert dump["n_events"] >= 1
    assert "post-mortem" in capsys.readouterr().err
    # tracing off: the crash still propagates, nothing is written
    (tmp_path / "results" / "postmortem.json").unlink()
    with pytest.raises(RuntimeError, match="boom"):
        sim.simulate_stream(tasks, np.asarray([0.0]), nodes,
                            rtt=BoomRTT())
    assert not (tmp_path / "results" / "postmortem.json").exists()


def test_postmortem_dump_is_best_effort(tmp_path):
    tr = Tracer()
    tr.instant("x", "e", 1.0)
    # unwritable path: swallowed, returns None, no raise
    assert postmortem_dump(tr, clock_s=1.0,
                           path="/proc/nope/postmortem.json") is None
    out = tmp_path / "pm.json"
    d = postmortem_dump(tr, clock_s=2.5, error="E", path=str(out))
    assert d["clock_s"] == 2.5 and out.exists()
