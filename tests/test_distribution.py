"""Distribution-layer unit tests: partition rules, cache policies,
roofline extraction, optimisers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh16():
    # host has 1 device; an abstract mesh suffices for spec computation
    from jax.sharding import AbstractMesh
    try:                               # jax >= 0.5: (sizes, names)
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:                  # jax 0.4.x: ((name, size), ...)
        return AbstractMesh((("data", 16), ("model", 16)))


def _spec_of(specs, *path):
    node = specs
    for p in path:
        node = node[p]
    return node


def test_param_rules_gemma(mesh16):
    """8 q-heads can't shard over model=16 → attention replicated on tp;
    FFN (16384) and vocab (256000) shard."""
    cfg = get_config("gemma-2b")
    api = build_model(cfg)
    specs, report = shd.param_specs(cfg, api.param_shapes(), mesh16,
                                    mode="serve")
    assert _spec_of(specs, "embed") == P("model", None)
    assert _spec_of(specs, "layers", "mlp", "w_gate") == P(None, None,
                                                           "model")
    assert _spec_of(specs, "layers", "attn", "wq") == P(None, None, None)
    assert any("wq" in p for p in report.replicated)


def test_param_rules_qwen_heads_shard(mesh16):
    cfg = get_config("qwen3-1.7b")
    api = build_model(cfg)
    specs, _ = shd.param_specs(cfg, api.param_shapes(), mesh16, mode="serve")
    assert _spec_of(specs, "layers", "attn", "wq") == P(None, None, "model")
    # kv heads = 8 -> replicated
    assert _spec_of(specs, "layers", "attn", "wk") == P(None, None, None)
    assert _spec_of(specs, "layers", "attn", "wo") == P(None, "model", None)


def test_param_rules_moe_expert_parallel(mesh16):
    cfg = get_config("deepseek-moe-16b")
    api = build_model(cfg)
    specs, _ = shd.param_specs(cfg, api.param_shapes(), mesh16, mode="serve")
    assert _spec_of(specs, "layers", "moe", "w_gate") == \
        P(None, "model", None, None)


def test_fsdp_only_in_train(mesh16):
    cfg = get_config("qwen3-1.7b")
    api = build_model(cfg)
    tr, _ = shd.param_specs(cfg, api.param_shapes(), mesh16, mode="train")
    sv, _ = shd.param_specs(cfg, api.param_shapes(), mesh16, mode="serve")
    wk_tr = _spec_of(tr, "layers", "attn", "wk")
    wk_sv = _spec_of(sv, "layers", "attn", "wk")
    assert "data" in str(wk_tr) and "data" not in str(wk_sv)
    no, _ = shd.param_specs(cfg, api.param_shapes(), mesh16, mode="train",
                            no_fsdp=True)
    assert "data" not in str(_spec_of(no, "layers", "attn", "wk"))


def test_cache_specs_policies(mesh16):
    cfg = get_config("qwen3-1.7b")
    api = build_model(cfg)
    # decode_32k-like: B=128 shardable, kv heads 8 NOT divisible by 16
    shapes = api.cache_shapes(128, 32768)
    specs = shd.cache_specs(cfg, shapes, mesh16)
    k = specs["layers"]["k"]
    assert k[1] in ("data", ("data",))   # batch over data
    assert k[2] == "model"            # sequence over model (heads 8 < 16)
    # B=1 long-context: batch unshardable -> seq over (model, data)
    shapes1 = api.cache_shapes(1, 524288)
    specs1 = shd.cache_specs(cfg, shapes1, mesh16)
    assert specs1["layers"]["k"][2] == ("model", "data")


def test_cache_specs_heads_shard(mesh16):
    cfg = get_config("zamba2-1.2b")    # kv=32 divisible
    api = build_model(cfg)
    specs = shd.cache_specs(cfg, api.cache_shapes(128, 32768), mesh16)
    assert specs["attn_k"][-2] == "model"


# --------------------------------------------------------------------------
# roofline HLO parsing
# --------------------------------------------------------------------------
SYNTH_HLO = """
%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %b = f32[16,32]{1,0} constant({...})
  %d = f32[8,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,32]{1,0} all-reduce(%d), replica_groups={}
}
%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
}
ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %w = (s32[], f32[8,16]{1,0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[128,16]{1,0} all-gather(%x), dimensions={0}
}
"""


def test_corrected_costs_loop_multiplier():
    from repro.roofline_hlo import corrected_costs
    cc = corrected_costs(SYNTH_HLO)
    # dot: 2 * 8*32 * 16 = 8192 flops, ×10 trips
    assert cc["flops"] == 8192 * 10
    # all-reduce inside loop: 8*32*4 bytes ×10; all-gather outside: 128*16*4
    assert cc["collectives"]["all-reduce"] == 8 * 32 * 4 * 10
    assert cc["collectives"]["all-gather"] == 128 * 16 * 4


def test_collective_bytes_regex():
    from repro.roofline import collective_bytes
    out = collective_bytes(SYNTH_HLO)
    assert out["all-gather"] == 128 * 16 * 4
    assert out["all-reduce"] == 8 * 32 * 4


# --------------------------------------------------------------------------
# optimisers match reference formulas
# --------------------------------------------------------------------------
def test_adam_matches_reference():
    from repro.optim import adam, apply_updates
    opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.25])}
    state = opt.init(p)
    updates, state = opt.update(g, state, p)
    m = 0.1 * np.array([0.5, 0.25])
    v = 0.001 * np.array([0.25, 0.0625])
    mhat, vhat = m / 0.1, v / 0.001
    exp = -0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(updates["w"]), exp, rtol=1e-5)


@pytest.mark.parametrize("name,lr", [("sgd", 0.05), ("adam", 0.05),
                                     ("rmsprop", 0.05), ("adagrad", 0.5)])
def test_all_paper_optimisers_reduce_quadratic(name, lr):
    from repro.optim import apply_updates, get_optimizer
    opt = get_optimizer(name, lr)
    p = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = opt.init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        updates, state = opt.update(g, state, p)
        p = apply_updates(p, updates)
    assert float(jnp.abs(p["w"]).max()) < 0.5, (name, p)


def test_schedules():
    from repro.optim import warmup_cosine
    s = warmup_cosine(1.0, warmup_steps=10, decay_steps=110)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(110))) < 0.2
