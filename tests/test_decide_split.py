"""On-accelerator decision kernels vs the host decision core.

Pins ``backend="jax"`` bit-for-bit (f64) to the numpy path — splits,
every DecisionPlan field, and the full latency matrix — and the fused
Pallas kernel within f32 tolerance (plus a near-optimality bound, so a
last-ulp argmin flip at a genuine tie cannot flake the suite).  Also
covers the degenerate shapes every backend must accept (empty layer
chain, zero environments) and the cost models that must *not* lower.
"""
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import costs as co
from repro.core import decisions as dec
from repro.core import offload as off
from repro.hw import EDGE_DEVICES, get_device
from repro.kernels.decide_split import ops
from repro.kernels.decide_split.ref import decide_ref, latency_matrix_ref

PLAN_FIELDS = ("splits", "total_time_s", "device_time_s",
               "transfer_time_s", "edge_time_s")


def rand_layers(rng, n):
    return [off.LayerCost(f"l{i}",
                          flops=float(rng.uniform(1e6, 1e12)),
                          act_bytes=float(rng.uniform(1e2, 1e8)))
            for i in range(n)]


def rand_envs(rng, n):
    specs = list(EDGE_DEVICES.values())
    return dec.make_envs(
        [specs[int(rng.integers(len(specs)))] for _ in range(max(n, 1))][:n]
        or [specs[0]],
        specs[int(rng.integers(len(specs)))],
        link_bw=rng.uniform(1e4, 1e10, max(n, 1))[:n],
        link_latency_s=rng.uniform(0.0, 0.05, max(n, 1))[:n],
        input_bytes=rng.uniform(0.0, 1e7, max(n, 1))[:n]) \
        if n else dec.EnvArrays(*[np.zeros(0)] * 7)


def composite():
    return co.CompositeCost(
        weights={"latency_s": 1.0, "energy_j": 0.05, "price": 1.0},
        price_per_edge_s=0.1, price_per_gb=0.01, deadline_s=0.05)


def assert_plans_equal(a, b):
    for f in PLAN_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.objectives == b.objectives
    for f in ("components", "scalar_cost"):
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), f
        if x is not None:
            assert np.array_equal(x, y), f


# --------------------------------------------------------------------------
# jax backend: bit-for-bit with the numpy reference (f64)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("trial", range(8))
def test_jax_decide_bit_for_bit(trial):
    rng = np.random.default_rng(trial)
    layers = rand_layers(rng, int(rng.integers(1, 24)))
    envs = rand_envs(rng, int(rng.integers(1, 48)))
    assert_plans_equal(decide_ref(layers, envs),
                       dec.decide_all(layers, envs, backend="jax"))


def test_jax_latency_matrix_bit_for_bit():
    rng = np.random.default_rng(3)
    layers = rand_layers(rng, 19)
    envs = rand_envs(rng, 31)
    assert np.array_equal(ops.latency_matrix_jax(layers, envs),
                          latency_matrix_ref(layers, envs))


def test_jax_custom_efficiency_bit_for_bit():
    rng = np.random.default_rng(4)
    layers = rand_layers(rng, 9)
    envs = rand_envs(rng, 12)
    assert_plans_equal(dec.decide_all(layers, envs, 0.71),
                       dec.decide_all(layers, envs, 0.71, backend="jax"))


@pytest.mark.parametrize("make_cost", [co.AnalyticCost,
                                       lambda: co.AnalyticCost(0.5),
                                       composite],
                         ids=["analytic", "analytic_eff", "composite"])
def test_jax_cost_models_bit_for_bit(make_cost):
    rng = np.random.default_rng(5)
    layers = rand_layers(rng, 14)
    envs = rand_envs(rng, 20)
    assert_plans_equal(
        dec.decide_all(layers, envs, cost=make_cost()),
        dec.decide_all(layers, envs, cost=make_cost(), backend="jax"))


def test_sweep_links_backend_passthrough():
    rng = np.random.default_rng(6)
    layers = rand_layers(rng, 8)
    env = off.OffloadEnv(get_device("pi5-arm"),
                         get_device("edge-server-a100"),
                         link_bw=1e8, input_bytes=1e5)
    bws = np.geomspace(1e5, 1e9, 16)
    assert_plans_equal(dec.sweep_links(layers, env, bws),
                       dec.sweep_links(layers, env, bws, backend="jax"))


# --------------------------------------------------------------------------
# Pallas kernel: within f32 tolerance, chosen splits near-optimal
# --------------------------------------------------------------------------
def assert_pallas_close(layers, envs, *, cost=None, rtol=1e-5):
    ref = decide_ref(layers, envs, cost=cost)
    got = dec.decide_all(layers, envs, cost=cost, backend="pallas")
    # the split the kernel picked, re-costed exactly in f64, must be
    # within f32-argmin distance of the true optimum...
    ranked_ref = ref.scalar_cost if ref.scalar_cost is not None \
        else ref.total_time_s
    ranked_got = got.scalar_cost if got.scalar_cost is not None \
        else got.total_time_s
    assert np.all(ranked_got <= ranked_ref * (1 + 1e-4) + 1e-12)
    # ...and the plan's own breakdown must be internally consistent
    np.testing.assert_allclose(
        got.device_time_s + got.transfer_time_s + got.edge_time_s,
        got.total_time_s, rtol=1e-9, atol=1e-15)
    # on fixed seeds the argmin agrees outright
    assert np.array_equal(ref.splits, got.splits)
    for f in PLAN_FIELDS[1:]:
        np.testing.assert_allclose(getattr(got, f), getattr(ref, f),
                                   rtol=rtol, atol=1e-12, err_msg=f)


@pytest.mark.parametrize("trial", range(4))
def test_pallas_decide_close(trial):
    rng = np.random.default_rng(10 + trial)
    assert_pallas_close(rand_layers(rng, int(rng.integers(1, 40))),
                        rand_envs(rng, int(rng.integers(1, 64))))


def test_pallas_composite_close():
    rng = np.random.default_rng(20)
    layers = rand_layers(rng, 12)
    envs = rand_envs(rng, 24)
    assert_pallas_close(layers, envs, cost=composite())
    ref = decide_ref(layers, envs, cost=composite())
    got = dec.decide_all(layers, envs, cost=composite(), backend="pallas")
    np.testing.assert_allclose(got.components, ref.components,
                               rtol=1e-5, atol=1e-12)


def test_pallas_multi_block_sweep():
    """Splits beyond one 128-lane block: the running argmin must carry
    across split blocks (and env padding must not leak into outputs)."""
    rng = np.random.default_rng(21)
    layers = rand_layers(rng, 300)               # 301 splits -> 3 blocks
    envs = rand_envs(rng, 13)                    # pads to block_e
    assert_pallas_close(layers, envs)


# --------------------------------------------------------------------------
# degenerate shapes: every backend, every entry point
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n_layers,n_envs", [(0, 7), (5, 0), (0, 0)])
def test_transfer_and_latency_degenerate(n_layers, n_envs):
    rng = np.random.default_rng(30)
    layers = rand_layers(rng, n_layers)
    envs = rand_envs(rng, n_envs)
    tb = dec.transfer_bytes(layers, envs)
    assert tb.shape == (n_envs, n_layers + 1)
    assert np.all(tb[:, -1] == 0.0)              # split == L ships nothing
    lat = dec.latency_matrix(layers, envs)
    assert lat.shape == (n_envs, n_layers + 1)
    assert np.array_equal(ops.latency_matrix_jax(layers, envs), lat)
    if n_layers == 0 and n_envs:                 # L == 0: only split 0 == L
        assert np.array_equal(tb, np.zeros((n_envs, 1)))
        assert np.array_equal(lat, np.zeros((n_envs, 1)))


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
@pytest.mark.parametrize("n_layers,n_envs", [(0, 7), (5, 0), (0, 0)])
def test_decide_all_degenerate(backend, n_layers, n_envs):
    rng = np.random.default_rng(31)
    layers = rand_layers(rng, n_layers)
    envs = rand_envs(rng, n_envs)
    plan = dec.decide_all(layers, envs, backend=backend)
    assert len(plan) == n_envs
    assert plan.splits.shape == plan.total_time_s.shape == (n_envs,)
    if n_layers == 0:                            # split 0 is also split L
        assert np.all(plan.splits == 0)
        assert np.all(plan.total_time_s == 0.0)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("n_layers,n_envs", [(0, 4), (3, 0)])
def test_decide_all_degenerate_composite(backend, n_layers, n_envs):
    rng = np.random.default_rng(32)
    plan = dec.decide_all(rand_layers(rng, n_layers),
                          rand_envs(rng, n_envs), cost=composite(),
                          backend=backend)
    assert len(plan) == n_envs
    assert plan.components.shape == (n_envs, 4)


# --------------------------------------------------------------------------
# lowering boundaries (PredictorCost over a *lowerable* regressor now
# lowers — see tests/test_oracle.py; only host-only models are rejected)
# --------------------------------------------------------------------------
class _HostModel:
    def predict(self, x):
        return np.zeros(len(x))


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_host_only_predictor_rejected_on_accelerator(backend):
    rng = np.random.default_rng(40)
    cost = co.PredictorCost(_HostModel(), get_device("pi5-arm"),
                            get_device("edge-server-a100"))
    with pytest.raises(TypeError, match="host-side"):
        dec.decide_all(rand_layers(rng, 4), rand_envs(rng, 3), cost=cost,
                       backend=backend)


def test_composite_over_host_only_base_rejected():
    cost = co.CompositeCost(base=co.PredictorCost(
        _HostModel(), get_device("pi5-arm"),
        get_device("edge-server-a100")))
    rng = np.random.default_rng(41)
    with pytest.raises(TypeError, match="host-side"):
        dec.decide_all(rand_layers(rng, 4), rand_envs(rng, 3), cost=cost,
                       backend="jax")


def test_unknown_backend_rejected():
    rng = np.random.default_rng(42)
    with pytest.raises(ValueError, match="backend"):
        dec.decide_all(rand_layers(rng, 2), rand_envs(rng, 2),
                       backend="tpu")


def test_efficiency_cost_conflict_guard_on_accelerator():
    rng = np.random.default_rng(43)
    with pytest.raises(ValueError, match="efficiency"):
        dec.decide_all(rand_layers(rng, 2), rand_envs(rng, 2), 0.5,
                       cost=co.AnalyticCost(), backend="jax")


# --------------------------------------------------------------------------
# hypothesis: backend equivalence over random env grids
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 12), st.integers(0, 24))
def test_jax_equivalence_property(seed, n_layers, n_envs):
    rng = np.random.default_rng(seed)
    layers = rand_layers(rng, n_layers)
    envs = rand_envs(rng, n_envs)
    assert_plans_equal(decide_ref(layers, envs),
                       dec.decide_all(layers, envs, backend="jax"))


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 20), st.integers(1, 16))
def test_pallas_equivalence_property(seed, n_layers, n_envs):
    rng = np.random.default_rng(seed)
    layers = rand_layers(rng, n_layers)
    envs = rand_envs(rng, n_envs)
    ref = decide_ref(layers, envs)
    got = dec.decide_all(layers, envs, backend="pallas")
    # f32 argmin may legitimately flip at near-ties, so compare the
    # achieved cost, not the index
    np.testing.assert_allclose(
        latency_matrix_ref(layers, envs)[np.arange(n_envs), got.splits],
        ref.total_time_s, rtol=1e-4, atol=1e-12)
