"""Optional-import shim for ``hypothesis``.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When
it is installed the real ``given``/``settings``/``st`` are re-exported and
property tests run in full.  When it is missing, ``given`` decorates each
property test with a skip marker so the rest of the module still runs —
the suite stays green without the dependency instead of dying at
collection time.

Usage in a test module::

    from hypothesis_shim import given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy construction (st.lists(st.floats(...)))."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
