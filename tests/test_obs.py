"""repro.obs test lanes.

The tentpole contract is **zero perturbation**: a run with
``obs=Tracer()`` is bit-for-bit identical to the untraced run — records,
summary, counters, and end-of-run link state — in *both* engines, and
the two engines' exports describe the same trace.  The satellites ride
along: a hypothesis property that any set of task lifecycles exports a
well-formed Chrome trace (every ``B`` matched by a LIFO ``E``, children
nested, timestamps monotone per track), the validator's negative cases,
the deferred slab-ingestion paths, the flight recorder, the metrics
registry / Prometheus exposition, and the ``Telemetry`` bridges
(``registry()`` / ``to_prometheus()``, CVaR in ``summary()``).
"""
import json

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro import sim
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.core.workloads import WorkloadConfig
from repro.hw import EDGE_DEVICES, get_device
from repro.obs import (LATENCY_BOUNDARIES, MetricsRegistry, NULL_TRACER,
                       Histogram, NullTracer, Tracer, validate_chrome)

SPECS = list(EDGE_DEVICES.values())


def make_tasks(n, seed=3):
    rng = np.random.default_rng(seed)
    return [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                     input_bytes=float(rng.uniform(1e4, 1e7)),
                     deadline_s=float(rng.uniform(0.02, 2.0)))
            for i in range(n)]


def make_nodes(n):
    return [sch.Node(SPECS[j % len(SPECS)]) for j in range(n)]


@pytest.fixture(scope="module")
def cnn_layers():
    wc = WorkloadConfig("cnn", 2, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32)
    return off.workload_layer_costs(wc)


def run_stream(engine, obs, cfg, cnn_layers, *, n_tasks=24, n_nodes=3,
               seed=5):
    """One simulate_stream pass (fresh stateful processes every call)
    -> (Telemetry, end-of-run link bandwidths)."""
    tasks = make_tasks(n_tasks, seed=seed)
    arrivals = sim.poisson_arrivals(8.0, n=n_tasks, seed=seed)
    links = sim.ClusterLinks.random_walk(
        [40e6 + 5e6 * j for j in range(n_nodes)], sigma=0.4,
        seed=seed + 100)
    kw = {}
    if cfg == "links_planner":
        kw["split_planner"] = sim.ParetoStreamScheduler()
        kw["split_env"] = sim.DriftingEnv(
            get_device("jetson-orin-nano"),
            get_device("edge-server-a100"),
            sim.TwoStateLink(80e6, 8e6, seed=seed + 7),
            input_bytes=2e6)
        kw["split_layers"] = cnn_layers
    elif cfg == "pools_rtt":
        kw["pools"] = sim.NodePools.uniform(n_nodes, 2)
        kw["rtt"] = sim.WeibullRTT(shape=0.7, scale=0.01, seed=seed + 9)
    else:
        raise ValueError(cfg)
    tel = sim.simulate_stream(tasks, arrivals, make_nodes(n_nodes),
                              policy="min_min", links=links,
                              link_update_dt=0.5, engine=engine,
                              obs=obs, **kw)
    return tel, links.values()


def rec_tuple(r):
    return (r.name, r.arrived_s, r.started_s, r.finished_s, r.node,
            r.node_id, r.deadline_s, r.energy_j, r.split, r.switches)


# --------------------------------------------------------------------------
# tentpole: tracing perturbs nothing, in either engine
# --------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["event", "fleet"])
@pytest.mark.parametrize("cfg", ["links_planner", "pools_rtt"])
def test_tracing_zero_perturbation(engine, cfg, cnn_layers):
    """obs=Tracer() leaves records, summary, counters, and the drift
    processes' end state bit-for-bit identical to the untraced run —
    and the trace it collected exports clean."""
    tel_off, links_off = run_stream(engine, None, cfg, cnn_layers)
    tracer = Tracer()
    tel_on, links_on = run_stream(engine, tracer, cfg, cnn_layers)
    assert [rec_tuple(r) for r in tel_on.records] \
        == [rec_tuple(r) for r in tel_off.records]
    assert tel_on.summary() == tel_off.summary()
    assert tel_on.counters == tel_off.counters
    np.testing.assert_array_equal(links_on, links_off)
    stats = validate_chrome(tracer.export_chrome(None))
    # every completed task contributes at least sojourn + service
    assert stats["n_spans"] >= 2 * len(tel_on.records)
    assert stats["n_instants"] >= 1                    # replans at least


@pytest.mark.parametrize("cfg", ["links_planner", "pools_rtt"])
def test_traced_event_fleet_equivalence(cfg, cnn_layers):
    """With tracing ON, the event ≡ fleet equivalence still holds, and
    the two engines' traces describe the same run: identical validator
    stats (the fleet's deferred slab ingestion materialises to the same
    spans and instants the event loop emitted one by one)."""
    stats, tels = [], []
    for engine in ("event", "fleet"):
        tracer = Tracer()
        tel, _ = run_stream(engine, tracer, cfg, cnn_layers)
        tels.append(tel)
        stats.append(validate_chrome(tracer.export_chrome(None)))
    assert [rec_tuple(r) for r in tels[0].records] \
        == [rec_tuple(r) for r in tels[1].records]
    assert stats[0] == stats[1]


def test_example_trace_file_roundtrip(tmp_path, cnn_layers):
    """export_chrome(path) writes Perfetto-loadable JSON: traceEvents +
    displayTimeUnit, process_name metadata per track, and the file
    re-validates from disk."""
    tracer = Tracer()
    run_stream("event", tracer, "links_planner", cnn_layers)
    path = str(tmp_path / "trace.json")
    trace = tracer.export_chrome(path)
    assert trace["displayTimeUnit"] == "ms"
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk == json.loads(json.dumps(trace))    # serialisable
    assert validate_chrome(path) == validate_chrome(trace)
    meta = [e for e in on_disk["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in meta} == {"process_name"}
    names = {e["args"]["name"] for e in meta}
    assert "scheduler" in names
    assert any("@" in n for n in names)          # per-node task tracks


# --------------------------------------------------------------------------
# property: any set of task lifecycles exports well-formed
# --------------------------------------------------------------------------
@given(data=st.data())
@settings(max_examples=60, deadline=None)
def test_exported_lifecycles_well_formed(data):
    """Random lifecycles (arbitrary tracks, waits, services, transfers,
    including zero-length phases) plus out-of-order instants always
    export with every B matched by a LIFO E, children nested inside
    parents, and per-track monotone timestamps."""
    pos = st.floats(0.0, 50.0, allow_nan=False, allow_infinity=False)
    tracer = Tracer()
    n = data.draw(st.integers(1, 30), label="n_tasks")
    for i in range(n):
        track = f"node@{data.draw(st.integers(0, 3))}"
        arrived = data.draw(pos, label=f"arrived{i}")
        wait = data.draw(pos, label=f"wait{i}")
        service = data.draw(pos, label=f"service{i}")
        transfer = data.draw(pos, label=f"transfer{i}")
        tracer.task_spans(track, i, f"t{i}", arrived, arrived + wait,
                          arrived + wait + service + transfer,
                          transfer_s=transfer)
    for k in range(data.draw(st.integers(0, 8), label="n_instants")):
        tracer.instant("scheduler", "replan",
                       data.draw(pos, label=f"ts{k}"))
    stats = validate_chrome(tracer.export_chrome(None))
    assert stats["n_spans"] == len(tracer.all_spans())
    assert stats["n_instants"] == len(tracer.all_instants())


def test_slab_ingestion_matches_per_event_path():
    """span_arrays / instant_arrays are exactly n deferred task_spans /
    instant calls in column order."""
    cols = dict(
        tracks=["a@0", "b@1", "a@0"], tids=np.array([0, 1, 2]),
        names=["t0", "t1", "t2"],
        arrived_s=np.array([0.0, 0.5, 1.0]),
        started_s=np.array([0.1, 0.5, 1.4]),
        finished_s=np.array([0.9, 0.8, 2.0]))
    batched = Tracer()
    batched.span_arrays(**cols, transfer_s=np.array([0.1, 0.0, 0.2]))
    batched.instant_arrays("scheduler", "replan",
                           np.array([0.0, 0.5]),
                           args_cols={"batch": np.array([2, 1])})
    loop = Tracer()
    for k in range(3):
        loop.task_spans(cols["tracks"][k], int(cols["tids"][k]),
                        cols["names"][k], cols["arrived_s"][k],
                        cols["started_s"][k], cols["finished_s"][k],
                        transfer_s=[0.1, 0.0, 0.2][k])
    for ts, b in ((0.0, 2), (0.5, 1)):
        loop.instant("scheduler", "replan", ts, args={"batch": b})
    # __len__ counts ingested rows while pending (3 lifecycles + 2
    # instants), materialised events once read
    assert len(batched) == 5
    assert batched._pending and not loop._pending
    assert batched.all_spans() == loop.all_spans()
    assert batched.all_instants() == loop.all_instants()
    assert len(batched) == len(loop)


def test_tracer_rejects_malformed_input():
    tracer = Tracer()
    with pytest.raises(ValueError, match="ends before it starts"):
        tracer.span("n", "bad", 2.0, 1.0)
    with pytest.raises(ValueError, match="column started_s"):
        tracer.span_arrays(["a"], [0], ["t0"], [0.0], [0.1, 0.2], [1.0])
    with pytest.raises(ValueError, match="args column"):
        tracer.instant_arrays("s", "replan", [0.0, 1.0],
                              args_cols={"batch": [1]})


def test_export_rejects_partial_overlap():
    tracer = Tracer()
    tracer.span("n", "a", 0.0, 2.0)
    tracer.span("n", "b", 1.0, 3.0)      # same (track, tid): not nested
    with pytest.raises(ValueError, match="partially overlap"):
        tracer.export_chrome(None)


def test_flight_recorder_ring():
    tracer = Tracer(ring=8)
    for k in range(20):
        tracer.instant("s", f"e{k}", float(k))
    assert [e.name for e in tracer.last(64)] \
        == [f"e{k}" for k in range(12, 20)]
    assert [e.name for e in tracer.last(3)] == ["e17", "e18", "e19"]
    assert tracer.last(0) == []


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert isinstance(NULL_TRACER, NullTracer)
    NULL_TRACER.span("n", "a", 0.0, 1.0)
    NULL_TRACER.instant("n", "a", 0.0)
    NULL_TRACER.task_spans("n", 0, "t", 0.0, 0.0, 1.0)
    NULL_TRACER.span_arrays([], [], [], [], [], [])
    NULL_TRACER.instant_arrays("n", "a", [])
    assert NULL_TRACER.last() == []
    with pytest.raises(ValueError, match="no-op tracer"):
        NULL_TRACER.export_chrome("/tmp/nope.json")


# --------------------------------------------------------------------------
# validator negatives: each well-formedness clause actually bites
# --------------------------------------------------------------------------
def _ev(ph, name, ts, pid=0, tid=0):
    return {"name": name, "ph": ph, "pid": pid, "tid": tid, "ts": ts}


@pytest.mark.parametrize("events,match", [
    ([_ev("E", "a", 1.0)], "no open 'B'"),
    ([_ev("B", "a", 0.0), _ev("B", "b", 1.0), _ev("E", "a", 2.0)],
     "close LIFO"),
    ([_ev("B", "a", 2.0), _ev("E", "a", 1.0)], "backwards"),
    ([_ev("B", "a", 0.0)], "unmatched 'B'"),
    ([_ev("X", "a", 0.0)], "unknown phase"),
    ([_ev("i", "a", 2.0), _ev("i", "b", 1.0)], "backwards"),
])
def test_validator_negatives(events, match):
    with pytest.raises(ValueError, match=match):
        validate_chrome(events)


def test_validator_accepts_nested_and_counts():
    events = [_ev("B", "sojourn", 0.0), _ev("B", "service", 1.0),
              _ev("E", "service", 2.0), _ev("i", "replan", 2.5),
              _ev("E", "sojourn", 3.0),
              _ev("B", "other", 0.0, pid=1)] + [_ev("E", "other", 1.0,
                                                    pid=1)]
    assert validate_chrome(events) == {"n_events": 7, "n_spans": 3,
                                       "n_instants": 1, "n_tracks": 2}


# --------------------------------------------------------------------------
# metrics registry + Prometheus exposition
# --------------------------------------------------------------------------
def test_registry_get_or_create_and_mismatches():
    reg = MetricsRegistry()
    c = reg.counter("req_total", help="requests")
    assert reg.counter("req_total") is c                 # idempotent
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("req_total")
    reg.histogram("lat", boundaries=(1.0, 2.0))
    with pytest.raises(ValueError, match="boundaries"):
        reg.histogram("lat", boundaries=(1.0, 3.0))
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name!")
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    with pytest.raises(ValueError, match="strictly increasing"):
        Histogram("h", boundaries=(1.0, 1.0, 2.0))


def test_histogram_buckets_and_percentile():
    h = Histogram("lat_seconds", boundaries=(1.0, 2.0, 5.0))
    h.observe_many([0.5, 1.0, 1.5, 10.0])                # le semantics
    assert h.counts.tolist() == [2, 1, 0, 1]
    assert h.count == 4 and h.sum == pytest.approx(13.0)
    exp = h.expose()
    assert 'lat_seconds_bucket{le="1"} 2' in exp
    assert 'lat_seconds_bucket{le="2"} 3' in exp
    assert 'lat_seconds_bucket{le="+Inf"} 4' in exp
    assert h.percentile_bound(0.5) == 1.0
    # the +Inf bucket answers with the exact observed max, never inf
    assert h.percentile_bound(1.0) == 10.0
    assert h.observed_max == 10.0
    # q below the observed mass clamps to the first observation's bucket
    assert h.percentile_bound(0.0) == 1.0
    # same-boundary merge sums counts and keeps the max
    h2 = Histogram("lat_seconds", boundaries=(1.0, 2.0, 5.0))
    h2.observe_many([3.0, 20.0])
    h.merge(h2)
    assert h.counts.tolist() == [2, 1, 1, 2]
    assert h.count == 6 and h.percentile_bound(1.0) == 20.0
    with pytest.raises(ValueError, match="identical boundaries"):
        h.merge(Histogram("other", boundaries=(1.0, 2.0)))


def test_prometheus_text_and_rows(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tasks_total", help="done").inc(3)
    reg.gauge("energy_joules").set(1.5)
    reg.histogram("sojourn_seconds",
                  boundaries=LATENCY_BOUNDARIES).observe_many(
                      [0.002, 0.3, 4.0])
    text = reg.to_prometheus()
    assert "# HELP tasks_total done" in text
    assert "# TYPE tasks_total counter" in text
    assert "# TYPE sojourn_seconds histogram" in text
    assert "tasks_total 3" in text
    assert "energy_joules 1.5" in text
    assert "sojourn_seconds_count 3" in text
    rows = reg.to_rows("m")
    assert rows[0] == {"name": "m", "energy_joules": 1.5,
                       "tasks_total": 3.0}
    assert rows[1]["name"] == "m_hist_sojourn_seconds"
    assert sum(rows[1]["counts"]) == 3
    path = str(tmp_path / "metrics.json")
    reg.save(path, "m")
    with open(path) as f:
        assert json.load(f) == json.loads(json.dumps(rows))


# --------------------------------------------------------------------------
# Telemetry bridges: registry()/to_prometheus(), cvar95 in summary()
# --------------------------------------------------------------------------
def test_telemetry_registry_bridge(cnn_layers):
    tel, _ = run_stream("event", None, "pools_rtt", cnn_layers,
                        n_tasks=30)
    reg = tel.registry()
    assert reg.get("sim_tasks_completed_total").value == len(tel.records)
    assert reg.get("sim_task_sojourn_seconds").count == len(tel.records)
    for key in tel.counters:
        assert reg.get(f"sim_{key}_total").value == tel.counters[key]
    text = tel.to_prometheus()
    assert "# TYPE sim_tasks_completed_total counter" in text
    assert "sim_task_wait_seconds_bucket" in text

    s = tel.summary()
    assert "cvar95_completion_s" in s
    assert np.isfinite(s["cvar95_completion_s"])
    # CVaR(0.95) is the mean of the worst 5% completions: at least p50
    assert s["cvar95_completion_s"] >= s["p50_completion_s"]
    # to_rows leads with the summary row, then one row per node
    rows = tel.to_rows()
    assert rows[0]["cvar95_completion_s"] == s["cvar95_completion_s"]
    assert len(rows) == 1 + len(tel.utilisation())
    for row in rows[1:]:
        assert {"name", "utilisation", "mean_queue_len"} <= set(row)


# --------------------------------------------------------------------------
# serving engines: wall-clock spans (tier-1 lane — model forward passes)
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_serve_engines_emit_spans():
    from repro.configs import reduced_config
    from repro.serve import Request, ServeEngine
    from repro.serve.continuous import ContinuousBatchEngine
    cfg = reduced_config("qwen3-1.7b").replace(dtype="float32")

    tracer = Tracer()
    engine = ServeEngine(cfg, batch_size=2, max_len=48, obs=tracer)
    prompts = np.tile(np.arange(8, dtype=np.int32)[None], (2, 1))
    engine.generate_batch(prompts, 5)
    spans = tracer.all_spans()
    assert [s.name for s in spans] == ["prefill", "decode"]
    assert all(s.track == "serve_engine" for s in spans)
    assert [i.name for i in tracer.all_instants()] == ["first_token"]
    validate_chrome(tracer.export_chrome(None))

    ctracer = Tracer()
    ceng = ContinuousBatchEngine(cfg, slots=2, max_len=48, seed=3,
                                 obs=ctracer)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=n,
                                               dtype=np.int32),
                    max_new_tokens=4, arrived_at=i * 0.01)
            for i, n in enumerate((5, 9, 7))]
    done = ceng.serve(reqs)
    sojourns = [s for s in ctracer.all_spans() if s.name == "sojourn"]
    assert len(sojourns) == len(done)
    assert {i.name for i in ctracer.all_instants()} >= {"admit"}
    validate_chrome(ctracer.export_chrome(None))
