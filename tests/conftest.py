"""Shared test configuration: hypothesis speed profiles.

The full tier-1 suite (``pytest -x -q``) keeps hypothesis defaults.  The
fast lane trims property tests to a smoke-sized number of examples::

    HYPOTHESIS_PROFILE=fast pytest -q -m "not slow"

which together with the ``slow`` markers (see pytest.ini) brings the
suite from ~10 minutes to a few minutes on CPU — the pre-push loop and
the CI ``fast`` job.  ``HYPOTHESIS_PROFILE=full`` (the default) is the
release gate.
"""
import os

from hypothesis_shim import HAVE_HYPOTHESIS

if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, settings

    settings.register_profile("full", settings.default)
    settings.register_profile(
        "fast", max_examples=10, deadline=None,
        suppress_health_check=list(HealthCheck))
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "full"))
