"""Predictor-layer tests: the shared metrics, GBT kernel-training
equivalence, persistence round-trips, and the extended-target selector."""
import dataclasses

import numpy as np
import pytest

from repro.core import features as feat
from repro.core.predictors import (GBTRegressor, LinearRegressor,
                                   MLPRegressor, MultiTargetGBT,
                                   RidgeRegressor, load_predictor,
                                   normalised_rmse, per_target_nrmse, r2,
                                   rmse, save_predictor)
from repro.core.profiler import ProfileRecord


def synth(rng, n=300, f=6):
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = 2.0 * x[:, 0] + np.sin(x[:, 1]) + 0.1 * rng.normal(size=n)
    return x, y


# --------------------------------------------------------------------------
# metrics (predictors/common.py)
# --------------------------------------------------------------------------
def test_rmse_basic():
    pred = np.array([1.0, 2.0, 3.0])
    y = np.array([1.0, 2.0, 5.0])
    assert rmse(pred, y) == pytest.approx(np.sqrt(4.0 / 3.0))
    assert rmse(y, y) == 0.0


def test_normalised_rmse_is_span_scaled():
    y = np.array([[0.0], [10.0], [20.0]])
    pred = y + 2.0
    # residual 2 over span 20 -> 0.1
    assert normalised_rmse(pred, y) == pytest.approx(0.1)
    # invariant to affine target rescaling
    assert normalised_rmse(pred * 50, y * 50) == pytest.approx(0.1)


def test_normalised_rmse_zero_span_degenerate():
    """A constant target column must not divide by zero — the span
    guard substitutes 1, so the metric stays finite."""
    y = np.full((5, 2), 3.0)
    y[:, 1] = np.arange(5)
    pred = y.copy()
    pred[:, 0] += 0.5                    # error on the constant column
    out = normalised_rmse(pred, y)
    assert np.isfinite(out)
    assert out == pytest.approx(np.sqrt(0.25 / 2))

    per = per_target_nrmse(pred, y)
    assert per.shape == (2,)
    assert per[0] == pytest.approx(0.5)  # span 1 substituted
    assert per[1] == 0.0


def test_per_target_nrmse_matches_scalar():
    rng = np.random.default_rng(0)
    y = rng.normal(size=(40, 3))
    pred = y + rng.normal(0, 0.1, size=y.shape)
    per = per_target_nrmse(pred, y)
    assert normalised_rmse(pred, y) == pytest.approx(
        float(np.sqrt(np.mean(per ** 2))))


def test_r2():
    rng = np.random.default_rng(1)
    y = rng.normal(size=(50, 1))
    assert r2(y, y) == pytest.approx(1.0)
    assert r2(np.full_like(y, y.mean()), y) == pytest.approx(0.0)
    assert r2(-y, y) < 0.0               # worse than the mean predictor
    # degenerate constant target: the eps guard keeps it finite
    const = np.full((10, 1), 2.0)
    assert np.isfinite(r2(const + 1.0, const))


# --------------------------------------------------------------------------
# GBT kernel-training equivalence
# --------------------------------------------------------------------------
def test_gbt_grad_histogram_kernel_matches_numpy():
    """The Pallas one-hot histogram agrees with the numpy bincount path
    (f32 kernel accumulation vs f64 host — tolerance)."""
    from repro.core.predictors.gbt import bin_data, grad_histogram, \
        quantile_bins
    rng = np.random.default_rng(2)
    x, _ = synth(rng, n=500)
    grad = rng.normal(size=500)
    codes = bin_data(x, quantile_bins(x, 32))
    g0, c0 = grad_histogram(codes, grad, 32, use_kernel=False)
    g1, c1 = grad_histogram(codes, grad, 32, use_kernel=True)
    np.testing.assert_allclose(g1, g0, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(c1, c0)


def test_gbt_use_kernel_training_equivalence():
    """use_kernel=True routes the gradient histograms through the Pallas
    one-hot kernel; on this fixture the grown trees match the
    numpy-histogram ensemble node-for-node and predictions are
    bit-identical (f32 histogram rounding can flip genuinely-tied
    splits on larger data, which leaves predictions equal anyway)."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(120, 4)).astype(np.float32)
    y = (x[:, 0] > 0).astype(float) * 2 + x[:, 1]
    kw = dict(n_trees=5, max_depth=2, subsample=1.0, seed=0)
    host = GBTRegressor(**kw, use_kernel=False).fit(x, y)
    kern = GBTRegressor(**kw, use_kernel=True).fit(x, y)
    assert len(host.trees_) == len(kern.trees_)
    for th, tk in zip(host.trees_, kern.trees_):
        assert [(n.feature, n.threshold_bin, n.left, n.right)
                for n in th] \
            == [(n.feature, n.threshold_bin, n.left, n.right) for n in tk]
    np.testing.assert_array_equal(kern.predict(x), host.predict(x))


# --------------------------------------------------------------------------
# persistence round-trips
# --------------------------------------------------------------------------
def test_linear_regressor_is_ridge_alias():
    assert LinearRegressor is RidgeRegressor


@pytest.mark.parametrize("make,multi_y", [
    (lambda: RidgeRegressor(alpha=0.5), True),
    (lambda: MLPRegressor(hidden=(12, 6), epochs=8), True),
    (lambda: GBTRegressor(n_trees=12, max_depth=3, subsample=0.8,
                          seed=5), False),
    (lambda: MultiTargetGBT(n_trees=6, max_depth=3, seed=5), True),
], ids=["ridge", "mlp", "gbt", "multigbt"])
def test_persist_round_trip_predict_equivalence(tmp_path, make, multi_y):
    rng = np.random.default_rng(6)
    x, y = synth(rng, n=200)
    if multi_y:
        y = np.stack([y, y * 0.5 + 1.0], axis=1)
    model = make().fit(x, y)
    base = str(tmp_path / "model")
    npz, meta = save_predictor(model, base)
    assert npz.endswith(".npz") and meta.endswith(".json")
    loaded = load_predictor(base)
    assert type(loaded) is type(model)
    assert np.array_equal(np.asarray(model.predict(x)),
                          np.asarray(loaded.predict(x)))


def test_persist_round_trip_hyperparams(tmp_path):
    rng = np.random.default_rng(7)
    x, y = synth(rng, 120)
    model = GBTRegressor(n_trees=5, max_depth=2, learning_rate=0.3,
                         n_bins=32, seed=9).fit(x, y)
    loaded = load_predictor(str(save_predictor(
        model, str(tmp_path / "m"))[0][:-4]))
    for f in dataclasses.fields(model):
        assert getattr(loaded, f.name) == getattr(model, f.name), f.name


def test_persist_rejects_unknown(tmp_path):
    class NotAModel:
        pass

    with pytest.raises(TypeError, match="persist"):
        save_predictor(NotAModel(), str(tmp_path / "x"))


# --------------------------------------------------------------------------
# extended profiling targets
# --------------------------------------------------------------------------
def make_record(**over):
    base = dict(label="r", kind="mlp", flops_per_step=1e9,
                macs_per_step=5e8, total_time_s=12.0, step_time_s=0.01,
                peak_bytes=2e6, param_count=1000, final_loss=0.1,
                final_acc=0.9,
                config={"kind": "mlp", "type_idx": 0, "lr": 1e-3,
                        "batch_size": 32, "epochs": 3,
                        "optimiser": "adam", "dataset_size": 1000},
                hardware={"hw_peak_flops": 1e12, "hw_hbm_bw": 1e10,
                          "hw_link_bw": 1e8, "hw_clock_ghz": 2.0,
                          "hw_mem_bytes": 1e9, "hw_is_accelerated": 1.0,
                          "hw_tdp_watts": 45.0})
    base.update(over)
    return ProfileRecord(**base)


def test_profile_record_targets_default_unchanged():
    rec = make_record()
    assert set(rec.targets()) == {"flops", "macs", "total_time"}
    ext = rec.targets(extended=True)
    assert ext["step_time"] == 0.01
    assert ext["peak_bytes"] == 2e6


def test_targets_of_selector():
    rec = make_record()
    default = feat.targets_of(rec)
    assert default.shape == (len(feat.TARGET_NAMES),)
    ext = feat.targets_of(rec, feat.EXTENDED_TARGET_NAMES)
    assert ext.shape == (5,)
    np.testing.assert_array_equal(ext[:3], default)
    picked = feat.targets_of(rec, ["total_time", "peak_bytes"])
    assert picked[0] == np.float32(12.0)
    assert picked[1] == np.float32(2e6)
    with pytest.raises(KeyError, match="unknown target"):
        feat.targets_of(rec, ["nope"])


def test_records_to_dataset_extended_targets():
    recs = [make_record(total_time_s=float(i + 1),
                        peak_bytes=float(1e6 * (i + 1)))
            for i in range(4)]
    data = feat.records_to_dataset(
        recs, targets=["total_time", "peak_bytes"])
    assert data.y.shape == (4, 2)
    assert data.target_names == ["total_time", "peak_bytes"]
    np.testing.assert_array_equal(data.y[:, 1],
                                  np.float32([1e6, 2e6, 3e6, 4e6]))