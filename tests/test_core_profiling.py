"""Core paper pipeline tests: profiler → predictors → FL → offload → sched."""
import numpy as np
import pytest

from repro.core import dataset as ds
from repro.core.features import FEATURE_NAMES, featurize, records_to_dataset
from repro.core.predictors import (GBTRegressor, MLPRegressor, MultiTargetGBT,
                                   RidgeRegressor, normalised_rmse, r2)
from repro.core.profiler import profile_workload
from repro.core.workloads import (WorkloadConfig, full_grid,
                                  synthetic_image_data)

# measured profiling runs + predictor fits: ~1.5 minutes on CPU —
# excluded from the fast lane, covered by the tier-1 job
pytestmark = pytest.mark.slow


# --------------------------------------------------------------------------
# workloads + profiler
# --------------------------------------------------------------------------
def test_table1_grid_size():
    grid = list(full_grid())
    # 2 families × 3 types × 4 epochs × 4 optimisers × 6 lrs × 4 batch sizes
    assert len(grid) == 2 * 3 * 4 * 4 * 6 * 4 == 2304


@pytest.mark.parametrize("kind,ti", [("mlp", 0), ("cnn", 1)])
def test_profile_workload_measured(kind, ti):
    wc = WorkloadConfig(kind, ti, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32, dataset_size=128)
    rec = profile_workload(wc, max_steps=3)
    assert rec.flops_per_step > 0
    assert rec.macs_per_step == rec.flops_per_step / 2
    assert rec.total_time_s > 0 and np.isfinite(rec.total_time_s)
    assert rec.param_count > 1000
    assert np.isfinite(rec.final_loss)
    feats = featurize(rec)
    assert feats.shape == (len(FEATURE_NAMES),)
    assert np.isfinite(feats).all()


def test_workload_training_learns():
    """A Table-I CNN must beat chance on the synthetic 10-class task."""
    wc = WorkloadConfig("cnn", 0, epochs=5, optimiser="adam", lr=3e-3,
                        batch_size=64, dataset_size=512)
    rec = profile_workload(wc)
    assert rec.final_acc > 0.5, rec.final_acc


# --------------------------------------------------------------------------
# predictors (the paper's Fig. 2 comparison, miniature)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def toy_regression():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(600, 8)).astype(np.float32)
    y1 = np.sin(3 * x[:, 0]) + x[:, 1] * x[:, 2]
    y2 = np.exp(x[:, 3]) + 0.5 * x[:, 4] ** 2
    y = np.stack([y1, y2], axis=1).astype(np.float32)
    return x[:480], y[:480], x[480:], y[480:]


def test_gbt_fits_nonlinear(toy_regression):
    xtr, ytr, xte, yte = toy_regression
    m = MultiTargetGBT(n_trees=150, max_depth=6, learning_rate=0.1,
                       subsample=0.8).fit(xtr, ytr)
    pred = m.predict(xte)
    assert r2(pred, yte) > 0.95, r2(pred, yte)


def test_gbt_depth_improves(toy_regression):
    """Paper Fig. 2b: max-depth proportionate to accuracy."""
    xtr, ytr, xte, yte = toy_regression
    errs = []
    for depth in (2, 4, 8):
        m = GBTRegressor(n_trees=80, max_depth=depth).fit(xtr, ytr[:, 0])
        errs.append(normalised_rmse(m.predict(xte), yte[:, 0]))
    assert errs[2] < errs[0], errs


def test_mlp_regressor_learns(toy_regression):
    xtr, ytr, xte, yte = toy_regression
    m = MLPRegressor(hidden=(64, 32), epochs=150, lr=3e-3).fit(xtr, ytr)
    assert r2(m.predict(xte), yte) > 0.8


def test_ridge_baseline(toy_regression):
    xtr, ytr, xte, yte = toy_regression
    m = RidgeRegressor().fit(xtr, ytr)
    assert r2(m.predict(xte), yte) > 0.3    # linear floor


def test_paper_headline_gbt_beats_mlp(toy_regression):
    """The paper's headline: trees beat MLPs on tabular profiles."""
    xtr, ytr, xte, yte = toy_regression
    gbt = MultiTargetGBT(n_trees=200, max_depth=8, subsample=0.8
                         ).fit(xtr, ytr)
    mlp = MLPRegressor(hidden=(64, 32), epochs=120, lr=3e-3).fit(xtr, ytr)
    e_gbt = normalised_rmse(gbt.predict(xte), yte)
    e_mlp = normalised_rmse(mlp.predict(xte), yte)
    assert e_gbt < e_mlp, (e_gbt, e_mlp)


def test_gbt_subsample_variants(toy_regression):
    xtr, ytr, xte, yte = toy_regression
    for sub in (0.5, 0.8, 1.0):
        m = GBTRegressor(n_trees=60, max_depth=5, subsample=sub
                         ).fit(xtr, ytr[:, 0])
        assert normalised_rmse(m.predict(xte), yte[:, 0]) < 0.2


# --------------------------------------------------------------------------
# dataset generation (tiny grid, real measurements)
# --------------------------------------------------------------------------
def test_generate_dataset_small():
    records, data = ds.generate(n_runs=6, max_steps=2, augment_hardware=True)
    assert len(records) == 6 * len(__import__(
        "repro.hw", fromlist=["EDGE_DEVICES"]).EDGE_DEVICES)
    assert data.x.shape[1] == len(FEATURE_NAMES)
    assert np.isfinite(data.x).all() and np.isfinite(data.y).all()
    # hardware projection must change total_time but not flops
    base = records[0]
    proj = [r for r in records if r.label.startswith(base.label + "@")]
    assert proj and all(p.flops_per_step == base.flops_per_step
                        for p in proj)
    assert any(p.total_time_s != base.total_time_s for p in proj)
