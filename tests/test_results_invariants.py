"""Invariants over the shipped results artifacts (skipped if absent) —
catches regressions in the dry-run/roofline pipeline itself."""
import json
import os

import pytest

R = "results"


def _load(name):
    p = os.path.join(R, name)
    if not os.path.exists(p):
        pytest.skip(f"{p} not generated")
    with open(p) as f:
        return json.load(f)


@pytest.mark.parametrize("fname", ["dryrun_single_pod.json",
                                   "dryrun_multi_pod.json"])
def test_dryrun_sweep_complete_and_consistent(fname):
    recs = _load(fname)
    assert len(recs) == 40                       # 10 archs × 4 shapes
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    failed = [r for r in recs if r["status"] == "FAILED"]
    assert not failed, [(r["arch"], r["shape"]) for r in failed]
    assert len(ok) == 39
    # the single principled skip
    assert [(r["arch"], r["shape"]) for r in skipped] == \
        [("whisper-tiny", "long_500k")]
    for r in ok:
        ro = r["roofline"]
        terms = (ro["compute_s"], ro["memory_s"], ro["collective_s"])
        assert all(t >= 0 for t in terms), r["arch"]
        dom = {"compute": 0, "memory": 1, "collective": 2}[ro["dominant"]]
        assert terms[dom] == max(terms), (r["arch"], r["shape"])
        assert r["fits_hbm16"], (r["arch"], r["shape"])
        assert r["bytes_per_device_tpu_adjusted"] <= r["bytes_per_device"]
        if ro["useful_ratio"] is not None:
            assert 0 < ro["useful_ratio"] <= 1.5, (r["arch"], r["shape"],
                                                   ro["useful_ratio"])


def test_roofline_flops_vs_model_flops_sane():
    recs = [r for r in _load("dryrun_single_pod.json")
            if r["status"] == "ok"]
    for r in recs:
        ro = r["roofline"]
        # compiled flops (global) must be >= a third of analytic model flops
        # (remat/attention push it above; sub-1 only from MoE all-expert
        # decode shapes and swa variants)
        glob = ro["flops_per_chip"] * 256
        assert glob > 0
        if r["shape"] == "train_4k":
            assert glob >= 0.8 * ro["model_flops"], (r["arch"],
                                                     glob / ro["model_flops"])


def test_perf_experiments_record_the_journey():
    recs = _load("perf_experiments.json")
    names = {r["experiment"] for r in recs}
    # three required pairs + the bonus pair, baselines present
    for base in ("A0", "B0", "C0", "D0"):
        assert any(n.startswith(base) for n in names), names
    assert all("hypothesis" in r for r in recs)
    by = {r["experiment"]: r for r in recs if r["status"] == "ok"}
    # headline wins still hold
    assert by["A1_mla_absorbed"]["roofline"]["compute_s"] < \
        0.2 * by["A0_baseline_mla_naive"]["roofline"]["compute_s"]
    assert by["B3_pin_inner"]["roofline"]["collective_s"] < \
        0.4 * by["B0_baseline_fsdp"]["roofline"]["collective_s"]
    assert by["C2_no_sp"]["roofline"]["collective_s"] < \
        0.2 * by["C0_baseline_sp"]["roofline"]["collective_s"]
