"""repro.sim.queueing test lanes.

Four layers of pins:

  * units — ``ServerPool`` FIFO semantics (first-index tie-break,
    realised busy state, infinite capacity), ``NodePools``'s
    incrementally-maintained ``avail`` against the full recompute, and
    the closed forms (Erlang-C, M/M/1, M/M/c, RTT mean/quantile/CVaR
    against Monte Carlo);
  * RNG hygiene — int seeds replay the historical ``default_rng(int)``
    streams bit-for-bit, ``SeedSequence(k)`` equals ``k``, spawned
    children are independent;
  * regression — capacity=1 pools with no RTT reproduce the historical
    believed-queue runs bit-for-bit on both engines (hypothesis sweep),
    and zero-contention capacity=∞ runs match too;
  * validation (slow) — simulated M/M/1 / M/M/c mean sojourn within
    confidence bounds of the Erlang-C prediction at ρ ∈ {0.3, 0.7, 0.9}.

The tail-aware cost stack (``CompositeCost(tail=...)``,
``QueueAwareCost``) is pinned numpy ≡ jax bit-for-bit and Pallas-close,
mirroring tests/test_decide_split.py.
"""
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import costs as co
from repro.core import decisions as dec
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.hw import EDGE_DEVICES, get_device
from repro.sim import (ClusterLinks, LognormalRTT, NodePools,
                       ParetoStreamScheduler, RandomWalkLink, ServerPool,
                       WeibullRTT, erlang_c, mm1_sojourn, mmc_sojourn,
                       poisson_arrivals, simulate_stream, spawn_streams)
from repro.sim.state import DriftingEnv

SPECS = list(EDGE_DEVICES.values())


def make_tasks(n, seed=3, deadlines=False):
    rng = np.random.default_rng(seed)
    return [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                     input_bytes=float(rng.uniform(1e4, 1e7)),
                     deadline_s=float(rng.uniform(0.02, 2.0))
                     if deadlines else None)
            for i in range(n)]


def make_nodes(n):
    return [sch.Node(SPECS[j % len(SPECS)]) for j in range(n)]


def record_rows(tel):
    return [(r.name, r.arrived_s, r.started_s, r.finished_s, r.node,
             r.node_id, r.energy_j, r.transfer_s, r.split, r.switches)
            for r in tel.records]


# --------------------------------------------------------------------------
# ServerPool / NodePools units
# --------------------------------------------------------------------------
def test_pool_capacity1_is_scalar_avail():
    pool = ServerPool(1)
    s0, f0 = pool.admit(0.0, 2.0)
    assert (s0, f0) == (0.0, 2.0)
    s1, f1 = pool.admit(1.0, 3.0)       # arrives while busy: waits
    assert (s1, f1) == (2.0, 5.0)
    assert pool.wait(3.0) == 2.0
    assert pool.wait(6.0) == 0.0
    assert pool.next_free() == 5.0


def test_pool_fifo_tie_break_first_index():
    pool = ServerPool(3)
    for _ in range(3):                   # all servers free at 0: use #0..2
        pool.admit(0.0, 1.0)
    assert np.array_equal(pool.busy, [1.0, 1.0, 1.0])
    s, f = pool.admit(0.5, 1.0)          # all free at 1.0: first index wins
    assert (s, f) == (1.0, 2.0)
    assert np.array_equal(pool.busy, [2.0, 1.0, 1.0])


def test_pool_multiserver_wait():
    pool = ServerPool(2)
    pool.admit(0.0, 4.0)
    pool.admit(0.0, 2.0)
    # both busy; earliest frees at 2.0
    assert pool.wait(1.0) == 1.0
    assert pool.queue_len(1.0) == 2
    s, f = pool.admit(1.0, 1.0)
    assert (s, f) == (2.0, 3.0)


def test_pool_infinite_capacity_never_waits():
    pool = ServerPool(None)
    for k in range(3):
        s, f = pool.admit(float(k) * 0.1, 5.0)
        assert s == float(k) * 0.1       # starts immediately, no wait
    assert pool.wait(0.3) == 0.0
    assert pool.queue_len(0.25) == 3     # three in service, none done
    assert pool.queue_len(6.0) == 0


def test_pool_rejects_bad_capacity():
    with pytest.raises(ValueError):
        ServerPool(0)


def test_pool_utilisation_and_queue_area():
    pool = ServerPool(1)
    pool.admit(0.0, 1.0)                 # busy [0, 1]
    assert pool.utilisation(2.0) == pytest.approx(0.5)
    pool2 = ServerPool(1)
    pool2.admit(0.0, 2.0)
    pool2.admit(0.0, 2.0)                # waits 2
    pool2.admit(0.0, 2.0)                # waits 4
    # Little's law: total wait 6 over a 6s busy period
    assert pool2.mean_queue_len(6.0) == pytest.approx(1.0)


def test_nodepools_incremental_avail_matches_recompute():
    rng = np.random.default_rng(0)
    pools = NodePools([ServerPool(int(c)) for c in rng.integers(1, 4, 6)])
    for _ in range(200):
        j = int(rng.integers(6))
        pools.admit(j, float(rng.uniform(0, 50)),
                    float(rng.uniform(0.1, 3.0)))
        assert np.array_equal(pools.avail, pools.recompute_avail())


def test_nodepools_validations():
    pools = NodePools.uniform(2, 1)
    nodes = make_nodes(3)
    with pytest.raises(ValueError, match="2 pools for 3 nodes"):
        simulate_stream(make_tasks(2), [0.0, 1.0], nodes, pools=pools)
    with pytest.raises(ValueError, match="rebalance"):
        simulate_stream(make_tasks(2), [0.0, 1.0], make_nodes(2),
                        pools=pools, rebalance=True)


# --------------------------------------------------------------------------
# closed forms
# --------------------------------------------------------------------------
def test_erlang_c_known_values():
    # c=1: P(wait) = rho
    assert erlang_c(1, 0.5) == pytest.approx(0.5)
    assert erlang_c(1, 0.0) == 0.0
    # c=2, a=1 (rho=0.5): C = 1/3
    assert erlang_c(2, 1.0) == pytest.approx(1.0 / 3.0)
    with pytest.raises(ValueError):
        erlang_c(2, 2.0)                 # unstable


def test_mm1_mmc_consistency():
    assert mm1_sojourn(0.5, 1.0) == pytest.approx(2.0)
    # M/M/1 is M/M/c at c=1
    assert mmc_sojourn(0.5, 1.0, 1) == pytest.approx(mm1_sojourn(0.5, 1.0))
    with pytest.raises(ValueError):
        mm1_sojourn(1.0, 1.0)


@pytest.mark.parametrize("proc", [WeibullRTT(shape=0.7, scale=0.02, seed=5),
                                  LognormalRTT(mu=-4.0, sigma=1.2, seed=5)])
def test_rtt_closed_forms_match_monte_carlo(proc):
    x = proc.sample(400_000)
    assert proc.mean() == pytest.approx(float(x.mean()), rel=0.05)
    assert proc.percentile(0.99) == pytest.approx(
        float(np.percentile(x, 99)), rel=0.05)
    var = np.percentile(x, 99)
    assert proc.cvar(0.99) == pytest.approx(
        float(x[x >= var].mean()), rel=0.10)
    # tail_stat dispatch
    assert proc.tail_stat("p99", 0.5) == proc.percentile(0.99)
    assert proc.tail_stat("cvar", 0.95) == proc.cvar(0.95)
    with pytest.raises(ValueError):
        proc.tail_stat("p50", 0.5)


def test_rtt_heavy_tail_orders():
    w = WeibullRTT(shape=0.6, scale=0.01, seed=0)
    assert w.mean() < w.percentile(0.99) < w.cvar(0.99)


# --------------------------------------------------------------------------
# RNG hygiene
# --------------------------------------------------------------------------
def test_int_seed_replays_historical_stream():
    # default_rng(k) builds SeedSequence(k) internally: accepting
    # SeedSequence seeds must not change what an int seed produces
    a = poisson_arrivals(5.0, n=64, seed=7)
    b = poisson_arrivals(5.0, n=64, seed=np.random.SeedSequence(7))
    assert np.array_equal(a, b)
    w1 = WeibullRTT(seed=3).sample(32)
    w2 = WeibullRTT(seed=np.random.SeedSequence(3)).sample(32)
    assert np.array_equal(w1, w2)


def test_spawn_streams_independent():
    kids = spawn_streams(42, 3)
    assert len(kids) == 3
    draws = [np.random.default_rng(k).uniform(size=8) for k in kids]
    assert not np.array_equal(draws[0], draws[1])
    # deterministic: spawning again yields the same children
    again = [np.random.default_rng(k).uniform(size=8)
             for k in spawn_streams(42, 3)]
    assert all(np.array_equal(a, b) for a, b in zip(draws, again))


def test_cluster_links_seedsequence_spawn():
    base = [40e6, 55e6, 70e6]
    # int seed: historical per-node seed+j streams, unchanged
    a = ClusterLinks.random_walk(base, sigma=0.4, seed=2)
    b = ClusterLinks([RandomWalkLink(bw, sigma=0.4, seed=2 + j)
                      for j, bw in enumerate(base)])
    for _ in range(5):
        assert np.array_equal(a.step(0.5), b.step(0.5))
    # SeedSequence seed: each link gets an independent spawned child
    c = ClusterLinks.random_walk(base, sigma=0.4,
                                 seed=np.random.SeedSequence(2))
    vals = c.step(0.5)
    assert vals.shape == (3,)
    assert not np.array_equal(vals, a.values())


def test_run_seed_spawn_keeps_processes_independent():
    arr_ss, rtt_ss = spawn_streams(123, 2)
    arr = poisson_arrivals(5.0, n=32, seed=arr_ss)
    rtt = WeibullRTT(seed=rtt_ss)
    # adding the RTT process does not perturb the arrival stream
    assert np.array_equal(arr, poisson_arrivals(5.0, n=32,
                                                seed=spawn_streams(123,
                                                                   2)[0]))
    assert rtt.sample(4).shape == (4,)


# --------------------------------------------------------------------------
# regression: pools thread through both engines without changing history
# --------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["event", "fleet"])
@pytest.mark.parametrize("policy", ["min_min", "heft"])
def test_capacity1_bit_for_bit_with_historical(engine, policy):
    tasks = make_tasks(40, seed=11, deadlines=True)
    rng = np.random.default_rng(1)
    arrivals = np.sort(rng.uniform(0, 3.0, len(tasks)))
    t0 = simulate_stream(tasks, arrivals, make_nodes(3), policy=policy,
                         engine=engine)
    t1 = simulate_stream(tasks, arrivals, make_nodes(3), policy=policy,
                         pools=NodePools.uniform(3, 1), engine=engine)
    assert record_rows(t0) == record_rows(t1)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(2, 12),
       st.integers(2, 4), st.booleans())
def test_zero_contention_infinite_capacity_bit_for_bit(
        seed, n_tasks, n_nodes, heft):
    """Zero-contention runs (arrivals spaced past every service time)
    are identical under capacity=∞ pools, capacity=1 pools, and the
    historical believed queue — on both engines."""
    rng = np.random.default_rng(seed)
    tasks = [sch.Task(f"t{i}", flops=float(rng.uniform(1e8, 5e9)),
                      input_bytes=float(rng.uniform(1e3, 1e5)))
             for i in range(n_tasks)]
    nodes = make_nodes(n_nodes)
    # worst-case service: slowest node, then space arrivals past it
    worst = max(sch.Node(n.spec).exec_time(t)
                for t in tasks for n in nodes)
    arrivals = np.arange(n_tasks, dtype=np.float64) * (worst * 1.01)
    policy = "heft" if heft else "min_min"
    base = [record_rows(simulate_stream(
        tasks, arrivals, nodes, policy=policy, engine=e))
        for e in ("event", "fleet")]
    assert base[0] == base[1]
    for cap in (None, 1):
        for e, ref in zip(("event", "fleet"), base):
            got = record_rows(simulate_stream(
                tasks, arrivals, nodes, policy=policy,
                pools=NodePools.uniform(n_nodes, cap), engine=e))
            assert got == ref


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 30), st.integers(2, 4),
       st.sampled_from(["min_min", "heft"]),
       st.sampled_from([1, 2, None]), st.booleans())
def test_event_fleet_equivalent_under_contention(
        seed, n_tasks, n_nodes, policy, capacity, with_rtt):
    rng = np.random.default_rng(seed)
    tasks = make_tasks(n_tasks, seed=seed % 1000, deadlines=True)
    arrivals = np.sort(rng.uniform(0, 2.0, n_tasks))
    rows = []
    for engine in ("event", "fleet"):
        rtt = WeibullRTT(shape=0.7, scale=0.01, seed=seed % 97) \
            if with_rtt else None
        rows.append(record_rows(simulate_stream(
            tasks, arrivals, make_nodes(n_nodes), policy=policy,
            pools=NodePools.uniform(n_nodes, capacity), rtt=rtt,
            engine=engine)))
    assert rows[0] == rows[1]


def test_contention_inflates_sojourn_and_telemetry():
    tasks = make_tasks(60, seed=2, deadlines=True)
    arrivals = np.zeros(len(tasks))          # all at once: heavy queueing
    tel = simulate_stream(tasks, arrivals, make_nodes(2),
                          pools=NodePools.uniform(2, 1))
    s = tel.summary()
    assert s["p99_wait_s"] > 0.0
    assert s["mean_wait_s"] > 0.0
    assert s["mean_queue_len"] > 0.0
    assert tel.cvar(0.95) >= s["p99_wait_s"] * 0.0  # defined, finite
    # per-record breakdown: sojourn == wait + service + transfer
    for r in tel.records:
        assert r.sojourn_s == pytest.approx(
            r.wait_s + r.service_s + r.transfer_s)
    # per-node queue lengths are exported
    assert sum(tel.queue_lens().values()) == pytest.approx(
        s["mean_queue_len"])


def test_rtt_recorded_as_transfer():
    tasks = make_tasks(10, seed=4)
    arrivals = np.linspace(0, 5, 10)
    rtt = WeibullRTT(seed=9)
    tel = simulate_stream(tasks, arrivals, make_nodes(2),
                          pools=NodePools.uniform(2, 1), rtt=rtt)
    assert all(r.transfer_s > 0.0 for r in tel.records)
    # the delay really pushed completions: finish > start + service
    assert all(r.finished_s > r.started_s for r in tel.records)


def test_saturation_hook_fires_and_fleet_rejects():
    layers = [off.LayerCost(f"l{i}", flops=2e8 * (i + 1),
                            act_bytes=1e5 * (i + 1)) for i in range(5)]
    env = DriftingEnv(get_device("jetson-orin-nano"),
                      get_device("edge-server-a100"),
                      RandomWalkLink(30e6, sigma=0.3, seed=4),
                      link_latency_s=0.005)
    tasks = make_tasks(60, seed=1, deadlines=True)
    arrivals = np.sort(np.random.default_rng(0).uniform(0, 0.4, 60))
    tel = simulate_stream(tasks, arrivals, make_nodes(3),
                          split_planner=ParetoStreamScheduler(),
                          split_env=env, split_layers=layers,
                          link_update_dt=0.5,
                          pools=NodePools.uniform(3, 1),
                          saturation_threshold=0.5)
    assert tel.summary().get("split_saturation_repicks", 0) >= 1
    with pytest.raises(ValueError, match="saturation_threshold"):
        simulate_stream(tasks, arrivals, make_nodes(3),
                        split_planner=ParetoStreamScheduler(),
                        split_env=env, split_layers=layers,
                        pools=NodePools.uniform(3, 1),
                        saturation_threshold=0.5, engine="fleet")
    with pytest.raises(ValueError, match="saturation_threshold"):
        simulate_stream(tasks, arrivals, make_nodes(3),
                        pools=NodePools.uniform(3, 1),
                        saturation_threshold=0.5)   # no planner


# --------------------------------------------------------------------------
# tail-aware cost stack: numpy == jax bit-for-bit, pallas close
# --------------------------------------------------------------------------
def rand_layers(rng, n):
    return [off.LayerCost(f"l{i}", flops=float(rng.uniform(1e6, 1e12)),
                          act_bytes=float(rng.uniform(1e2, 1e8)))
            for i in range(n)]


def rand_envs(rng, n):
    return dec.make_envs(
        [SPECS[int(rng.integers(len(SPECS)))] for _ in range(n)],
        SPECS[int(rng.integers(len(SPECS)))],
        link_bw=rng.uniform(1e4, 1e10, n),
        link_latency_s=rng.uniform(0.0, 0.05, n),
        input_bytes=rng.uniform(0.0, 1e7, n))


def tail_cost(tail, wait=0.0):
    return co.CompositeCost(
        weights={"latency_s": 1.0, "energy_j": 0.05, "price": 1.0,
                 "tail_latency_s": 0.5},
        price_per_edge_s=0.1, price_per_gb=0.01, deadline_s=0.05,
        tail=tail, tail_alpha=0.95,
        rtt=WeibullRTT(shape=0.7, scale=0.02, seed=0))


def assert_plans_equal(a, b):
    for f in ("splits", "total_time_s", "device_time_s",
              "transfer_time_s", "edge_time_s"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.objectives == b.objectives
    for f in ("components", "scalar_cost"):
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), f
        if x is not None:
            assert np.array_equal(x, y), f


def test_tail_objective_grows_component_column():
    cost = tail_cost("p99")
    assert cost.objectives == ("latency_s", "energy_j", "price",
                               "deadline_slack_s", "tail_latency_s")
    # the class default is untouched
    assert co.CompositeCost().objectives == ("latency_s", "energy_j",
                                             "price", "deadline_slack_s")
    rng = np.random.default_rng(0)
    layers, envs = rand_layers(rng, 6), rand_envs(rng, 4)
    plan = dec.decide_all(layers, envs, cost=cost, backend="numpy")
    assert plan.components.shape == (4, 5)
    assert cost.tail_excess_s() > 0.0
    # tail excess is charged on offloading splits only: the last
    # column (no offload) carries plain latency
    comp = np.asarray(cost.components(layers, envs))
    assert np.array_equal(comp[..., :-1, 4],
                          comp[..., :-1, 0] + cost.tail_excess_s())
    assert np.array_equal(comp[..., -1, 4], comp[..., -1, 0])


def test_tail_requires_rtt():
    with pytest.raises(ValueError, match="rtt"):
        co.CompositeCost(tail="p99")
    with pytest.raises(ValueError, match="tail"):
        co.CompositeCost(tail="p42", rtt=WeibullRTT(seed=0))


@pytest.mark.parametrize("tail", ["p99", "cvar"])
def test_tail_cost_numpy_jax_bit_for_bit(tail):
    rng = np.random.default_rng(17)
    layers, envs = rand_layers(rng, 12), rand_envs(rng, 9)
    cost = tail_cost(tail)
    assert_plans_equal(dec.decide_all(layers, envs, cost=cost,
                                      backend="numpy"),
                       dec.decide_all(layers, envs, cost=cost,
                                      backend="jax"))


def test_queue_aware_cost_bumps_latency_only():
    rng = np.random.default_rng(5)
    layers, envs = rand_layers(rng, 8), rand_envs(rng, 5)
    base = co.CompositeCost(weights={"latency_s": 1.0, "energy_j": 0.1},
                            deadline_s=0.05)
    qa = co.QueueAwareCost(base=base, wait_s=0.25)
    c0 = np.asarray(base.components(layers, envs))
    c1 = np.asarray(qa.components(layers, envs))
    # latency column: +wait on offloading splits, last split untouched
    assert np.array_equal(c1[..., :-1, 0], c0[..., :-1, 0] + 0.25)
    assert np.array_equal(c1[..., -1, 0], c0[..., -1, 0])
    # every other objective untouched
    assert np.array_equal(c1[..., 1:], c0[..., 1:])


def test_queue_aware_cost_live_pool_state():
    pool = ServerPool(1)
    pool.admit(0.0, 3.0)                 # busy until 3.0
    qa = co.QueueAwareCost(base=co.AnalyticCost(), edge_pool=pool,
                           rtt=LognormalRTT(mu=-5.0, sigma=0.5, seed=0))
    qa.set_now(1.0)
    assert qa._edge_wait() == pytest.approx(2.0 + qa.rtt.mean())
    qa.set_now(5.0)                      # pool drained
    assert qa._edge_wait() == pytest.approx(qa.rtt.mean())


@pytest.mark.parametrize("tail", [None, "p99"])
def test_queue_aware_cost_numpy_jax_bit_for_bit(tail):
    rng = np.random.default_rng(23)
    layers, envs = rand_layers(rng, 10), rand_envs(rng, 7)
    base = tail_cost(tail) if tail else co.CompositeCost(
        weights={"latency_s": 1.0, "energy_j": 0.05, "price": 1.0},
        price_per_edge_s=0.1, price_per_gb=0.01, deadline_s=0.05)
    qa = co.QueueAwareCost(base=base, wait_s=0.1)
    assert_plans_equal(dec.decide_all(layers, envs, cost=qa,
                                      backend="numpy"),
                       dec.decide_all(layers, envs, cost=qa,
                                      backend="jax"))


def test_queue_aware_cost_pallas_close():
    rng = np.random.default_rng(31)
    layers, envs = rand_layers(rng, 9), rand_envs(rng, 6)
    qa = co.QueueAwareCost(base=tail_cost("p99"), wait_s=0.05)
    ref = dec.decide_all(layers, envs, cost=qa, backend="numpy")
    got = dec.decide_all(layers, envs, cost=qa, backend="pallas")
    assert np.array_equal(ref.splits, got.splits)
    for f in ("total_time_s", "device_time_s", "transfer_time_s",
              "edge_time_s"):
        np.testing.assert_allclose(getattr(got, f), getattr(ref, f),
                                   rtol=1e-5, atol=1e-7)


def test_queue_aware_task_matrix_adds_node_waits():
    pools = NodePools.uniform(3, 1)
    pools.admit(1, 0.0, 4.0)             # node 1 backlogged
    qa = co.QueueAwareCost(base=co.AnalyticCost(), pools=pools)
    qa.set_now(1.0)
    tasks = make_tasks(4, seed=0)
    nodes = make_nodes(3)
    base_etc = sch.etc_matrix(tasks, nodes, cost=co.AnalyticCost())
    etc = sch.etc_matrix(tasks, nodes, cost=qa)
    extra = np.asarray(etc) - np.asarray(base_etc)
    assert np.allclose(extra[:, 1], 3.0)       # wait at node 1
    assert np.allclose(extra[:, [0, 2]], 0.0)


# --------------------------------------------------------------------------
# slow validation: M/M/1 and M/M/c against the closed forms
# --------------------------------------------------------------------------
def _sim_mmc_pool(lam, mu, c, n, seed):
    arr_ss, svc_ss = spawn_streams(seed, 2)
    arr = np.cumsum(np.random.default_rng(arr_ss).exponential(1.0 / lam,
                                                              n))
    svc = np.random.default_rng(svc_ss).exponential(1.0 / mu, n)
    pool = ServerPool(c)
    soj = np.empty(n)
    for i in range(n):
        start, fin = pool.admit(arr[i], svc[i])
        soj[i] = fin - arr[i]
        assert start >= arr[i]
    return float(soj.mean())


@pytest.mark.slow
@pytest.mark.parametrize("rho,tol", [(0.3, 0.05), (0.7, 0.05),
                                     (0.9, 0.12)])
@pytest.mark.parametrize("c", [1, 3])
def test_mmc_sojourn_matches_erlang_c(rho, tol, c):
    mu = 1.0
    lam = rho * c * mu
    want = mm1_sojourn(lam, mu) if c == 1 else mmc_sojourn(lam, mu, c)
    got = _sim_mmc_pool(lam, mu, c, 40_000, seed=0)
    assert got == pytest.approx(want, rel=tol)


@pytest.mark.slow
def test_mm1_through_simulator():
    """End-to-end M/M/1: one node, Poisson arrivals, exponential ground
    truth service — the recorded sojourns match 1/(mu - lambda)."""
    mu, rho = 2.0, 0.7
    lam = rho * mu
    n = 12_000
    arr_ss, svc_ss = spawn_streams(7, 2)
    arrivals = poisson_arrivals(lam, n=n, seed=arr_ss)
    svc_rng = np.random.default_rng(svc_ss)

    def service(task, spec, etc_s, start_s):
        return float(svc_rng.exponential(1.0 / mu))

    tasks = [sch.Task(f"t{i}", flops=1e9, input_bytes=0.0)
             for i in range(n)]
    tel = simulate_stream(tasks, arrivals, make_nodes(1),
                          pools=NodePools.uniform(1, 1),
                          service_time_fn=service)
    soj = np.asarray([r.sojourn_s for r in tel.records])
    assert float(soj.mean()) == pytest.approx(mm1_sojourn(lam, mu),
                                              rel=0.08)
    # wait + service decomposition holds for every record
    s = tel.summary()
    assert s["mean_wait_s"] == pytest.approx(
        float(soj.mean()) - 1.0 / mu, rel=0.12)
