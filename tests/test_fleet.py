"""repro.sim.fleet test lanes.

The fleet engine's contract is *bit-for-bit f64 equality* with the host
event loop on every supported configuration — so nearly everything here
is exact ``==``, no tolerances: a hypothesis property sweeps small
random configs across all four arrival processes × all four link-drift
processes × both policies × (no splits / Pareto planner / decide-at-
admission) × (believed / ground-truth service times), and deterministic
pins cover the orderings that only bite on exact ties (arrivals or
finishes landing exactly on link ticks).  The satellites ride along:
``step_batch`` vs scalar ``step`` equality, ``EventQueue.push_batch``
FIFO order, ``DriftingEnv.snapshot`` build counts, and the sharded
``decide_all`` (single-device fallback in the fast lane, an 8-device
``shard_map`` subprocess in tier-1).
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro import sim
from repro.core import decisions as dec
from repro.core import offload as off
from repro.core import scheduler as sch
from repro.core.workloads import WorkloadConfig
from repro.hw import EDGE_DEVICES, get_device

SPECS = list(EDGE_DEVICES.values())


def make_tasks(n, seed=3, deadlines=False):
    rng = np.random.default_rng(seed)
    return [sch.Task(f"t{i}", flops=float(rng.uniform(1e9, 5e11)),
                     input_bytes=float(rng.uniform(1e4, 1e7)),
                     deadline_s=float(rng.uniform(0.02, 2.0))
                     if deadlines else None)
            for i in range(n)]


def make_nodes(n):
    return [sch.Node(SPECS[j % len(SPECS)]) for j in range(n)]


def make_links(kind, n, seed):
    if kind == "fixed":
        return sim.ClusterLinks([sim.FixedLink(50e6 * (j + 1))
                                 for j in range(n)])
    if kind == "walk":
        return sim.ClusterLinks.random_walk(
            [40e6 + 5e6 * j for j in range(n)], sigma=0.4, seed=seed)
    if kind == "twostate":
        return sim.ClusterLinks([sim.TwoStateLink(20e6 * (j + 1),
                                                  4e6 * (j + 1),
                                                  seed=seed + j)
                                 for j in range(n)])
    return sim.ClusterLinks([sim.DiurnalLink(30e6 + 10e6 * j,
                                             amplitude=0.6, period_s=7.0,
                                             noise_sigma=0.2,
                                             seed=seed + j)
                             for j in range(n)])


def make_link_process(kind, seed):
    return {"fixed": lambda: sim.FixedLink(60e6),
            "walk": lambda: sim.RandomWalkLink(60e6, sigma=0.5,
                                               seed=seed),
            "twostate": lambda: sim.TwoStateLink(80e6, 8e6, seed=seed),
            "diurnal": lambda: sim.DiurnalLink(60e6, amplitude=0.7,
                                               period_s=5.0,
                                               noise_sigma=0.3,
                                               seed=seed)}[kind]()


def make_env(kind, seed):
    return sim.DriftingEnv(get_device("jetson-orin-nano"),
                           get_device("edge-server-a100"),
                           make_link_process(kind, seed),
                           input_bytes=2e6)


@pytest.fixture(scope="module")
def cnn_layers():
    wc = WorkloadConfig("cnn", 2, epochs=5, optimiser="adam", lr=1e-3,
                        batch_size=32)
    return off.workload_layer_costs(wc)


def rec_tuple(r):
    return (r.name, r.arrived_s, r.started_s, r.finished_s, r.node,
            r.node_id, r.deadline_s, r.energy_j, r.split, r.switches)


def make_arrivals(kind, n, seed):
    if kind == "poisson":
        return sim.poisson_arrivals(8.0, n=n, seed=seed)
    if kind == "trace":                  # coarse grid: forces exact ties
        rng = np.random.default_rng(seed)
        return np.sort(np.round(rng.uniform(0, 3, n), 1))
    if kind == "mmpp":
        a = sim.mmpp_arrivals([5.0, 60.0], [0.5, 0.2], horizon=6.0,
                              seed=seed)
    else:
        a = sim.diurnal_arrivals(10.0, horizon=6.0, amplitude=0.8,
                                 period_s=2.0, seed=seed)
    if len(a) >= n:
        return a[:n]
    return np.concatenate([a, 6.0 + np.arange(n - len(a), dtype=float)])


def run_both(*, n_tasks, n_nodes, arrival, linkkind, policy, mode,
             ground_truth, seed, cnn_layers, dt=0.5,
             split_backend="numpy"):
    """One config through both engines (fresh stateful processes each)
    -> (event Telemetry, fleet Telemetry, event links, fleet links)."""
    out = []
    end_links = []
    for engine in ("event", "fleet"):
        tasks = make_tasks(n_tasks, seed=seed, deadlines=True)
        links = make_links(linkkind, n_nodes, seed + 100)
        kw = {}
        if mode == "planner":
            kw["split_planner"] = sim.ParetoStreamScheduler()
        if mode in ("planner", "decide"):
            kw["split_env"] = make_env(linkkind, seed + 7)
            kw["split_layers"] = cnn_layers
        if mode == "decide":
            kw["split_backend"] = split_backend
        if ground_truth:
            kw["service_time_fn"] = \
                lambda task, spec, etc, start: etc * (
                    1.1 + 0.2 * np.sin(start + task.flops * 1e-12))
        tel = sim.simulate_stream(
            tasks, make_arrivals(arrival, n_tasks, seed),
            make_nodes(n_nodes), policy=policy, links=links,
            link_update_dt=dt, engine=engine, **kw)
        out.append(tel)
        end_links.append(links.values())
    return out[0], out[1], end_links[0], end_links[1]


def assert_bit_for_bit(ev, fl, lv_ev=None, lv_fl=None):
    assert [rec_tuple(r) for r in ev.records] \
        == [rec_tuple(r) for r in fl.records]
    assert ev.summary() == fl.summary()
    assert ev.counters == fl.counters
    if lv_ev is not None:                # drift processes end identical
        np.testing.assert_array_equal(lv_ev, lv_fl)


# --------------------------------------------------------------------------
# tentpole: fleet engine == host event loop, bit for bit
# --------------------------------------------------------------------------
@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_fleet_equivalence_property(data, cnn_layers):
    """The satellite-3 property: random small configs over all four
    arrival processes × all four link processes, both policies, all
    three split modes, believed and ground-truth service times —
    telemetry records (placements, splits, switches, energy), summary,
    and counters all exactly equal, and the drift processes end in the
    same state."""
    cfg = dict(
        n_tasks=data.draw(st.integers(1, 32), label="n_tasks"),
        n_nodes=data.draw(st.integers(1, 8), label="n_nodes"),
        arrival=data.draw(st.sampled_from(
            ["poisson", "trace", "mmpp", "diurnal"]), label="arrival"),
        linkkind=data.draw(st.sampled_from(
            ["fixed", "walk", "twostate", "diurnal"]), label="link"),
        policy=data.draw(st.sampled_from(["min_min", "heft"]),
                         label="policy"),
        mode=data.draw(st.sampled_from(["none", "planner", "decide"]),
                       label="mode"),
        ground_truth=data.draw(st.booleans(), label="ground_truth"),
        dt=data.draw(st.sampled_from([0.25, 0.5, 1.0]), label="dt"),
        seed=data.draw(st.integers(0, 2**16), label="seed"))
    ev, fl, lv_ev, lv_fl = run_both(cnn_layers=cnn_layers, **cfg)
    assert_bit_for_bit(ev, fl, lv_ev, lv_fl)


@pytest.mark.parametrize("arrival,linkkind,policy,mode,ground_truth", [
    ("poisson", "walk", "min_min", "none", False),
    ("poisson", "walk", "min_min", "none", True),
    ("trace", "twostate", "heft", "none", True),
    ("mmpp", "diurnal", "min_min", "planner", False),
    ("diurnal", "fixed", "heft", "planner", True),
    ("trace", "walk", "min_min", "decide", False),
    ("poisson", "diurnal", "heft", "decide", True),
])
def test_fleet_equivalence_pins(arrival, linkkind, policy, mode,
                                ground_truth, cnn_layers):
    ev, fl, lv_ev, lv_fl = run_both(
        n_tasks=13, n_nodes=4, arrival=arrival, linkkind=linkkind,
        policy=policy, mode=mode, ground_truth=ground_truth, seed=5,
        cnn_layers=cnn_layers)
    assert_bit_for_bit(ev, fl, lv_ev, lv_fl)


@pytest.mark.slow
@pytest.mark.parametrize("policy,ground_truth", [
    ("min_min", False), ("min_min", True),
    ("heft", False), ("heft", True),
])
def test_fleet_scan_path_equivalence(policy, ground_truth, cnn_layers,
                                     monkeypatch):
    """Long singleton runs route through the jitted lax.scan lowering
    (small-config suites never reach ``_SCAN_MIN``); pin that the scan
    path actually engages and stays bit-for-bit with the host loop."""
    from repro.sim import fleet as fleet_mod
    calls = []
    real = fleet_mod._place_singleton_run

    def counting(*a, **k):
        res = real(*a, **k)
        calls.append(res is not None)
        return res

    monkeypatch.setattr(fleet_mod, "_place_singleton_run", counting)
    ev, fl, lv_ev, lv_fl = run_both(
        n_tasks=1500, n_nodes=8, arrival="poisson", linkkind="walk",
        policy=policy, mode="none", ground_truth=ground_truth, seed=11,
        cnn_layers=cnn_layers, dt=1.0)
    assert calls and all(calls)           # scan lowering really ran
    assert_bit_for_bit(ev, fl, lv_ev, lv_fl)


def test_fleet_tick_collisions():
    """The orderings that only bite on exact ties: arrivals landing
    exactly on link ticks (they pop before the tick — lower seq), and a
    completion landing exactly on a tick (it keeps one extra tick alive
    iff it arrived after the previous tick)."""
    tasks = [sch.Task(f"t{i}", flops=1e11 * (i + 1), input_bytes=1e6)
             for i in range(6)]

    def links():
        return sim.ClusterLinks.random_walk([4e7] * 3, sigma=0.5, seed=3)

    def on_tick(task, spec, etc, start):   # realised finish on the grid
        return float(np.ceil(start + etc) - start)

    for arr, kw in [([0.0, 1.0, 1.0, 2.0, 3.0, 3.0], {}),
                    ([0.0, 0.3, 1.0, 1.7, 2.0, 2.4],
                     dict(service_time_fn=on_tick))]:
        ev = sim.simulate_stream(tasks, arr, make_nodes(3), links=links(),
                                 link_update_dt=1.0, **kw)
        fl = sim.simulate_stream(tasks, arr, make_nodes(3), links=links(),
                                 link_update_dt=1.0, engine="fleet", **kw)
        assert_bit_for_bit(ev, fl)


def test_fleet_edge_configs(cnn_layers):
    """Empty runs, single static task, duplicate task objects in one
    batch, drift disabled (dt=0), callable split_layers."""
    t = sch.Task("x", flops=2e11, input_bytes=5e6)
    tasks = [sch.Task(f"t{i}", flops=1e11 * (i + 1), input_bytes=1e6)
             for i in range(4)]
    cases = [
        dict(tasks=[], arrivals=[], nodes=make_nodes(2)),
        dict(tasks=[], arrivals=[], nodes=make_nodes(3),
             links=lambda: sim.ClusterLinks.random_walk([4e7] * 3,
                                                        seed=1)),
        dict(tasks=[t], arrivals=[0.0], nodes=make_nodes(2)),
        dict(tasks=[t, t, t], arrivals=[0.5, 0.5, 0.5],
             nodes=make_nodes(3),
             links=lambda: sim.ClusterLinks.random_walk([4e7] * 3,
                                                        seed=2)),
        dict(tasks=tasks, arrivals=[0.0, 0.5, 1.0, 1.5],
             nodes=make_nodes(2), link_update_dt=0.0,
             links=lambda: sim.ClusterLinks.random_walk([4e7] * 2,
                                                        seed=5)),
        dict(tasks=tasks, arrivals=[0.0, 0.5, 1.0, 1.5],
             nodes=make_nodes(2),
             split_env=lambda: make_env("walk", 9),
             split_layers=lambda task: cnn_layers),
    ]
    for case in cases:
        tels = []
        for engine in ("event", "fleet"):
            kw = {k: (v() if k in ("links", "split_env") else v)
                  for k, v in case.items()}
            tels.append(sim.simulate_stream(engine=engine, **kw))
        assert_bit_for_bit(*tels)


def test_fleet_rejects_sequential_features(cnn_layers):
    tasks = make_tasks(3)
    arr = [0.0, 0.1, 0.2]
    for kw, msg in [(dict(oracle=object()), "oracle"),
                    (dict(rebalance=True), "rebalance"),
                    (dict(cost=object()), "cost")]:
        with pytest.raises(ValueError, match=msg):
            sim.simulate_fleet(tasks, arr, make_nodes(2), **kw)

    class NoBatchPlanner:                  # lacks admit_batch
        def admit(self, *a, **k):
            pass

    with pytest.raises(ValueError, match="admit_batch"):
        sim.simulate_fleet(tasks, arr, make_nodes(2),
                           split_planner=NoBatchPlanner(),
                           split_env=make_env("fixed", 0),
                           split_layers=cnn_layers)
    with pytest.raises(ValueError, match="split_cost"):
        sim.simulate_stream(tasks, arr, make_nodes(2),
                            split_planner=sim.ParetoStreamScheduler(),
                            split_env=make_env("fixed", 0),
                            split_layers=cnn_layers, split_cost=object())
    with pytest.raises(ValueError, match="engine"):
        sim.simulate_stream(tasks, arr, make_nodes(2), engine="warp")


# --------------------------------------------------------------------------
# satellite: step_batch == n scalar steps, bit for bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["fixed", "walk", "twostate", "diurnal"])
@pytest.mark.parametrize("dt", [0.25, 1.0])
def test_step_batch_matches_scalar_steps(kind, dt):
    a = make_link_process(kind, seed=11)
    b = make_link_process(kind, seed=11)
    scalar = np.asarray([a.step(dt) for _ in range(40)])
    batch = b.step_batch(dt, 40)
    np.testing.assert_array_equal(scalar, batch)
    # continuation: the end states agree too (next steps identical)
    assert a.step(dt) == b.step(dt)
    # chunked == one-shot
    c, d = make_link_process(kind, 11), make_link_process(kind, 11)
    np.testing.assert_array_equal(
        np.concatenate([c.step_batch(dt, 7), c.step_batch(dt, 13)]),
        d.step_batch(dt, 20))
    assert d.step_batch(dt, 0).shape == (0,)


def test_random_walk_step_batch_clipped():
    """Near the clip bounds the log-space cumsum prefix is invalid; the
    batched path must replay the same draws sequentially."""
    a = sim.RandomWalkLink(1.1e6, sigma=2.0, seed=3, min_bw=1e6,
                           max_bw=2e6)
    b = sim.RandomWalkLink(1.1e6, sigma=2.0, seed=3, min_bw=1e6,
                           max_bw=2e6)
    scalar = np.asarray([a.step(0.5) for _ in range(64)])
    np.testing.assert_array_equal(scalar, b.step_batch(0.5, 64))
    # the clip lives in log space: exp(log(bound)) may round one ulp out
    assert scalar.max() <= 2e6 * (1 + 1e-12)
    assert scalar.min() >= 1e6 * (1 - 1e-12)
    assert (scalar == scalar.min()).sum() > 1     # clipping engaged


def test_cluster_links_step_batch():
    a = make_links("walk", 3, seed=7)
    b = make_links("walk", 3, seed=7)
    scalar = np.stack([a.step(0.5) for _ in range(20)])
    np.testing.assert_array_equal(scalar, b.step_batch(0.5, 20))


# --------------------------------------------------------------------------
# satellite: EventQueue.push_batch FIFO semantics
# --------------------------------------------------------------------------
def test_push_batch_fifo_matches_push():
    """Bulk heapify must pop identically to n pushes: time order with
    FIFO ties, interleaved correctly with pushes before and after."""
    qa, qb = sim.EventQueue(), sim.EventQueue()
    for q in (qa, qb):
        q.push(1.0, "before", "x")
    times = [2.0, 1.0, 1.0, 0.5, 2.0, 1.0]
    payloads = list(range(6))
    for t, p in zip(times, payloads):
        qa.push(t, "batch", p)
    qb.push_batch(times, "batch", payloads)
    for q in (qa, qb):
        q.push(1.0, "after", "y")

    def drain(q):
        out = []
        while q:
            e = q.pop()
            out.append((e.time, e.kind, e.payload))
        return out

    popped = drain(qb)
    assert popped == drain(qa)           # bulk heapify == n sift-ups
    assert popped == [
        (0.5, "batch", 3), (1.0, "before", "x"), (1.0, "batch", 1),
        (1.0, "batch", 2), (1.0, "batch", 5), (1.0, "after", "y"),
        (2.0, "batch", 0), (2.0, "batch", 4)]


def test_push_batch_validates_lengths():
    q = sim.EventQueue()
    with pytest.raises(ValueError, match="payloads"):
        q.push_batch([1.0, 2.0], "x", [None])
    assert q.push_batch([], "x") == [] and not q


# --------------------------------------------------------------------------
# satellite: DriftingEnv.snapshot caching (build counts pinned)
# --------------------------------------------------------------------------
def test_snapshot_caches_until_link_moves(monkeypatch):
    import repro.sim.state as state
    calls = {"n": 0}
    real = state.make_envs

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(state, "make_envs", counting)
    env = sim.DriftingEnv(get_device("jetson-orin-nano"),
                          get_device("edge-server-a100"),
                          sim.FixedLink(60e6), input_bytes=2e6)
    first = env.snapshot()
    for _ in range(10):                  # static link: built exactly once
        assert env.snapshot() is first
    assert calls["n"] == 1
    env.snapshot(5e6)                    # new input size: one more build
    assert calls["n"] == 2
    assert env.snapshot(5e6) is not first and calls["n"] == 2
    env.step(1.0)                        # FixedLink: value unchanged
    assert env.snapshot() is first and calls["n"] == 2

    env.link = sim.RandomWalkLink(60e6, sigma=0.5, seed=1)
    env.step(1.0)                        # link moved: cache invalidated
    env.snapshot()
    assert calls["n"] == 3
    env.snapshot(5e6)
    assert calls["n"] == 4


# --------------------------------------------------------------------------
# satellite: telemetry column batches == per-record completes
# --------------------------------------------------------------------------
def test_complete_arrays_matches_completes():
    a, b = sim.Telemetry(), sim.Telemetry()
    names = ["u", "v", "w"]
    cols = dict(arrived_s=[0.0, 0.1, 0.2], started_s=[0.0, 0.2, 0.4],
                finished_s=[1.0, 0.9, 1.1], node=["n0", "n1", "n0"],
                node_id=[0, 1, 0], deadline_s=[None, 1.0, 0.5],
                energy_j=[5.0, 4.0, 3.0], split=[None, 3, 2],
                switches=[0, 1, 2])
    for k in range(3):
        a.complete(sim.TaskRecord(
            name=names[k], **{key: v[k] for key, v in cols.items()
                              if key not in ("switches",)},
            switches=cols["switches"][k]))
    b.complete_arrays(names, **cols)
    assert len(b) == 3                   # pending counts before build
    assert [rec_tuple(r) for r in a.records] \
        == [rec_tuple(r) for r in b.records]
    assert a.summary() == b.summary()
    with pytest.raises(ValueError, match="node_id"):
        b.complete_arrays(["x"], [0.0], [0.0], [1.0], node=["n"],
                          node_id=[], deadline_s=[None], energy_j=[1.0])


# --------------------------------------------------------------------------
# satellite: env-axis padding + sharded decide
# --------------------------------------------------------------------------
def test_pad_envs():
    env = make_env("fixed", 0)
    envs = env.snapshot([1e6, 2e6, 3e6])
    padded, e = dec.pad_envs(envs, 4)
    assert (len(padded), e) == (4, 3)
    np.testing.assert_array_equal(padded.input_bytes,
                                  [1e6, 2e6, 3e6, 3e6])  # repeats last
    same, e2 = dec.pad_envs(envs, 3)
    assert same is envs and e2 == 3
    with pytest.raises(ValueError):
        dec.pad_envs(envs, 0)


def test_decide_all_sharded_single_device(cnn_layers):
    """On one device the helper must fall back to the jit path and stay
    bit-for-bit with the numpy reference."""
    env = make_env("walk", 5)
    envs = env.snapshot(np.linspace(1e5, 8e6, 5))
    ref = dec.decide_all(cnn_layers, envs)
    out = sim.decide_all_sharded(cnn_layers, envs)
    for f in ("splits", "total_time_s", "device_time_s",
              "transfer_time_s", "edge_time_s"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(out, f)))


_SHARDED_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro import sim
from repro.core import decisions as dec
from repro.core import offload as off
from repro.core.workloads import WorkloadConfig
from repro.hw import get_device
import jax
assert jax.device_count() == 8, jax.device_count()
layers = off.workload_layer_costs(WorkloadConfig(
    "cnn", 2, epochs=5, optimiser="adam", lr=1e-3, batch_size=32))
env = sim.DriftingEnv(get_device("jetson-orin-nano"),
                      get_device("edge-server-a100"),
                      sim.RandomWalkLink(60e6, sigma=0.5, seed=5))
envs = env.snapshot(np.linspace(1e5, 8e6, 13))   # 13: forces pad + trim
ref = dec.decide_all(layers, envs)
out = sim.decide_all_sharded(layers, envs)
for f in ("splits", "total_time_s", "device_time_s", "transfer_time_s",
          "edge_time_s"):
    a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(out, f))
    assert a.shape == b.shape and (a == b).all(), f
from repro.launch.mesh import make_debug_mesh
out2 = sim.decide_all_sharded(layers, envs, mesh=make_debug_mesh(8))
assert (np.asarray(out2.splits) == np.asarray(ref.splits)).all()
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_decide_all_sharded_eight_devices():
    """shard_map over an 8-host-device mesh, non-divisible env axis:
    still bit-for-bit with the numpy reference (subprocess because
    XLA_FLAGS must be set before any jax import)."""
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], capture_output=True,
        text=True, cwd=str(Path(__file__).resolve().parent.parent),
        env={**__import__("os").environ,
             "PYTHONPATH": str(Path(__file__).resolve().parent.parent
                               / "src")})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_OK" in r.stdout


@pytest.mark.slow
def test_fleet_jax_split_backend_equivalence(cnn_layers):
    """decide-at-admission under backend='jax': both engines agree with
    each other and with the numpy backend."""
    ref = None
    for backend in ("numpy", "jax"):
        ev, fl, *_ = run_both(
            n_tasks=10, n_nodes=3, arrival="trace", linkkind="walk",
            policy="min_min", mode="decide", ground_truth=False, seed=2,
            cnn_layers=cnn_layers, split_backend=backend)
        assert_bit_for_bit(ev, fl)
        recs = [rec_tuple(r) for r in ev.records]
        if ref is None:
            ref = recs
        else:
            assert recs == ref
